//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the subset of anyhow's API the workspace uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and
//! `Option`. Semantics match anyhow where it matters here: any
//! `std::error::Error` converts into [`Error`] via `?`, and `context`
//! prepends a message (`"context: cause"` in `Display`).

use std::fmt;

/// A string-backed error value. Unlike real anyhow there is no backtrace
/// capture and no downcasting — nothing in this workspace uses either.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`;
// that is what keeps the blanket conversion below coherent (same design
// as real anyhow).
impl<E: std::error::Error> From<E> for Error {
    fn from(err: E) -> Error {
        Error { msg: err.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`: attach context to the error of a `Result`, or turn
/// an `Option::None` into an error.
///
/// The `Result` impl is bounded by `E: Into<Error>`, which covers both
/// real `std::error::Error` values (via the blanket `From` above) and
/// `Error` itself (via the reflexive `From<T> for T`) with one impl.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/real/path")?)
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn context_prepends() {
        let err = io_fail().context("loading config").unwrap_err();
        assert!(err.to_string().starts_with("loading config: "));
    }

    #[test]
    fn with_context_on_option() {
        let none: Option<u32> = None;
        let err = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(err.to_string(), "missing thing");
    }

    #[test]
    fn context_chains_on_error_results() {
        let base: Result<()> = Err(anyhow!("inner {}", 7));
        let err = base.context("outer").unwrap_err();
        assert_eq!(err.to_string(), "outer: inner 7");
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
