//! Bench: RPC serving at connection-count scale — the tentpole claim
//! of the reactor rewrite, measured. Opens `TT_RPC_CONNS` (default
//! 10000, CI runs 1024) mostly-idle connections against one
//! `RpcServer`, drives a small active subset with real sessions, and
//! proves the two resource invariants the thread-per-connection design
//! could not offer:
//!
//! 1. **threads <= jobs + 2** — one event loop + `jobs` workers (+ the
//!    main thread), regardless of connection count;
//! 2. idle connections stay healthy (none evicted, none refused) while
//!    the active subset sees ordinary latencies.
//!
//! Emits `results/BENCH_rpc_scale.json` —
//! `{connections, active, p50_ms, p99_ms, threads}` — the
//! perf-trajectory artifact CI uploads.

use std::io::Write;
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};
use transfer_tuning::device::DeviceProfile;
use transfer_tuning::service::rpc::{
    default_admin_with_gauges, encode_frame, handle_request, read_frame, RpcDefaults, RpcServer,
    ServerConfig, ServerGauges,
};
use transfer_tuning::service::ScheduleService;
use transfer_tuning::util::json::Json;

/// Worker-pool size for the run: small on purpose, so the thread
/// invariant is sharp (6 threads serving 10k connections).
const JOBS: usize = 4;

/// Raise the soft fd limit to the hard limit and report it. The bench
/// needs two fds per connection (client + server end) in one process.
#[cfg(target_os = "linux")]
fn raise_nofile_limit() -> u64 {
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut lim = Rlimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 1024;
        }
        lim.cur = lim.max;
        // Best-effort: if the raise is refused we run under the old
        // soft limit, and the connection count clamps below.
        setrlimit(RLIMIT_NOFILE, &lim);
        let mut now = Rlimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut now) != 0 {
            return 1024;
        }
        now.cur
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_nofile_limit() -> u64 {
    // No portable rlimit FFI off Linux; assume the default is enough
    // and let the clamp below keep the bench runnable.
    4096
}

/// Live thread count of this process (`Threads:` in /proc/self/status).
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

fn main() {
    transfer_tuning::coordinator::set_global_jobs(JOBS);
    let requested: usize =
        std::env::var("TT_RPC_CONNS").ok().and_then(|s| s.parse().ok()).unwrap_or(10_000);
    let fd_limit = raise_nofile_limit();
    // Two fds per held connection, plus headroom for the process's own
    // files, the listener, and the wake pair.
    let usable = (fd_limit.saturating_sub(256) / 2) as usize;
    let connections = requested.min(usable).max(16);
    if connections < requested {
        println!(
            "[bench rpc_scale] fd limit {fd_limit}: clamping {requested} -> {connections} conns"
        );
    }
    let active = 8usize.min(connections);
    let samples_target = 1000usize;

    // An empty service answering the built-in zoo catalog: session
    // replies are deterministic untuned fallbacks, so the bench
    // measures the serving plane, not the tuner.
    let service = ScheduleService::empty(8);
    let d = RpcDefaults { device: DeviceProfile::xeon_e5_2620(), seed: 0xA45 };
    let line = "{\"model\":\"ResNet18\"}";
    handle_request(&service, &d, line); // warm the shared cache
    let expected = handle_request(&service, &d, line).to_compact();
    let frame = encode_frame(line).expect("encodable");

    let t0 = Instant::now();
    // Explicit config: the herd must stay idle for the whole run, so
    // push the idle deadline far past any plausible wall time (a slow
    // runner crossing the default 30s would reap the herd and fail the
    // liveness assert below), and size max_conns to the herd exactly.
    let gauges = std::sync::Arc::new(ServerGauges::default());
    let admin = default_admin_with_gauges(gauges.clone());
    let config = ServerConfig {
        max_conns: connections + active + 64,
        idle_timeout: Duration::from_secs(600),
        ..ServerConfig::default()
    };
    let server = RpcServer::builder()
        .defaults(d)
        .admin(admin)
        .config(config)
        .gauges(gauges)
        .start("127.0.0.1:0", service)
        .expect("bind");
    let addr = server.local_addr();
    let gauges = server.gauges();

    // The idle herd, paced so the kernel backlog never overflows (the
    // event loop accepts greedily, but connect bursts outrun it).
    let mut idle = Vec::with_capacity(connections);
    for i in 0..connections {
        match TcpStream::connect(addr) {
            Ok(s) => idle.push(s),
            Err(e) => panic!("connect {i}/{connections} failed: {e}"),
        }
        if i % 100 == 99 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    // Every connection registered, none evicted or refused.
    let deadline = Instant::now() + Duration::from_secs(60);
    while gauges.connections.load(std::sync::atomic::Ordering::SeqCst) < connections {
        assert!(Instant::now() < deadline, "reactor never registered the idle herd");
        std::thread::sleep(Duration::from_millis(10));
    }
    let connect_wall = t0.elapsed().as_secs_f64();

    // Thread invariant, measured while all connections are live: main
    // + event loop + JOBS workers, nothing per-connection.
    let threads = process_threads().unwrap_or(JOBS + 2);
    assert!(
        threads <= JOBS + 2,
        "{connections} connections cost {threads} threads (cap: jobs+2 = {})",
        JOBS + 2
    );

    // The active subset: real framed sessions, round-robin across a
    // few connections, every reply byte-checked against the oracle.
    let mut actives: Vec<TcpStream> =
        (0..active).map(|_| TcpStream::connect(addr).expect("active connect")).collect();
    let mut latencies_ms = Vec::with_capacity(samples_target);
    for i in 0..samples_target {
        let conn = &mut actives[i % active];
        let t = Instant::now();
        conn.write_all(&frame).expect("send");
        let got = read_frame(conn).expect("reply");
        latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
        assert_eq!(got, expected, "reply diverged under load (sample {i})");
    }
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies_ms[((latencies_ms.len() - 1) as f64 * p / 100.0) as usize];
    let (p50, p99) = (pct(50.0), pct(99.0));

    // Idle herd still fully alive after the active burst (no eviction,
    // no starvation).
    let live = gauges.connections.load(std::sync::atomic::Ordering::SeqCst);
    assert!(
        live >= connections,
        "idle connections were lost under load: {live} < {connections}"
    );

    println!(
        "[bench rpc_scale] {connections} idle + {active} active conns on {threads} threads \
         (jobs={JOBS}): p50 {p50:.3} ms, p99 {p99:.3} ms, connect wall {connect_wall:.2}s"
    );

    let report = Json::obj(vec![
        ("bench", Json::str("rpc_scale")),
        ("connections", Json::num(connections as f64)),
        ("active", Json::num(active as f64)),
        ("p50_ms", Json::num(p50)),
        ("p99_ms", Json::num(p99)),
        ("threads", Json::num(threads as f64)),
        ("jobs", Json::num(JOBS as f64)),
    ]);
    std::fs::create_dir_all("results").ok();
    let out = Path::new("results").join("BENCH_rpc_scale.json");
    let mut text = report.to_compact();
    text.push('\n');
    std::fs::write(&out, text).expect("write BENCH_rpc_scale.json");
    println!("[bench rpc_scale] wrote {}", out.display());

    drop(actives);
    drop(idle);
    server.shutdown();
}
