//! Bench: ablations of the design choices DESIGN.md calls out.
//!
//! 1. **Eq. 1 exponents** — the paper squares the class proportion and
//!    square-roots the schedule count "to avoid models with very high
//!    numbers of schedules dominating". Compare against linear/linear.
//! 2. **Pool sampling** (paper §4.4.2/§5.5 extension): full pool vs
//!    random-k vs source-quality-k — speedup retained vs search time
//!    saved.
//! 3. **cache_write** — how much the local accumulation buffer
//!    (Algorithm 1 line 22) matters for a large GEMM.

use transfer_tuning::autosched::{tune_model, TuneOptions};
use transfer_tuning::device::{simulate, untuned_kernel_times, DeviceProfile};
use transfer_tuning::ir::{KernelBuilder, ModelGraph};
use transfer_tuning::report::{ExperimentConfig, Zoo};
use transfer_tuning::sched::{apply, Schedule};
use transfer_tuning::transfer::{
    class_proportions, sample_by_source_quality, sample_random, transfer_tune,
};
use transfer_tuning::util::table::{fmt_duration, fmt_speedup, Table};

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500);
    let device = DeviceProfile::xeon_e5_2620();
    let t0 = std::time::Instant::now();
    let zoo = Zoo::build(
        ExperimentConfig {
            trials,
            seed: 0xA45,
            device: device.clone(),
            jobs: 0,
            speculative_keep: 1.0,
            ..Default::default()
        },
        |l| eprintln!("  {l}"),
    );

    // ---- 1. heuristic exponents ----------------------------------------
    let mut h = Table::new(
        "Ablation: Eq. 1 exponents (choice-1 per target)",
        &["Target", "P^2*sqrt(W) (paper)", "P*W (linear)"],
    );
    for m in &zoo.models {
        let props = class_proportions(m, &device);
        let paper_choice = zoo.choices(m).first().map(|(n, _)| n.clone()).unwrap_or_default();
        // Linear variant: P * W.
        let mut linear: Vec<(String, f64)> = zoo
            .store
            .source_models()
            .into_iter()
            .filter(|s| s != &m.name)
            .map(|s| {
                let score: f64 = props
                    .iter()
                    .map(|(sig, p)| p * zoo.store.class_count(&s, sig) as f64)
                    .sum();
                (s, score)
            })
            .collect();
        linear.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let linear_choice = linear.first().map(|(n, _)| n.clone()).unwrap_or_default();
        h.row(vec![m.name.clone(), paper_choice, linear_choice]);
    }
    print!("{}", h.render());
    h.write_csv(std::path::Path::new("results"), "ablation_heuristic").ok();
    println!();

    // ---- 2. pool sampling ------------------------------------------------
    let mut p = Table::new(
        "Ablation: mixed-pool sampling (paper §4.4.2 extension)",
        &["Target", "Strategy", "Pairs", "Speedup", "Search time"],
    );
    for name in ["ResNet18", "GoogLeNet", "MobileNetV2"] {
        let m = &zoo.models[zoo.model_index(name).unwrap()];
        let full_pool = transfer_tuning::transfer::ScheduleStore {
            records: zoo
                .store
                .records
                .iter()
                .filter(|r| r.source_model != m.name)
                .cloned()
                .collect(),
        };
        let variants: Vec<(&str, transfer_tuning::transfer::ScheduleStore)> = vec![
            ("full pool", full_pool.clone()),
            ("random k=8", sample_random(&full_pool, 8, 0xA45)),
            ("quality k=8", sample_by_source_quality(&full_pool, 8)),
        ];
        for (label, store) in variants {
            let res = transfer_tune(m, &store, &device, label, 0xA45);
            p.row(vec![
                m.name.clone(),
                label.into(),
                res.pairs_evaluated().to_string(),
                fmt_speedup(res.speedup()),
                fmt_duration(res.search_time_s()),
            ]);
        }
    }
    print!("{}", p.render());
    p.write_csv(std::path::Path::new("results"), "ablation_sampling").ok();
    println!();

    // ---- 3. cache_write --------------------------------------------------
    let mut cw = Table::new(
        "Ablation: cache-write (Alg. 1 line 22) on a 1024^2 GEMM",
        &["Variant", "Simulated time", "vs with"],
    );
    let mut g = ModelGraph::new("gemm1024");
    g.push(KernelBuilder::dense(1024, 1024, 1024, &[]));
    let res = tune_model(&g, &device, &TuneOptions { trials: 600, seed: 3, ..Default::default() });
    let mut best = res.best[&0].schedule.clone();
    best.cache_write = true;
    let with_cw = simulate(&g.kernels[0], &apply(&best, &g.kernels[0]).unwrap(), &device).total_s;
    best.cache_write = false;
    let without = simulate(&g.kernels[0], &apply(&best, &g.kernels[0]).unwrap(), &device).total_s;
    cw.row(vec!["with cache_write".into(), fmt_duration(with_cw), "1.00x".into()]);
    cw.row(vec![
        "without".into(),
        fmt_duration(without),
        format!("{:.2}x", without / with_cw),
    ]);
    print!("{}", cw.render());
    cw.write_csv(std::path::Path::new("results"), "ablation_cachewrite").ok();

    let _ = untuned_kernel_times(&g, &device);
    let _ = Schedule::naive(&g.kernels[0]);
    println!("\n[bench ablations] trials={trials} host_wall={:.1}s", t0.elapsed().as_secs_f64());
}
