//! Bench: regenerate Fig 6 — transfer-tuning vs Ansor on the edge CPU
//! (Cortex-A72 profile with RPC-measurement overheads).

use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{figures, ExperimentConfig, Zoo};

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let t0 = std::time::Instant::now();
    let zoo = Zoo::build(
        ExperimentConfig {
            trials,
            seed: 0xA45,
            device: DeviceProfile::cortex_a72(),
            jobs: 0,
            speculative_keep: 1.0,
            ..Default::default()
        },
        |l| eprintln!("  {l}"),
    );
    let table = figures::fig5(&zoo); // same emitter; edge device selects Fig 6 framing
    print!("{}", table.render());
    table.write_csv(std::path::Path::new("results"), "fig6").ok();
    println!(
        "\n[bench fig6_edge] trials={} host_wall={:.1}s",
        trials,
        t0.elapsed().as_secs_f64()
    );
}
