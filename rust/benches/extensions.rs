//! Bench: the paper's stated future-work directions, implemented as
//! first-class features.
//!
//! 1. **Cross-device transfer** (§5.3: "explore if transfer-tuning is
//!    viable between hardware platforms"): schedules tuned on the Xeon
//!    applied to the Cortex-A72 target, vs natively-edge-tuned sources.
//! 2. **CNN input-size transfer** (§5.4: fine-tuned models with a new
//!    input size): ResNet18 at 224 -> ResNet18 at 192/160.
//! 3. **Cross-class adaptation** (§4.2): E/G schedules adapted onto
//!    ResNet18's uncovered class-F kernels.
//! 4. **Pairwise-aware refinement** (§5.5: "evaluating kernels
//!    pairwise"): in-context re-selection among near-best candidates.

use transfer_tuning::autosched::{tune_model, TuneOptions};
use transfer_tuning::device::DeviceProfile;
use transfer_tuning::models;
use transfer_tuning::transfer::{
    refine_pairwise, transfer_tune, transfer_tune_with, ScheduleStore, TransferOptions,
};
use transfer_tuning::util::table::{fmt_duration, fmt_speedup, Table};

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500);
    let seed = 0xA45;
    let t0 = std::time::Instant::now();
    let server = DeviceProfile::xeon_e5_2620();
    let edge = DeviceProfile::cortex_a72();
    let opts = TuneOptions { trials, seed, ..Default::default() };

    // ---- 1. cross-device transfer --------------------------------------
    let src = models::resnet::resnet50();
    eprintln!("tuning ResNet50 on server + edge ({trials} trials each) ...");
    let mut server_store = ScheduleStore::new();
    server_store.add_tuning(&src, &tune_model(&src, &server, &opts));
    let mut edge_store = ScheduleStore::new();
    edge_store.add_tuning(&src, &tune_model(&src, &edge, &opts));

    let target = models::resnet::resnet18();
    let cross_dev = transfer_tune(&target, &server_store, &edge, "ResNet50@server", seed);
    let native_dev = transfer_tune(&target, &edge_store, &edge, "ResNet50@edge", seed);
    let mut t1 = Table::new(
        "Ext 1: cross-device transfer (target = ResNet18 on cortex-a72)",
        &["Schedule source", "Speedup", "Search time"],
    );
    t1.row(vec![
        "tuned on xeon-e5-2620 (cross-device)".into(),
        fmt_speedup(cross_dev.speedup()),
        fmt_duration(cross_dev.search_time_s()),
    ]);
    t1.row(vec![
        "tuned on cortex-a72 (native)".into(),
        fmt_speedup(native_dev.speedup()),
        fmt_duration(native_dev.search_time_s()),
    ]);
    print!("{}", t1.render());
    t1.write_csv(std::path::Path::new("results"), "ext_cross_device").ok();
    println!();

    // ---- 2. CNN input-size transfer ------------------------------------
    eprintln!("tuning ResNet18-224 on server ...");
    let rn224 = models::resnet::resnet18();
    let mut store224 = ScheduleStore::new();
    store224.add_tuning(&rn224, &tune_model(&rn224, &server, &opts));
    let mut t2 = Table::new(
        "Ext 2: input-size transfer (ResNet18-224 schedules -> smaller inputs)",
        &["Target", "Speedup", "Search time", "Invalid pairs"],
    );
    for hw in [192u64, 160] {
        let tgt = models::resnet::resnet18_hw(hw);
        let res = transfer_tune(&tgt, &store224, &server, "ResNet18-224", seed);
        t2.row(vec![
            tgt.name.clone(),
            fmt_speedup(res.speedup()),
            fmt_duration(res.search_time_s()),
            format!("{}/{}", res.invalid_pairs(), res.pairs_evaluated()),
        ]);
    }
    print!("{}", t2.render());
    t2.write_csv(std::path::Path::new("results"), "ext_input_size").ok();
    println!();

    // ---- 3. cross-class adaptation --------------------------------------
    let plain = transfer_tune(&target, &server_store, &server, "ResNet50", seed);
    let cross = transfer_tune_with(
        &target,
        &server_store,
        &server,
        "ResNet50",
        seed,
        &TransferOptions { cross_class: true, ..Default::default() },
    );
    let f_kernels = target.kernels_of_class("conv2d_bias_add_relu");
    let covered = |r: &transfer_tuning::transfer::TransferResult| {
        f_kernels.iter().filter(|&&k| r.sweeps[k].chosen.is_some()).count()
    };
    let mut t3 = Table::new(
        "Ext 3: cross-class adaptation (ResNet18 <- ResNet50, class F uncovered in-paper)",
        &["Mode", "Class-F kernels covered", "Speedup", "Pairs", "Search time"],
    );
    t3.row(vec![
        "same-class only (paper)".into(),
        format!("{}/{}", covered(&plain), f_kernels.len()),
        fmt_speedup(plain.speedup()),
        plain.pairs_evaluated().to_string(),
        fmt_duration(plain.search_time_s()),
    ]);
    t3.row(vec![
        "with E/G->F adaptation".into(),
        format!("{}/{}", covered(&cross), f_kernels.len()),
        fmt_speedup(cross.speedup()),
        cross.pairs_evaluated().to_string(),
        fmt_duration(cross.search_time_s()),
    ]);
    print!("{}", t3.render());
    t3.write_csv(std::path::Path::new("results"), "ext_cross_class").ok();
    println!();

    // ---- 4. pairwise refinement ------------------------------------------
    let refined = refine_pairwise(&target, &server_store, &plain, &server, 0.15);
    let mut t4 = Table::new(
        "Ext 4: pairwise-aware refinement (ResNet18 <- ResNet50)",
        &["Stage", "Model time", "Improvement", "Extra measurements"],
    );
    t4.row(vec![
        "standalone selection".into(),
        fmt_duration(refined.baseline_model_s),
        "1.00x".into(),
        "0".into(),
    ]);
    t4.row(vec![
        format!("pairwise refined ({} picks changed)", refined.changed),
        fmt_duration(refined.refined_model_s),
        format!("{:.3}x", refined.improvement()),
        refined.extra_ledger.measurements.to_string(),
    ]);
    print!("{}", t4.render());
    t4.write_csv(std::path::Path::new("results"), "ext_pairwise").ok();

    println!("\n[bench extensions] trials={trials} host_wall={:.1}s", t0.elapsed().as_secs_f64());
}
