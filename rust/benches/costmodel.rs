//! Bench: learned cost prior vs the static (from-scratch) model on a
//! warm measure cache — the PR-8 payoff claim, gated.
//!
//! A small dense zoo is built cold, its pooled transfers warm the
//! shared measure cache, and `Zoo::refit_cost_model` fits the learned
//! prior from that cache (the training pipeline under test: content-
//! sorted folds, threshold-bucketed corpus). A held-out dense target is
//! then tuned twice at the same budget and seed: once from scratch
//! (static) and once seeded with the fitted prior (learned). Gates:
//!
//!   1. Rank quality on the warm cache: the fitted prior's Spearman
//!      rank correlation over the cache's (features, target) pairs must
//!      beat the static model's — which is 0.0 by construction (an
//!      untrained model predicts a constant and cannot rank anything).
//!      On the tuning trajectory, the primed run must rank at least as
//!      many rounds as the static run (`HistoryPoint::rank_corr`): the
//!      prior carries a model into round one, while the static model
//!      spends its warmup rounds untrained.
//!   2. Quality parity (the PR-6 gates, reused): the learned run's
//!      best-schedule costs stay within x2.0 per kernel and x1.25
//!      geomean of the static run's. The prior steers the search; it
//!      must never wreck it.
//!   3. Determinism: re-fitting on the same cache is hash-stable, and
//!      the primed tune is bit-identical when repeated.
//!
//! Emits `results/BENCH_costmodel.json` — `{trials, pairs, prior_hash,
//! cache_rank_corr_{static,learned}, traj_rank_corr_{static,learned},
//! quality_ratio, static_wall_s, learned_wall_s}` — as the
//! perf-trajectory artifact (uploaded per commit by the CI bench-smoke
//! job, which fails if any gate trips).

use std::path::Path;
use std::time::Instant;
use transfer_tuning::autosched::{
    tune_model, CostModel, CostModelKind, TrainingPair, TuneOptions, TuningResult,
};
use transfer_tuning::device::DeviceProfile;
use transfer_tuning::ir::{KernelBuilder, ModelGraph};
use transfer_tuning::report::{ExperimentConfig, Zoo};
use transfer_tuning::util::json::Json;
use transfer_tuning::util::stats::spearman;
use transfer_tuning::util::table::Table;

fn dense_fat(name: &str, dims: &[u64]) -> ModelGraph {
    let mut g = ModelGraph::new(name);
    for &d in dims {
        g.push(KernelBuilder::dense(d, d, d, &[]));
    }
    g
}

/// Build the learned prior the product way: cold zoo, pooled transfers
/// warm the cache, `refit_cost_model` fits from it. Returns the fitted
/// prior and the warm cache's full training corpus (for evaluation).
fn fit_prior(trials: usize, prof: &DeviceProfile) -> (CostModel, Vec<TrainingPair>) {
    let zoo = Zoo::build_for_models(
        vec![
            dense_fat("PriorSrcA", &[256, 320, 384, 448, 512]),
            dense_fat("PriorSrcB", &[576, 640, 704, 768, 832]),
            dense_fat("PriorSrcC", &[896, 960, 1024, 1088, 1152]),
        ],
        ExperimentConfig {
            trials,
            seed: 0xA47,
            device: prof.clone(),
            jobs: 1,
            cost_model: CostModelKind::Learned,
            ..Default::default()
        },
        None,
        |_| {},
    );
    for m in &zoo.models {
        zoo.transfer_pooled(m);
    }
    let pairs = zoo.training_pairs();
    assert!(
        zoo.refit_cost_model(),
        "warm cache ({} pairs) must cross a refit threshold and train the prior",
        pairs.len()
    );
    // Re-fitting on the same cache is hash-stable: the fit is a pure
    // function of cache contents, so "changed" must report false.
    assert!(!zoo.refit_cost_model(), "re-fit on an unchanged cache must be hash-stable");
    let prior = zoo.cost_model.borrow().clone();
    (prior, pairs)
}

/// Spearman rank correlation of a model's predictions over a corpus,
/// with the tuner's own convention: a constant predictor (every
/// untrained model) has no rank information and scores 0.0.
fn corpus_rank_corr(model: &CostModel, pairs: &[TrainingPair]) -> f64 {
    let preds: Vec<f64> = pairs.iter().map(|p| model.predict(&p.x)).collect();
    // A constant predictor induces no order at all — `spearman` would
    // rank the ties by enumeration order, crediting the corpus layout,
    // not the model.
    if preds.windows(2).all(|w| w[0] == w[1]) {
        return 0.0;
    }
    let ys: Vec<f64> = pairs.iter().map(|p| p.y).collect();
    let r = spearman(&preds, &ys);
    if r.is_finite() {
        r
    } else {
        0.0
    }
}

fn tune_target(target: &ModelGraph, prof: &DeviceProfile, prior: CostModel) -> (TuningResult, f64) {
    let opts = TuneOptions {
        trials: 384,
        batch_size: 16,
        population: 32,
        generations: 2,
        seed: 0xA48,
        jobs: 1,
        prior,
        ..Default::default()
    };
    let t0 = Instant::now();
    let res = tune_model(target, prof, &opts);
    (res, t0.elapsed().as_secs_f64())
}

fn mean_rank_corr(res: &TuningResult) -> f64 {
    if res.history.is_empty() {
        return 0.0;
    }
    res.history.iter().map(|h| h.rank_corr).sum::<f64>() / res.history.len() as f64
}

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(150);
    let prof = DeviceProfile::xeon_e5_2620();

    // ---- fit the prior from a warm cache -------------------------------
    let (prior, pairs) = fit_prior(trials, &prof);
    let prior_hash = prior.content_hash();
    assert_ne!(prior_hash, 0, "fitted prior must have a nonzero identity");

    // ---- gate 1a: rank quality on the warm cache -----------------------
    // The fitted prior must rank the cache's measurements; the static
    // (untrained) model predicts a constant and scores exactly 0.0.
    let static_cache_corr = corpus_rank_corr(&CostModel::default(), &pairs);
    let learned_cache_corr = corpus_rank_corr(&prior, &pairs);
    assert_eq!(static_cache_corr, 0.0, "an untrained model cannot rank anything");
    assert!(
        learned_cache_corr > static_cache_corr,
        "learned rank corr on the warm cache ({learned_cache_corr:.3}) must beat \
         static ({static_cache_corr:.3})"
    );

    // Held-out target: same transfer class (dense) at dims the corpus
    // never tuned — the prior must generalize, not memorize.
    let target = dense_fat("CostModelTarget", &[300, 700, 1100]);

    // ---- static vs learned at the same budget and seed -----------------
    let (static_res, static_wall) = tune_target(&target, &prof, CostModel::default());
    let (learned_res, learned_wall) = tune_target(&target, &prof, prior.clone());

    let static_corr = mean_rank_corr(&static_res);
    let learned_corr = mean_rank_corr(&learned_res);

    let mut table = Table::new(
        "Warm-cache cost prior vs static (same budget, same seed)",
        &["Regime", "Mean rank corr", "Host s", "Trials", "Charged device s"],
    );
    for (label, res, corr, wall) in [
        ("static", &static_res, static_corr, static_wall),
        ("learned", &learned_res, learned_corr, learned_wall),
    ] {
        table.row(vec![
            label.into(),
            format!("{corr:.3}"),
            format!("{wall:.2}"),
            res.trials_used.to_string(),
            format!("{:.1}", res.search_time_s),
        ]);
    }

    // ---- gate 1b: rank coverage on the trajectory ----------------------
    // The from-scratch run has no trained model in round one (its
    // diagnostic is exactly 0.0); the primed run carries one from the
    // start, so it must rank at least as many rounds. The per-round
    // values themselves are diagnostics (recorded in the JSON below),
    // not gates — both runs retrain on their own measurements after
    // every round, so their later trajectories legitimately diverge.
    assert_eq!(
        static_res.history[0].rank_corr, 0.0,
        "from-scratch run has no trained model in round one"
    );
    let ranked = |res: &TuningResult| res.history.iter().filter(|h| h.rank_corr != 0.0).count();
    assert!(
        ranked(&learned_res) >= ranked(&static_res),
        "primed run ranked fewer rounds ({}) than from-scratch ({})",
        ranked(&learned_res),
        ranked(&static_res)
    );

    // ---- gate 2: quality parity (the PR-6 gates, learned vs static) ----
    let mut log_ratio_sum = 0.0f64;
    let mut kernels = 0usize;
    for (k, static_best) in &static_res.best {
        let learned_best = learned_res.best.get(k).expect("primed run tuned the same kernels");
        let ratio = learned_best.cost_s / static_best.cost_s.max(1e-12);
        assert!(
            ratio <= 2.0,
            "kernel {k}: learned best {:.3e}s vs static {:.3e}s (x{ratio:.2})",
            learned_best.cost_s,
            static_best.cost_s,
        );
        log_ratio_sum += ratio.max(1e-12).ln();
        kernels += 1;
    }
    assert!(kernels > 0, "target tune produced no kernels");
    let quality_ratio = (log_ratio_sum / kernels as f64).exp();
    assert!(
        quality_ratio <= 1.25,
        "geomean learned/static cost ratio x{quality_ratio:.3} exceeds the x1.25 parity gate"
    );

    // ---- gate 3: determinism -------------------------------------------
    // Identical budget + seed + prior => bit-identical primed tune.
    let (learned_again, _) = tune_target(&target, &prof, prior);
    assert_eq!(learned_again.trials_used, learned_res.trials_used);
    assert_eq!(
        learned_again.search_time_s.to_bits(),
        learned_res.search_time_s.to_bits(),
        "repeated primed tune must charge an identical ledger"
    );
    for (k, best) in &learned_res.best {
        let again = learned_again.best.get(k).expect("same kernels");
        assert_eq!(again.schedule, best.schedule, "kernel {k}: primed tune must be deterministic");
        assert_eq!(again.cost_s.to_bits(), best.cost_s.to_bits(), "kernel {k}");
    }

    print!("{}", table.render());
    println!(
        "[bench costmodel] prior {prior_hash:016x} from {} pairs; warm-cache rank corr \
         static {static_cache_corr:.3} -> learned {learned_cache_corr:.3}, trajectory mean \
         {static_corr:.3} -> {learned_corr:.3}, geomean quality x{quality_ratio:.3} over \
         {kernels} kernels",
        pairs.len(),
    );

    // The perf-trajectory artifact: one JSON object per run.
    let report = Json::obj(vec![
        ("bench", Json::str("costmodel")),
        ("trials", Json::num(trials as f64)),
        ("pairs", Json::num(pairs.len() as f64)),
        ("prior_hash", Json::str(format!("{prior_hash:016x}"))),
        ("cache_rank_corr_static", Json::num(static_cache_corr)),
        ("cache_rank_corr_learned", Json::num(learned_cache_corr)),
        ("traj_rank_corr_static", Json::num(static_corr)),
        ("traj_rank_corr_learned", Json::num(learned_corr)),
        ("quality_ratio", Json::num(quality_ratio)),
        ("static_wall_s", Json::num(static_wall)),
        ("learned_wall_s", Json::num(learned_wall)),
    ]);
    std::fs::create_dir_all("results").ok();
    let out = Path::new("results").join("BENCH_costmodel.json");
    let mut text = report.to_compact();
    text.push('\n');
    std::fs::write(&out, text).expect("write BENCH_costmodel.json");
    println!("[bench costmodel] wrote {}", out.display());
}
