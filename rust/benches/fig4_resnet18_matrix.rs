//! Bench: regenerate Fig 4 — ResNet18 kernels x ResNet50 schedules
//! standalone sweep (including the invalid `-1` entries).

use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{figures, ExperimentConfig, Zoo};

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let t0 = std::time::Instant::now();
    let zoo = Zoo::build(
        ExperimentConfig {
            trials,
            seed: 0xA45,
            device: DeviceProfile::xeon_e5_2620(),
            jobs: 0,
            speculative_keep: 1.0,
            ..Default::default()
        },
        |l| eprintln!("  {l}"),
    );
    let table = figures::fig4(&zoo);
    print!("{}", table.render());
    table.write_csv(std::path::Path::new("results"), "fig4").ok();
    let invalid = table.rows.iter().filter(|r| r[3] == "-1").count();
    println!(
        "\n[bench fig4_resnet18_matrix] pairs={} invalid={} host_wall={:.1}s",
        table.rows.len(),
        invalid,
        t0.elapsed().as_secs_f64()
    );
}
