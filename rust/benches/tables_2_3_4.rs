//! Bench: regenerate Tables 2, 3 and 4 (kernel classes + heuristic
//! choices; top-3 choice speedups; TT vs full-Ansor percentages).

use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{tables, ExperimentConfig, Zoo};

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let t0 = std::time::Instant::now();
    let zoo = Zoo::build(
        ExperimentConfig {
            trials,
            seed: 0xA45,
            device: DeviceProfile::xeon_e5_2620(),
            jobs: 0,
            speculative_keep: 1.0,
            ..Default::default()
        },
        |l| eprintln!("  {l}"),
    );
    for (table, slug) in [
        (tables::table2(&zoo), "table2"),
        (tables::table3(&zoo), "table3"),
        (tables::table4(&zoo), "table4"),
    ] {
        print!("{}", table.render());
        table.write_csv(std::path::Path::new("results"), slug).ok();
        println!();
    }
    println!(
        "[bench tables_2_3_4] trials={} host_wall={:.1}s",
        trials,
        t0.elapsed().as_secs_f64()
    );
}
