//! Bench: regenerate Fig 8 — one-to-one vs mixed-pool transfer-tuning.

use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{figures, ExperimentConfig, Zoo};

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let t0 = std::time::Instant::now();
    let zoo = Zoo::build(
        ExperimentConfig {
            trials,
            seed: 0xA45,
            device: DeviceProfile::xeon_e5_2620(),
            jobs: 0,
            speculative_keep: 1.0,
            ..Default::default()
        },
        |l| eprintln!("  {l}"),
    );
    let table = figures::fig8(&zoo);
    print!("{}", table.render());
    table.write_csv(std::path::Path::new("results"), "fig8").ok();
    println!(
        "\n[bench fig8_pool] trials={} host_wall={:.1}s",
        trials,
        t0.elapsed().as_secs_f64()
    );
}
