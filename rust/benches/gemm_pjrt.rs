//! Bench: the §4.1 GEMM experiment on *real* PJRT executions — wall-clock
//! of the native / transferred / naive schedule artifacts.
//!
//! Needs `make artifacts`; prints a skip note otherwise so `cargo bench`
//! works on a fresh clone.

use transfer_tuning::runtime::{artifacts_dir, Runtime};
use transfer_tuning::util::rng::Rng;
use transfer_tuning::util::table::Table;

fn main() {
    let dir = artifacts_dir();
    if !transfer_tuning::runtime::AVAILABLE {
        println!("[bench gemm_pjrt] skipped: build with --features pjrt for real PJRT execution");
        return;
    }
    if !dir.join("manifest.json").exists() {
        println!("[bench gemm_pjrt] skipped: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let mut rng = Rng::new(42);
    let mut table = Table::new(
        "GEMM schedules on PJRT (real wall-clock)",
        &["Artifact", "Time/call (ms)", "vs native"],
    );
    let t0 = std::time::Instant::now();
    for size in [512usize, 1024] {
        let x: Vec<f32> = (0..size * size).map(|_| rng.f64() as f32 - 0.5).collect();
        let w: Vec<f32> = (0..size * size).map(|_| rng.f64() as f32 - 0.5).collect();
        let shape = [size as i64, size as i64];
        let mut native = 0.0f64;
        for variant in ["native", "xfer", "naive"] {
            let kernel = rt
                .load_hlo_text(&dir.join(format!("gemm{size}_{variant}.hlo.txt")))
                .expect("artifact loads");
            let (warmup, iters) = match (variant, size) {
                ("naive", _) => (0, 1),
                (_, 512) => (2, 9),
                _ => (1, 3),
            };
            let t = kernel
                .bench_f32(&[(&x, &shape), (&w, &shape)], warmup, iters)
                .expect("bench runs");
            if variant == "native" {
                native = t;
            }
            table.row(vec![
                format!("gemm{size}_{variant}"),
                format!("{:.2}", t * 1e3),
                format!("{:+.1}%", (t / native - 1.0) * 100.0),
            ]);
        }
    }
    print!("{}", table.render());
    table.write_csv(std::path::Path::new("results"), "gemm_pjrt").ok();
    println!("\n[bench gemm_pjrt] host_wall={:.1}s", t0.elapsed().as_secs_f64());
}
