//! Bench: cold zoo builds, serial vs `--jobs 4` — the wall-clock payoff
//! of the deterministic parallel tuning pipeline, and the proof that it
//! is *only* a wall-clock knob.
//!
//! Two cold builds of the same zoo (`jobs = 1` vs `jobs = 4`) must
//! produce a byte-identical persisted `ScheduleStore`, identical
//! `ZooBuildStats` trial counts, and bit-identical standalone search
//! times — while the parallel build beats the serial one on the clock.
//! A warm rebuild at `jobs = 4` over the serial build's artifacts must
//! still run 0 trials and charge 0.0 device-seconds (parallelism can
//! never turn a warm-start into work).
//!
//! Emits `results/BENCH_parallel_zoo.json` — `{wall_s, jobs, trials}`
//! plus the serial reference — as the repo's perf-trajectory artifact
//! (the CI bench-smoke job uploads it per commit).

use std::path::{Path, PathBuf};
use std::time::Instant;
use transfer_tuning::artifact::ArtifactStore;
use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{ExperimentConfig, Zoo};
use transfer_tuning::util::json::Json;
use transfer_tuning::util::table::Table;

const PARALLEL_JOBS: usize = 4;

fn build(trials: usize, jobs: usize, artifacts: Option<&mut ArtifactStore>) -> (Zoo, f64) {
    let config = ExperimentConfig {
        trials,
        seed: 0xA45,
        device: DeviceProfile::xeon_e5_2620(),
        jobs,
        speculative_keep: 1.0,
        ..Default::default()
    };
    let t0 = Instant::now();
    let zoo = Zoo::build_incremental(config, artifacts, |_| {});
    (zoo, t0.elapsed().as_secs_f64())
}

/// The one `store_*.jsonl` artifact in a cache dir, as raw bytes.
fn persisted_store_bytes(dir: &Path) -> Vec<u8> {
    let mut stores: Vec<PathBuf> = std::fs::read_dir(dir)
        .expect("read artifact dir")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("store_") && n.ends_with(".jsonl"))
        })
        .collect();
    assert_eq!(stores.len(), 1, "expected exactly one persisted store in {}", dir.display());
    std::fs::read(stores.remove(0)).expect("read persisted store")
}

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(150);
    let dir_serial = std::env::temp_dir().join("tt_bench_parallel_zoo_serial");
    let dir_parallel = std::env::temp_dir().join("tt_bench_parallel_zoo_parallel");
    let _ = std::fs::remove_dir_all(&dir_serial);
    let _ = std::fs::remove_dir_all(&dir_parallel);

    let mut table = Table::new(
        "Cold zoo build: serial vs parallel (deterministic pipeline)",
        &["Regime", "Jobs", "Host s", "Models tuned", "Trials run", "Tuning device s"],
    );

    // ---- cold, serial --------------------------------------------------
    let (serial_zoo, serial_wall) = build(trials, 1, None);
    table.row(vec![
        "cold".into(),
        "1".into(),
        format!("{serial_wall:.2}"),
        serial_zoo.build_stats.models_tuned.to_string(),
        serial_zoo.build_stats.trials_run.to_string(),
        format!("{:.1}", serial_zoo.build_stats.tuning_seconds_charged),
    ]);

    // ---- cold, parallel ------------------------------------------------
    let (par_zoo, par_wall) = build(trials, PARALLEL_JOBS, None);
    table.row(vec![
        "cold".into(),
        PARALLEL_JOBS.to_string(),
        format!("{par_wall:.2}"),
        par_zoo.build_stats.models_tuned.to_string(),
        par_zoo.build_stats.trials_run.to_string(),
        format!("{:.1}", par_zoo.build_stats.tuning_seconds_charged),
    ]);

    // ---- determinism gates --------------------------------------------
    assert_eq!(
        serial_zoo.build_stats.trials_run, par_zoo.build_stats.trials_run,
        "trial counts must not depend on jobs"
    );
    assert_eq!(
        serial_zoo.build_stats.tuning_seconds_charged.to_bits(),
        par_zoo.build_stats.tuning_seconds_charged.to_bits(),
        "charged tuning seconds must be bit-identical"
    );
    for (a, b) in serial_zoo.tunings.iter().zip(&par_zoo.tunings) {
        assert_eq!(a.model, b.model, "models must land in submission order");
        assert_eq!(
            a.search_time_s.to_bits(),
            b.search_time_s.to_bits(),
            "standalone search time of {} drifted across jobs",
            a.model
        );
    }
    assert_eq!(
        serial_zoo.store.to_jsonl(),
        par_zoo.store.to_jsonl(),
        "merged schedule store must be byte-identical across jobs"
    );

    // Persisted form too: both zoos written through the artifact store
    // land byte-identical `store_*.jsonl` files under the same key.
    let mut artifacts_serial = ArtifactStore::open(&dir_serial).expect("open serial dir");
    serial_zoo.persist(&mut artifacts_serial).expect("persist serial zoo");
    let mut artifacts_parallel = ArtifactStore::open(&dir_parallel).expect("open parallel dir");
    par_zoo.persist(&mut artifacts_parallel).expect("persist parallel zoo");
    drop(artifacts_serial);
    drop(artifacts_parallel);
    assert_eq!(
        persisted_store_bytes(&dir_serial),
        persisted_store_bytes(&dir_parallel),
        "persisted ScheduleStore bytes must be identical across jobs"
    );

    // ---- warm, parallel, over artifacts from another jobs setting -----
    // (tuning artifacts were not persisted by the cold in-memory builds,
    // so seed the dir with a cold artifact-backed build first — itself a
    // cross-check: artifact-backed, parallel, must reproduce the serial
    // in-memory store byte for byte)
    let mut artifacts = ArtifactStore::open(&dir_serial).expect("reopen serial dir");
    let (seeded_zoo, _) = build(trials, PARALLEL_JOBS, Some(&mut artifacts));
    assert_eq!(
        seeded_zoo.store.to_jsonl(),
        serial_zoo.store.to_jsonl(),
        "artifact-backed build must reproduce the in-memory store"
    );
    drop(seeded_zoo);
    let (warm_zoo, warm_wall) = build(trials, PARALLEL_JOBS, Some(&mut artifacts));
    table.row(vec![
        "warm".into(),
        PARALLEL_JOBS.to_string(),
        format!("{warm_wall:.2}"),
        warm_zoo.build_stats.models_tuned.to_string(),
        warm_zoo.build_stats.trials_run.to_string(),
        format!("{:.1}", warm_zoo.build_stats.tuning_seconds_charged),
    ]);
    assert_eq!(warm_zoo.build_stats.trials_run, 0, "warm parallel build must run zero trials");
    assert_eq!(warm_zoo.build_stats.models_tuned, 0);
    assert_eq!(warm_zoo.build_stats.tuning_seconds_charged, 0.0);
    assert_eq!(
        warm_zoo.store.to_jsonl(),
        serial_zoo.store.to_jsonl(),
        "warm parallel store must be byte-identical"
    );

    print!("{}", table.render());
    println!(
        "[bench parallel_zoo] cold speedup: {:.2}x (jobs=1 {:.2}s -> jobs={} {:.2}s), \
         stores byte-identical",
        serial_wall / par_wall.max(1e-9),
        serial_wall,
        PARALLEL_JOBS,
        par_wall,
    );

    // The perf-trajectory artifact: one JSON object per run.
    let report = Json::obj(vec![
        ("bench", Json::str("parallel_zoo")),
        ("jobs", Json::num(PARALLEL_JOBS as f64)),
        ("trials", Json::num(trials as f64)),
        ("wall_s", Json::num(par_wall)),
        ("serial_wall_s", Json::num(serial_wall)),
        ("speedup", Json::num(serial_wall / par_wall.max(1e-9))),
    ]);
    std::fs::create_dir_all("results").ok();
    let out = Path::new("results").join("BENCH_parallel_zoo.json");
    let mut text = report.to_compact();
    text.push('\n');
    std::fs::write(&out, text).expect("write BENCH_parallel_zoo.json");
    println!("[bench parallel_zoo] wrote {}", out.display());

    // Hard-gate the speedup only when the serial build did meaningful
    // work: at tiny TT_TRIALS budgets on a loaded shared runner,
    // thread overhead can rival the work itself, and a wall-clock
    // flake must not mask the byte-identity gates above (which always
    // run). The JSON artifact records the ratio either way.
    if serial_wall >= 0.5 {
        assert!(
            par_wall < serial_wall,
            "jobs={PARALLEL_JOBS} cold build ({par_wall:.2}s) must beat jobs=1 ({serial_wall:.2}s)"
        );
    } else {
        println!(
            "[bench parallel_zoo] serial build too fast ({serial_wall:.3}s) for a robust \
             wall-clock gate; speedup recorded but not asserted"
        );
    }

    std::fs::remove_dir_all(&dir_serial).ok();
    std::fs::remove_dir_all(&dir_parallel).ok();
}
