//! Bench: cold vs warm `Zoo::build` through the persistent artifact
//! store — the end-to-end payoff of `--cache-dir`.
//!
//! Three regimes of the same zoo build (one zoo = 11 Ansor tunings):
//!
//!   cold     — empty artifact dir: every model is tuned, artifacts
//!              written;
//!   warm     — same dir, fresh store handle (process-equivalent
//!              restart): every tuning is loaded, zero trials run;
//!   warm+rep — warm build plus a pooled report sweep served entirely
//!              from the persisted measurement cache.
//!
//! Reported per regime: host wall-clock, trials run, simulated tuning
//! device-seconds charged, and artifact hits/misses — printed next to
//! the `cache_sweep` numbers (same plain-main harness; the environment
//! has no criterion).

use std::time::Instant;
use transfer_tuning::artifact::ArtifactStore;
use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{ExperimentConfig, Zoo};
use transfer_tuning::util::table::Table;

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(150);
    let config = ExperimentConfig {
        trials,
        seed: 0xA45,
        device: DeviceProfile::xeon_e5_2620(),
        jobs: 0,
        speculative_keep: 1.0,
        ..Default::default()
    };
    let dir = std::env::temp_dir().join("tt_bench_zoo_warm_start");
    let _ = std::fs::remove_dir_all(&dir);

    let mut table = Table::new(
        "Zoo build: cold vs warm through the artifact store",
        &["Regime", "Host s", "Models tuned", "Trials run", "Tuning device s", "Artifact hits"],
    );

    // ---- cold ----------------------------------------------------------
    let mut artifacts = ArtifactStore::open(&dir).expect("open artifact dir");
    let t0 = Instant::now();
    let cold_zoo = Zoo::build_incremental(config.clone(), Some(&mut artifacts), |_| {});
    let cold_host = t0.elapsed().as_secs_f64();
    // Warm the measurement cache with a pooled sweep, then persist.
    let target = cold_zoo.models[cold_zoo.model_index("ResNet18").expect("ResNet18")].clone();
    let cold_sweep = cold_zoo.transfer_pooled(&target);
    cold_zoo.persist(&mut artifacts).expect("persist zoo artifacts");
    table.row(vec![
        "cold".into(),
        format!("{cold_host:.2}"),
        cold_zoo.build_stats.models_tuned.to_string(),
        cold_zoo.build_stats.trials_run.to_string(),
        format!("{:.1}", cold_zoo.build_stats.tuning_seconds_charged),
        artifacts.stats.hits.to_string(),
    ]);
    drop(cold_zoo);
    drop(artifacts);

    // ---- warm (fresh handle = process restart) -------------------------
    let mut artifacts = ArtifactStore::open(&dir).expect("reopen artifact dir");
    let t1 = Instant::now();
    let warm_zoo = Zoo::build_incremental(config, Some(&mut artifacts), |_| {});
    let warm_host = t1.elapsed().as_secs_f64();
    table.row(vec![
        "warm".into(),
        format!("{warm_host:.2}"),
        warm_zoo.build_stats.models_tuned.to_string(),
        warm_zoo.build_stats.trials_run.to_string(),
        format!("{:.1}", warm_zoo.build_stats.tuning_seconds_charged),
        artifacts.stats.hits.to_string(),
    ]);

    // ---- warm + report sweep off the persisted measurement cache ------
    let t2 = Instant::now();
    let warm_sweep = warm_zoo.transfer_pooled(&target);
    let sweep_host = t2.elapsed().as_secs_f64();
    table.row(vec![
        "warm+rep".into(),
        format!("{:.2}", warm_host + sweep_host),
        "0".into(),
        "0".into(),
        format!("{:.1}", warm_sweep.search_time_s()),
        artifacts.stats.hits.to_string(),
    ]);

    print!("{}", table.render());
    println!(
        "[bench zoo_warm_start] host speedup: {:.1}x (cold {:.2}s -> warm {:.2}s); \
         warm sweep charged {:.1}s vs cold {:.1}s",
        cold_host / warm_host.max(1e-9),
        cold_host,
        warm_host,
        warm_sweep.search_time_s(),
        cold_sweep.search_time_s(),
    );

    assert_eq!(warm_zoo.build_stats.trials_run, 0, "warm build must run zero trials");
    assert_eq!(warm_zoo.build_stats.models_tuned, 0);
    assert_eq!(warm_sweep.search_time_s(), 0.0, "warm sweep must be free");
    assert_eq!(
        warm_sweep.tuned_model_s.to_bits(),
        cold_sweep.tuned_model_s.to_bits(),
        "warm results must be bit-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}
