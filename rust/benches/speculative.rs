//! Bench: cold zoo builds, exact (`--speculative-keep 1.0`) vs
//! draft-then-verify (`--speculative-keep 0.25`) — the wall-clock
//! payoff of speculative sweeps, and the proof that pruning is a
//! *quality-bounded* shortcut, not a different experiment.
//!
//! Both builds tune the same models at the same trial budget and seed.
//! The speculative build lets the GBDT draft scorer rank each round's
//! candidate batch and only simulates the top fraction, so it must
//! finish faster on the host clock while landing best-schedule costs
//! within a bounded factor of the exact build (per-kernel x2.0, geomean
//! x1.25). A repeated speculative build must be byte-identical — keep
//! changes *which* experiment runs, never makes it nondeterministic.
//!
//! Emits `results/BENCH_speculative.json` — `{keep, trials,
//! exact_wall_s, spec_wall_s, speedup, quality_ratio}` — as the
//! perf-trajectory artifact (the CI bench-smoke job uploads it per
//! commit and fails if any quality gate trips).

use std::path::Path;
use std::time::Instant;
use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{ExperimentConfig, Zoo};
use transfer_tuning::util::json::Json;
use transfer_tuning::util::table::Table;

const KEEP: f64 = 0.25;

fn build(trials: usize, keep: f64) -> (Zoo, f64) {
    let config = ExperimentConfig {
        trials,
        seed: 0xA46,
        device: DeviceProfile::xeon_e5_2620(),
        jobs: 1,
        speculative_keep: keep,
        ..Default::default()
    };
    let t0 = Instant::now();
    let zoo = Zoo::build_incremental(config, None, |_| {});
    (zoo, t0.elapsed().as_secs_f64())
}

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(150);

    let mut table = Table::new(
        "Cold zoo build: exact vs speculative (draft-then-verify)",
        &["Regime", "Keep", "Host s", "Models tuned", "Trials run", "Tuning device s"],
    );

    // ---- cold, exact ---------------------------------------------------
    let (exact_zoo, exact_wall) = build(trials, 1.0);
    table.row(vec![
        "exact".into(),
        "1.00".into(),
        format!("{exact_wall:.2}"),
        exact_zoo.build_stats.models_tuned.to_string(),
        exact_zoo.build_stats.trials_run.to_string(),
        format!("{:.1}", exact_zoo.build_stats.tuning_seconds_charged),
    ]);

    // ---- cold, speculative ---------------------------------------------
    let (spec_zoo, spec_wall) = build(trials, KEEP);
    table.row(vec![
        "speculative".into(),
        format!("{KEEP:.2}"),
        format!("{spec_wall:.2}"),
        spec_zoo.build_stats.models_tuned.to_string(),
        spec_zoo.build_stats.trials_run.to_string(),
        format!("{:.1}", spec_zoo.build_stats.tuning_seconds_charged),
    ]);

    // ---- budget + determinism gates ------------------------------------
    // Pruned slots still spend their trials (the budget is the
    // experiment's identity), and skipped measurements can only shrink
    // the charged device-seconds.
    assert_eq!(
        exact_zoo.build_stats.trials_run, spec_zoo.build_stats.trials_run,
        "pruning must not refund trials"
    );
    assert!(
        spec_zoo.build_stats.tuning_seconds_charged
            <= exact_zoo.build_stats.tuning_seconds_charged,
        "speculative charged seconds ({}) exceed exact ({})",
        spec_zoo.build_stats.tuning_seconds_charged,
        exact_zoo.build_stats.tuning_seconds_charged,
    );
    let (spec_again, _) = build(trials, KEEP);
    assert_eq!(
        spec_zoo.store.to_jsonl(),
        spec_again.store.to_jsonl(),
        "repeated speculative build must be byte-identical"
    );

    // ---- quality parity -------------------------------------------------
    // Per-kernel: the speculative best must stay within x2.0 of the
    // exact best. In aggregate: the geomean cost ratio must stay
    // within x1.25. Both gates always run, at any TT_TRIALS.
    let mut log_ratio_sum = 0.0f64;
    let mut kernels = 0usize;
    for (exact_t, spec_t) in exact_zoo.tunings.iter().zip(&spec_zoo.tunings) {
        assert_eq!(exact_t.model, spec_t.model, "builds must land models in the same order");
        for (k, exact_best) in &exact_t.best {
            let spec_best = spec_t.best.get(k).expect("speculative run tuned the same kernels");
            let ratio = spec_best.cost_s / exact_best.cost_s.max(1e-12);
            assert!(
                ratio <= 2.0,
                "{} kernel {k}: speculative best {:.3e}s vs exact {:.3e}s (x{ratio:.2})",
                exact_t.model,
                spec_best.cost_s,
                exact_best.cost_s,
            );
            log_ratio_sum += ratio.max(1e-12).ln();
            kernels += 1;
        }
    }
    assert!(kernels > 0, "zoo build produced no tuned kernels");
    let quality_ratio = (log_ratio_sum / kernels as f64).exp();
    assert!(
        quality_ratio <= 1.25,
        "geomean speculative/exact cost ratio x{quality_ratio:.3} exceeds the x1.25 parity gate"
    );

    print!("{}", table.render());
    println!(
        "[bench speculative] cold speedup: {:.2}x (keep=1.00 {:.2}s -> keep={:.2} {:.2}s), \
         geomean quality x{:.3} over {} kernels",
        exact_wall / spec_wall.max(1e-9),
        exact_wall,
        KEEP,
        spec_wall,
        quality_ratio,
        kernels,
    );

    // The perf-trajectory artifact: one JSON object per run.
    let report = Json::obj(vec![
        ("bench", Json::str("speculative")),
        ("keep", Json::num(KEEP)),
        ("trials", Json::num(trials as f64)),
        ("exact_wall_s", Json::num(exact_wall)),
        ("spec_wall_s", Json::num(spec_wall)),
        ("speedup", Json::num(exact_wall / spec_wall.max(1e-9))),
        ("quality_ratio", Json::num(quality_ratio)),
    ]);
    std::fs::create_dir_all("results").ok();
    let out = Path::new("results").join("BENCH_speculative.json");
    let mut text = report.to_compact();
    text.push('\n');
    std::fs::write(&out, text).expect("write BENCH_speculative.json");
    println!("[bench speculative] wrote {}", out.display());

    // Hard-gate the wall-clock win only when the exact build did
    // meaningful work: at tiny TT_TRIALS budgets the draft model's
    // warmup rounds (measure-everything until trained) dominate, and a
    // wall-clock flake must not mask the quality gates above (which
    // always run). The JSON artifact records the ratio either way.
    if exact_wall >= 0.5 {
        assert!(
            spec_wall * 2.0 <= exact_wall,
            "keep={KEEP} cold build ({spec_wall:.2}s) must be at least 2x faster than \
             exact ({exact_wall:.2}s)"
        );
    } else {
        println!(
            "[bench speculative] exact build too fast ({exact_wall:.3}s) for a robust \
             wall-clock gate; speedup recorded but not asserted"
        );
    }
}
