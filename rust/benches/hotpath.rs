//! Bench: the L3 hot paths — what the performance pass optimizes.
//!
//! Times the inner loops that dominate every experiment — schedule
//! application, the cost simulator, the learned cost model (feature
//! extraction + GBDT train/predict) — plus the serving hot path
//! (`ScheduleService::open_session` on a warm cache), and **proves the
//! serving path is zero-copy**: the `StoreRecord` clone counter must
//! not move across sessions (PR 2 cloned a store slice per session;
//! the per-source `Arc` sub-stores + `StoreView` sweeps removed that).
//! Prints ops/second so before/after comparisons in EXPERIMENTS.md
//! §Perf are one-liners.

use std::time::Instant;
use transfer_tuning::autosched::{
    features, random_schedule, tune_model, CostModel, GbdtParams, NUM_FEATURES, TuneOptions,
};
use transfer_tuning::device::{simulate_with, DeviceProfile, SimScratch};
use transfer_tuning::ir::{KernelBuilder, ModelGraph};
use transfer_tuning::sched::apply;
use transfer_tuning::service::{ScheduleService, SessionRequest};
use transfer_tuning::transfer::{store_record_clones, ScheduleStore};
use transfer_tuning::util::rng::Rng;
use transfer_tuning::util::table::Table;

fn rate(n: usize, secs: f64) -> String {
    format!("{:.2} M/s", n as f64 / secs / 1e6)
}

fn main() {
    let profile = DeviceProfile::xeon_e5_2620();
    let mut rng = Rng::new(7);
    let kernels = [
        KernelBuilder::dense(512, 512, 512, &[]),
        KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[transfer_tuning::ir::OpKind::BiasAdd, transfer_tuning::ir::OpKind::Relu]),
        KernelBuilder::batch_matmul(12, 256, 64, 256, &[]),
    ];
    let scheds: Vec<_> = (0..128)
        .map(|i| {
            let k = &kernels[i % kernels.len()];
            (i % kernels.len(), random_schedule(k, &mut rng))
        })
        .collect();

    let mut table = Table::new("L3 hot-path microbenches", &["Path", "Iterations", "Time", "Rate"]);

    // 1. apply()
    let n = 200_000;
    let t0 = Instant::now();
    let mut ok = 0usize;
    for i in 0..n {
        let (ki, s) = &scheds[i % scheds.len()];
        if apply(s, &kernels[*ki]).is_ok() {
            ok += 1;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    table.row(vec!["sched::apply".into(), n.to_string(), format!("{dt:.2}s"), rate(n, dt)]);
    assert!(ok > 0);

    // 2. simulate() with reused scratch (the measurement hot loop)
    let nests: Vec<_> = scheds
        .iter()
        .filter_map(|(ki, s)| apply(s, &kernels[*ki]).ok().map(|nst| (*ki, nst)))
        .collect();
    let n = 200_000;
    let mut scratch = SimScratch::default();
    let t0 = Instant::now();
    let mut acc = 0.0f64;
    for i in 0..n {
        let (ki, nest) = &nests[i % nests.len()];
        acc += simulate_with(&kernels[*ki], nest, &profile, &mut scratch).total_s;
    }
    let dt = t0.elapsed().as_secs_f64();
    table.row(vec!["device::simulate".into(), n.to_string(), format!("{dt:.2}s"), rate(n, dt)]);
    assert!(acc > 0.0);

    // 3. feature extraction
    let n = 200_000;
    let t0 = Instant::now();
    let mut sum = 0.0;
    for i in 0..n {
        let (ki, nest) = &nests[i % nests.len()];
        sum += features(&kernels[*ki], nest, &profile)[0];
    }
    let dt = t0.elapsed().as_secs_f64();
    table.row(vec!["autosched::features".into(), n.to_string(), format!("{dt:.2}s"), rate(n, dt)]);
    assert!(sum.is_finite());

    // 4. GBDT train + predict
    let xs: Vec<[f64; NUM_FEATURES]> = (0..512)
        .map(|i| {
            let (ki, nest) = &nests[i % nests.len()];
            features(&kernels[*ki], nest, &profile)
        })
        .collect();
    let ys: Vec<f64> = (0..512).map(|i| (i % 17) as f64).collect();
    let t0 = Instant::now();
    let rounds = 50;
    let mut model = CostModel::default();
    for _ in 0..rounds {
        model = CostModel::train(&xs, &ys, &GbdtParams::default());
    }
    let dt = t0.elapsed().as_secs_f64();
    table.row(vec![
        "gbdt::train(512)".into(),
        rounds.to_string(),
        format!("{dt:.2}s"),
        format!("{:.1} ms/round", dt * 1e3 / rounds as f64),
    ]);

    let n = 500_000;
    let t0 = Instant::now();
    let mut acc = 0.0;
    for i in 0..n {
        acc += model.predict(&xs[i % xs.len()]);
    }
    let dt = t0.elapsed().as_secs_f64();
    table.row(vec!["gbdt::predict".into(), n.to_string(), format!("{dt:.2}s"), rate(n, dt)]);
    assert!(acc.is_finite());

    // 5. ScheduleService::open_session (the zero-copy serving hot path).
    // Two tuned sources + one target; the first session warms the
    // sharded cache, then sessions are pure cache-hit sweeps — the
    // regime a long-lived service spends its life in.
    let tune_opts = TuneOptions {
        trials: 96,
        batch_size: 16,
        population: 32,
        generations: 2,
        ..Default::default()
    };
    let mut store = ScheduleStore::new();
    let mut models = Vec::new();
    for (name, dim) in [("SrcA", 512u64), ("SrcB", 1024u64)] {
        let mut g = ModelGraph::new(name);
        g.push(KernelBuilder::dense(dim, dim, dim, &[]));
        let res = tune_model(&g, &profile, &tune_opts);
        store.add_tuning(&g, &res);
        models.push(g);
    }
    let mut target = ModelGraph::new("TargetDense");
    target.push(KernelBuilder::dense(768, 768, 768, &[]));
    models.push(target);
    let service = ScheduleService::new(store, models, 8);
    let request = SessionRequest {
        model: "TargetDense".into(),
        device: profile.clone(),
        budget_s: None,
        seed: 7,
    };
    let warm = service.open_session(&request).expect("warm-up session");
    assert!(warm.predicted_speedup() > 1.0);

    let clones_before = store_record_clones();
    let n = 2_000;
    let t0 = Instant::now();
    for _ in 0..n {
        let reply = service.open_session(&request).expect("session");
        assert_eq!(reply.tuned_model_s.to_bits(), warm.tuned_model_s.to_bits());
    }
    let dt = t0.elapsed().as_secs_f64();
    let cloned = store_record_clones() - clones_before;
    table.row(vec![
        "service::open_session".into(),
        n.to_string(),
        format!("{dt:.2}s"),
        format!("{:.1} k sessions/s", n as f64 / dt / 1e3),
    ]);
    assert_eq!(
        cloned, 0,
        "serving hot path must clone zero StoreRecords ({cloned} cloned across {n} sessions)"
    );
    println!("[bench hotpath] {n} warm sessions cloned {cloned} StoreRecords (must be 0)");

    print!("{}", table.render());
    table.write_csv(std::path::Path::new("results"), "hotpath").ok();
}
