//! Bench: the content-addressed measurement cache on pooled-store
//! sweeps (the Fig 8 shape — where the cache pays off hardest).
//!
//! Builds a two-model schedule store, then times three regimes of the
//! same pooled `transfer_tune` sweep:
//!
//!   cold    — empty cache: every unique pair is measured;
//!   rerun   — same sweep again, warm cache: every pair is a hit;
//!   overlap — pool sweep after a one-to-one sweep warmed a subset.
//!
//! Reported per regime: simulated device seconds charged to the ledger
//! (the paper's search-time axis), cache hit rate, and host wall-clock.
//! Offline-friendly plain-main harness, like the other benches here
//! (the environment has no criterion).

use std::time::Instant;
use transfer_tuning::autosched::{tune_model, TuneOptions};
use transfer_tuning::coordinator::{MeasureCache, SweepMetrics};
use transfer_tuning::models;
use transfer_tuning::transfer::{transfer_tune_cached, ScheduleStore, TransferOptions};
use transfer_tuning::util::table::Table;

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let device = transfer_tuning::device::DeviceProfile::xeon_e5_2620();
    let opts = TransferOptions::default();
    let seed = 0xA45;

    let t0 = Instant::now();
    let tgt = models::resnet::resnet18();
    let mut store = ScheduleStore::new();
    for src in [models::resnet::resnet50(), models::googlenet::googlenet()] {
        let tuning = tune_model(
            &src,
            &device,
            &TuneOptions { trials, batch_size: 16, population: 32, generations: 2, seed, ..Default::default() },
        );
        store.add_tuning(&src, &tuning);
    }
    eprintln!(
        "[bench cache_sweep] store: {} records from 2 models ({} trials each, host {:.1}s)",
        store.records.len(),
        trials,
        t0.elapsed().as_secs_f64()
    );

    let mut table = Table::new(
        "Pooled-store sweep: measurement cache amortization",
        &["Regime", "Pairs", "Measured", "Device s", "Hit rate", "Host ms", "Speedup"],
    );
    let mut row = |regime: &str, cache: &mut MeasureCache| {
        cache.reset_stats();
        let t = Instant::now();
        let res = transfer_tune_cached(&tgt, &store, &device, "mixed", seed, &opts, cache);
        let host_ms = t.elapsed().as_secs_f64() * 1e3;
        let m = SweepMetrics::from_parts(&res.ledger, &cache.stats);
        eprintln!("[bench cache_sweep] {regime}: {}", m.summary());
        table.row(vec![
            regime.to_string(),
            res.pairs_evaluated().to_string(),
            m.measurements.to_string(),
            format!("{:.2}", m.device_seconds),
            format!("{:.1}%", m.cache.hit_rate() * 100.0),
            format!("{host_ms:.1}"),
            format!("{:.2}x", res.speedup()),
        ]);
        m.device_seconds
    };

    let mut cache = MeasureCache::new();
    let cold_s = row("cold", &mut cache);
    let rerun_s = row("rerun (warm)", &mut cache);

    let mut overlap_cache = MeasureCache::new();
    {
        // Warm only the ResNet50 slice, as a one-to-one sweep would.
        let slice = store.of_model("ResNet50");
        let _ = transfer_tune_cached(&tgt, &slice, &device, "ResNet50", seed, &opts, &mut overlap_cache);
    }
    let overlap_s = row("overlap (1:1 warmed)", &mut overlap_cache);

    print!("{}", table.render());
    println!(
        "[bench cache_sweep] device-second savings: rerun {:.0}% overlap {:.0}%",
        (1.0 - rerun_s / cold_s) * 100.0,
        (1.0 - overlap_s / cold_s) * 100.0,
    );
    assert!(rerun_s == 0.0, "warm rerun must be free (got {rerun_s})");
    assert!(overlap_s < cold_s, "overlap must be cheaper than cold");
}
