//! Bench: regenerate Fig 7 — BERT/MobileBERT sequence-length transfer
//! (128 <-> 256).

use transfer_tuning::device::DeviceProfile;
use transfer_tuning::report::{figures, ExperimentConfig};

fn main() {
    let trials: usize =
        std::env::var("TT_TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let t0 = std::time::Instant::now();
    let config = ExperimentConfig {
        trials,
        seed: 0xA45,
        device: DeviceProfile::xeon_e5_2620(),
        jobs: 0,
        speculative_keep: 1.0,
        ..Default::default()
    };
    let table = figures::fig7(&config, |l| eprintln!("  {l}"));
    print!("{}", table.render());
    table.write_csv(std::path::Path::new("results"), "fig7").ok();
    println!(
        "\n[bench fig7_seqlen] trials={} host_wall={:.1}s",
        trials,
        t0.elapsed().as_secs_f64()
    );
}
