//! Deterministic fault injection.
//!
//! Robustness work is only testable if failure is reproducible, so this
//! module treats faults the way the rest of the crate treats measurement
//! noise: every injected failure is drawn from a seeded stream and a
//! faulty run is bit-replayable. A [`FaultPlan`] is parsed from
//! `--fault-plan SPEC` (or the `TT_FAULTS` env var) and installed
//! process-wide; instrumented sites then ask [`should_fail`] /
//! [`measure_failure`] / [`sleep_site`] at the moment the real operation
//! would happen.
//!
//! The plan is an operational/testing knob only: it changes *when* work
//! happens (a write errors, a connection drops, a measurement is lost),
//! never *what* a completed artifact contains — so the spec string is
//! deliberately **never** an artifact-key ingredient.
//!
//! # Grammar
//!
//! ```text
//! SPEC    := RULE (';' RULE)*
//! RULE    := SITE ':' OPT (',' OPT)*
//! OPT     := 'after=N'            fire on every op past the Nth
//!          | 'nth=N'              fire on exactly the Nth op (1-based)
//!          | 'prob=P[@seed=S]'    fire with probability P, seeded draw
//!          | 'seed=S'             seed for prob draws (default 0)
//!          | 'delay=MS'           sleep instead of failing (latency fault)
//!          | 'penalty=SECS'       device-seconds charged per lost measurement
//! ```
//!
//! Example: `io.write:after=3;rpc.accept:prob=0.05@seed=7;persist.rename:nth=2`
//!
//! # Sites
//!
//! | site             | effect when fired                                      |
//! |------------------|--------------------------------------------------------|
//! | `io.write`       | artifact payload/manifest temp write torn mid-file     |
//! | `persist.rename` | temp file written + synced, commit rename never happens|
//! | `rpc.accept`     | accepted connection dropped before registration        |
//! | `rpc.read`       | connection read errors (peer torn away) — fires on     |
//! |                  | the server, the thin client, and the fleet router's    |
//! |                  | forwarding link (a flaky backend link is rehearsable)  |
//! | `rpc.write`      | connection write errors (reply lost mid-flush) — same  |
//! |                  | three vantage points as `rpc.read`                     |
//! | `measure.pair`   | one pair's measurement lost (`PairOutcome::Failed`)    |
//! | `rpc.handler`    | handler latency (use `delay=MS`; makes overload        |
//! |                  | deterministic in tests)                                |
//!
//! Counter-triggered sites (`after`/`nth`) count ops in arrival order;
//! `measure.pair` is content-keyed instead (like `pool::noise_seed`), so
//! the same pair fails no matter how a sweep is scheduled across workers.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// When a rule fires, relative to the site's op counter or a seeded draw.
#[derive(Clone, Debug, PartialEq)]
pub enum Trigger {
    /// Fire on every op with 1-based index strictly greater than `n`.
    After(u64),
    /// Fire on exactly the `n`th op (1-based).
    Nth(u64),
    /// Fire with probability `p` per op, from the rule's seeded stream.
    Prob(f64),
}

/// One `site:trigger` clause of a fault plan.
#[derive(Clone, Debug)]
pub struct FaultRule {
    pub site: String,
    pub trigger: Trigger,
    /// Seed for `Prob` draws; decorrelated from measurement noise.
    pub seed: u64,
    /// If set, the site sleeps this long instead of failing.
    pub delay_ms: Option<u64>,
    /// Device-seconds charged for a lost measurement (`measure.pair`).
    pub penalty_s: f64,
}

/// A parsed, installable fault schedule.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse the `--fault-plan` grammar. Errors name the offending clause.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (site, opts) = clause
                .split_once(':')
                .ok_or_else(|| format!("fault clause `{clause}` is missing `site:`"))?;
            let site = site.trim();
            if site.is_empty() {
                return Err(format!("fault clause `{clause}` has an empty site"));
            }
            let mut trigger = None;
            let mut seed = 0u64;
            let mut delay_ms = None;
            let mut penalty_s = 1.0f64;
            for opt in opts.split(',').map(str::trim).filter(|o| !o.is_empty()) {
                // `prob=0.05@seed=7` attaches the seed to the draw inline.
                let (opt, inline_seed) = match opt.split_once('@') {
                    Some((head, tail)) => (head.trim(), Some(tail.trim())),
                    None => (opt, None),
                };
                if let Some(extra) = inline_seed {
                    let v = extra
                        .strip_prefix("seed=")
                        .ok_or_else(|| format!("expected `@seed=N` in `{clause}`"))?;
                    seed = parse_num(v, clause)?;
                }
                let (key, val) = opt
                    .split_once('=')
                    .ok_or_else(|| format!("fault option `{opt}` in `{clause}` is not k=v"))?;
                match key.trim() {
                    "after" => trigger = Some(Trigger::After(parse_num(val, clause)?)),
                    "nth" => trigger = Some(Trigger::Nth(parse_num(val, clause)?)),
                    "prob" => {
                        let p: f64 = val
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad probability `{val}` in `{clause}`"))?;
                        if !(0.0..=1.0).contains(&p) {
                            return Err(format!("probability `{val}` outside [0,1] in `{clause}`"));
                        }
                        trigger = Some(Trigger::Prob(p));
                    }
                    "seed" => seed = parse_num(val, clause)?,
                    "delay" => delay_ms = Some(parse_num(val, clause)?),
                    "penalty" => {
                        let p: f64 = val
                            .trim()
                            .parse()
                            .map_err(|_| format!("bad penalty `{val}` in `{clause}`"))?;
                        if !(p.is_finite() && p >= 0.0) {
                            return Err(format!("penalty `{val}` must be >= 0 in `{clause}`"));
                        }
                        penalty_s = p;
                    }
                    other => return Err(format!("unknown fault option `{other}` in `{clause}`")),
                }
            }
            let trigger = trigger
                .ok_or_else(|| format!("fault clause `{clause}` needs after=/nth=/prob="))?;
            rules.push(FaultRule { site: site.to_string(), trigger, seed, delay_ms, penalty_s });
        }
        if rules.is_empty() {
            return Err("empty fault plan".to_string());
        }
        Ok(FaultPlan { rules })
    }
}

fn parse_num<T: std::str::FromStr>(val: &str, clause: &str) -> Result<T, String> {
    val.trim().parse().map_err(|_| format!("bad number `{val}` in `{clause}`"))
}

struct Active {
    plan: FaultPlan,
    /// Per-site op counters; ordered triggers count arrival order.
    counters: Mutex<HashMap<String, u64>>,
}

/// Fast-path flag so un-faulted runs pay one relaxed atomic load per site.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ACTIVE: Mutex<Option<Active>> = Mutex::new(None);

/// Install a plan process-wide (replacing any previous one).
pub fn install(plan: FaultPlan) {
    let mut guard = ACTIVE.lock().unwrap();
    *guard = Some(Active { plan, counters: Mutex::new(HashMap::new()) });
    ENABLED.store(true, Ordering::SeqCst);
}

/// Parse + install in one step (the `--fault-plan` / `TT_FAULTS` path).
pub fn install_spec(spec: &str) -> Result<(), String> {
    install(FaultPlan::parse(spec)?);
    Ok(())
}

/// Remove the active plan (tests use this to scope injection).
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    let mut guard = ACTIVE.lock().unwrap();
    *guard = None;
}

/// True if any plan is installed.
pub fn active() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// splitmix64 finalizer: decorrelates (seed, site, index) into a draw.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn u01(z: u64) -> f64 {
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

fn site_hash(site: &str) -> u64 {
    // FNV-1a, same construction as the artifact keys.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in site.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn rule_fires(rule: &FaultRule, site: &str, n: u64) -> bool {
    match rule.trigger {
        Trigger::After(k) => n > k,
        Trigger::Nth(k) => n == k,
        Trigger::Prob(p) => u01(mix64(rule.seed ^ site_hash(site) ^ n)) < p,
    }
}

/// Evaluate fail-action rules for `site`, advancing its op counter.
/// Returns true when this operation should fail. Sites that are not
/// named by the active plan still count ops, so `nth=` schedules stay
/// stable when a plan adds or removes sibling clauses.
pub fn should_fail(site: &str) -> bool {
    if !ENABLED.load(Ordering::Relaxed) {
        return false;
    }
    let guard = ACTIVE.lock().unwrap();
    let Some(active) = guard.as_ref() else { return false };
    let mut counters = active.counters.lock().unwrap();
    let n = counters.entry(site.to_string()).or_insert(0);
    *n += 1;
    let n = *n;
    active
        .plan
        .rules
        .iter()
        .any(|r| r.site == site && r.delay_ms.is_none() && rule_fires(r, site, n))
}

/// Content-keyed failure for `measure.pair`: the draw is derived from the
/// pair's content key (like `pool::noise_seed`), so the same pair is lost
/// regardless of worker scheduling or batch order. Returns the penalty in
/// device-seconds when the measurement should be lost.
pub fn measure_failure(content: u64) -> Option<f64> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let guard = ACTIVE.lock().unwrap();
    let active = guard.as_ref()?;
    for rule in active.plan.rules.iter().filter(|r| r.site == "measure.pair") {
        let fires = match rule.trigger {
            // Ordered triggers fall back to the shared counter path.
            Trigger::After(_) | Trigger::Nth(_) => {
                let mut counters = active.counters.lock().unwrap();
                let n = counters.entry("measure.pair".to_string()).or_insert(0);
                *n += 1;
                rule_fires(rule, "measure.pair", *n)
            }
            Trigger::Prob(p) => u01(mix64(rule.seed ^ content)) < p,
        };
        if fires {
            return Some(rule.penalty_s);
        }
    }
    None
}

/// Sleep if the plan schedules a latency fault for `site` on this op.
/// Used by the RPC handler so overload tests are deterministic.
pub fn sleep_site(site: &str) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let ms = {
        let guard = ACTIVE.lock().unwrap();
        let Some(active) = guard.as_ref() else { return };
        let mut counters = active.counters.lock().unwrap();
        let n = counters.entry(site.to_string()).or_insert(0);
        *n += 1;
        let n = *n;
        active
            .plan
            .rules
            .iter()
            .find(|r| r.site == site && r.delay_ms.is_some() && rule_fires(r, site, n))
            .and_then(|r| r.delay_ms)
    };
    if let Some(ms) = ms {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// The `io::Error` injected sites return, tagged with the site name so
/// logs show the failure was scheduled, not environmental.
pub fn io_error(site: &str) -> std::io::Error {
    std::io::Error::other(format!("injected fault at {site}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_issue_example() {
        let plan =
            FaultPlan::parse("io.write:after=3;rpc.accept:prob=0.05@seed=7;persist.rename:nth=2")
                .unwrap();
        assert_eq!(plan.rules.len(), 3);
        assert_eq!(plan.rules[0].trigger, Trigger::After(3));
        assert_eq!(plan.rules[1].trigger, Trigger::Prob(0.05));
        assert_eq!(plan.rules[1].seed, 7);
        assert_eq!(plan.rules[2].trigger, Trigger::Nth(2));
    }

    #[test]
    fn parses_delay_and_penalty() {
        let spec = "rpc.handler:prob=1,delay=250;measure.pair:prob=0.5,penalty=2.5";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.rules[0].delay_ms, Some(250));
        assert_eq!(plan.rules[1].penalty_s, 2.5);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "io.write",
            "io.write:nth=x",
            "io.write:prob=1.5",
            ":nth=1",
            "io.write:frequency=2",
            "measure.pair:prob=0.1,penalty=-1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn triggers_fire_at_the_documented_indices() {
        let nth = FaultRule {
            site: "s".into(),
            trigger: Trigger::Nth(2),
            seed: 0,
            delay_ms: None,
            penalty_s: 1.0,
        };
        assert!(!rule_fires(&nth, "s", 1));
        assert!(rule_fires(&nth, "s", 2));
        assert!(!rule_fires(&nth, "s", 3));
        let after = FaultRule { trigger: Trigger::After(2), ..nth.clone() };
        assert!(!rule_fires(&after, "s", 2));
        assert!(rule_fires(&after, "s", 3));
        assert!(rule_fires(&after, "s", 100));
    }

    #[test]
    fn prob_draws_are_seeded_and_replayable() {
        let rule = FaultRule {
            site: "s".into(),
            trigger: Trigger::Prob(0.3),
            seed: 42,
            delay_ms: None,
            penalty_s: 1.0,
        };
        let a: Vec<bool> = (1..200).map(|n| rule_fires(&rule, "s", n)).collect();
        let b: Vec<bool> = (1..200).map(|n| rule_fires(&rule, "s", n)).collect();
        assert_eq!(a, b, "same seed, same schedule");
        let fired = a.iter().filter(|x| **x).count();
        assert!((20..100).contains(&fired), "p=0.3 over 199 draws fired {fired}");
        let other = FaultRule { seed: 43, ..rule };
        let c: Vec<bool> = (1..200).map(|n| rule_fires(&other, "s", n)).collect();
        assert_ne!(a, c, "different seeds draw different schedules");
    }

    // NOTE: install()/clear() are process-global, and the lib unit-test
    // binary runs tests in parallel threads — so no lib test installs a
    // plan. The install paths (and the injected artifact/pool/reactor
    // behavior) are exercised in `rust/tests/crashsafety.rs`, which owns
    // its own process and serializes plan changes behind a mutex.
    #[test]
    fn content_keyed_draw_is_position_independent() {
        let p = 0.5;
        let seed = 9u64;
        let draw = |content: u64| u01(mix64(seed ^ content)) < p;
        let a: Vec<bool> = (0..64u64).map(|c| draw(c * 7919)).collect();
        let b: Vec<bool> = (0..64u64).rev().map(|c| draw(c * 7919)).collect();
        assert_eq!(a, b.into_iter().rev().collect::<Vec<_>>(), "depends only on content");
        assert!(a.iter().any(|x| *x) && a.iter().any(|x| !*x));
        assert_eq!(measure_failure(1), None, "no plan installed, nothing injected");
    }
}
