//! Readiness-driven connection reactor: thousands of idle connections
//! on one thread.
//!
//! PR 3–6 served RPC connections from a bounded worker pool — one
//! *thread* per live connection, so concurrency was capped at `--jobs`
//! and a silent client pinned a worker for `READ_STALL_TIMEOUT`. This
//! module inverts that: all sockets are nonblocking and registered with
//! one readiness poller (raw `epoll(7)` FFI on Linux, same
//! no-dependency `extern "C"` discipline as the `signal` shim in
//! `main.rs`; a portable busy-poll fallback elsewhere), and a single
//! **event-loop thread** owns every connection:
//!
//! * it accepts (until `max_conns`), reads, and accumulates partial
//!   frames per connection — a slowloris client dripping one byte per
//!   write costs a buffer, not a thread;
//! * complete, decoded frames become jobs on a queue drained by
//!   `jobs`-many **worker threads**, which only ever run the supplied
//!   [`Handler`] on a full payload — they never touch a socket;
//! * the queue is bounded by `max_queue` (0 = unbounded): a request
//!   landing on a full queue is answered at once with the caller's
//!   [`ShedHook`] reply (the v5 `overloaded` frame) instead of waiting,
//!   so an overloaded server degrades to fast typed refusals rather
//!   than unbounded latency;
//! * replies come back to the event loop (over a loopback wakeup
//!   socket) and are written through the connection's outbound buffer,
//!   so a client that stops reading stalls its buffer, not a worker;
//! * idle, read-stall, and write-stall deadlines live in a hashed
//!   [`TimerWheel`] — arming is O(1), and the loop harvests expiries
//!   once per tick.
//!
//! The reactor knows framing (`u32_be` length prefix, a length cap, a
//! UTF-8 requirement) but no JSON: payload semantics live entirely in
//! the [`Handler`], and framing-violation replies are produced by the
//! caller's [`ViolationHook`] so the wire error shapes stay owned by
//! `service::rpc`. Per-connection ordering is strict: replies are
//! written in request order, and a violation's error frame (or a clean
//! close) is sequenced *after* every earlier request's reply via a
//! close sentinel in the connection's work queue.
//!
//! Thread accounting (the bench-enforced invariant): one event loop +
//! `jobs` workers, regardless of connection count — a server under
//! 10 000 idle connections runs `jobs + 1` threads.

use crate::service::timer::{TimerWheel, TICK_MS};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serve one complete request payload, returning the reply payload.
/// Runs on a worker thread; must never panic on hostile input.
pub type Handler = Arc<dyn Fn(&str) -> String + Send + Sync>;

/// Produce the reply payload for a framing violation (sent best-effort
/// before the connection closes). Keeps wire error shapes out of the
/// reactor.
pub type ViolationHook = Arc<dyn Fn(&FrameViolation) -> String + Send + Sync>;

/// Produce the reply payload for a request shed by the `max_queue`
/// bound (given the observed queue depth). Like [`ViolationHook`], this
/// keeps the wire error shape (`overloaded` + `retry_after_ms`) owned
/// by `service::rpc`; the reactor only knows that a shed request gets a
/// typed reply instead of a queue slot.
pub type ShedHook = Arc<dyn Fn(usize) -> String + Send + Sync>;

/// A framing-layer violation, reported to the [`ViolationHook`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FrameViolation {
    /// Declared payload length exceeds the configured cap.
    Oversized(u32),
    /// Stream ended inside a header or payload.
    Truncated,
    /// Payload bytes are not UTF-8.
    Utf8,
}

/// Reactor tuning knobs. The caller resolves every default (the
/// reactor imposes none), so `service::rpc` remains the single owner
/// of wire-facing constants.
#[derive(Clone, Debug)]
pub struct ReactorConfig {
    /// Worker threads executing the [`Handler`]; 0 means the global
    /// `--jobs` knob via [`effective_jobs`](crate::coordinator::effective_jobs).
    pub jobs: usize,
    /// Live-connection cap: at the cap the listener pauses (connections
    /// queue in the kernel backlog) and resumes when a slot frees.
    pub max_conns: usize,
    /// Close a connection with no in-flight work and no partial frame
    /// after this long without a byte.
    pub idle_timeout: Duration,
    /// Close a connection stuck mid-frame (slowloris) after this long
    /// without progress.
    pub read_stall: Duration,
    /// Close a connection whose outbound buffer makes no progress (a
    /// client that stopped reading) after this long.
    pub write_stall: Duration,
    /// Frame payload cap, both directions.
    pub max_frame_len: u32,
    /// Load-shed bound: a decoded request arriving while this many jobs
    /// are already queued (not yet picked up by a worker) is answered
    /// with the [`ShedHook`]'s typed reply instead of queueing — the
    /// connection stays open and healthy. 0 = unbounded (the pre-v5
    /// behavior).
    pub max_queue: usize,
}

/// Live serving gauges, exported for the `stats` admin op: updated by
/// the event loop (connections) and the job queue (queue depth).
#[derive(Debug, Default)]
pub struct ServerGauges {
    /// Connections currently registered with the reactor.
    pub connections: AtomicUsize,
    /// Decoded requests queued for a worker (excludes in-execution).
    pub queue_depth: AtomicUsize,
    /// Connections closed by the idle deadline (no in-flight work, no
    /// partial frame, no byte for `idle_timeout`). Monotonic counters —
    /// drain-path closes are deliberate shutdowns, not evictions, and
    /// are never counted here.
    pub evicted_idle: AtomicUsize,
    /// Connections closed mid-frame by the read-stall deadline
    /// (slowloris).
    pub evicted_read_stall: AtomicUsize,
    /// Connections closed by the write-stall deadline (a client that
    /// stopped reading its replies).
    pub evicted_write_stall: AtomicUsize,
    /// Requests answered with the typed `overloaded` reply because the
    /// job queue was at `max_queue` (monotonic).
    pub shed_total: AtomicUsize,
    /// Files the artifact store's open-time recovery pass quarantined
    /// (crash residue). Set once by the serving process after it opens
    /// its `--cache-dir`; the reactor itself never writes it — it lives
    /// here so the `stats` admin op exports one coherent server block.
    pub quarantined: AtomicUsize,
    /// Requests completed by a worker (monotonic). Together with
    /// [`busy_micros`](ServerGauges::busy_micros) this is the measured
    /// drain rate the adaptive `retry_after_ms` hint (wire v6) divides
    /// the queue depth by.
    pub jobs_done: AtomicUsize,
    /// Total microseconds workers spent executing the [`Handler`]
    /// (monotonic; wall time per job, summed across workers).
    pub busy_micros: AtomicU64,
}

/// Stop reading a connection once this many decoded requests are
/// already queued on it (level-triggered: reads resume as replies
/// drain). Bounds per-connection memory under a blasting client.
const PENDING_PAUSE: usize = 32;
/// Hard parse bound per connection (> [`PENDING_PAUSE`] so one read's
/// residue still parses after the pause engages).
const PENDING_LIMIT: usize = 64;

const TOK_LISTENER: u64 = 0;
const TOK_WAKE: u64 = 1;
const TOK_FIRST_CONN: u64 = 2;

fn dur_ms(d: Duration) -> u64 {
    (d.as_millis() as u64).max(1)
}

#[cfg(unix)]
fn sock_fd<T: std::os::unix::io::AsRawFd>(s: &T) -> i32 {
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn sock_fd<T>(_s: &T) -> i32 {
    -1
}

/// One readiness report from the poller.
#[derive(Clone, Copy, Debug)]
struct Event {
    token: u64,
    readable: bool,
    writable: bool,
    err: bool,
}

/// Linux backend: raw `epoll(7)` via `extern "C"`, no crates. Level-
/// triggered on purpose — combined with per-connection interest flags
/// it needs no readiness bookkeeping beyond what the kernel holds.
#[cfg(target_os = "linux")]
mod sys {
    use super::Event;

    // glibc packs epoll_event on x86_64 only; mirroring that layout is
    // what makes the raw calls ABI-correct on both x86_64 and aarch64.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLLIN: u32 = 0x1;
    const EPOLLOUT: u32 = 0x4;
    const EPOLLERR: u32 = 0x8;
    const EPOLLHUP: u32 = 0x10;

    pub struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> std::io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Poller { epfd, buf: vec![EpollEvent { events: 0, data: 0 }; 256] })
        }

        fn ctl(&mut self, op: i32, fd: i32, token: u64, r: bool, w: bool) -> std::io::Result<()> {
            let events = if r { EPOLLIN } else { 0 } | if w { EPOLLOUT } else { 0 };
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn add(&mut self, fd: i32, token: u64, r: bool, w: bool) -> std::io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, r, w)
        }

        pub fn modify(&mut self, fd: i32, token: u64, r: bool, w: bool) -> std::io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, r, w)
        }

        pub fn remove(&mut self, fd: i32, token: u64) -> std::io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, token, false, false)
        }

        pub fn wait(&mut self, timeout_ms: u64, out: &mut Vec<Event>) {
            let timeout = timeout_ms.min(i32::MAX as u64) as i32;
            let cap = self.buf.len() as i32;
            let n = unsafe { epoll_wait(self.epfd, self.buf.as_mut_ptr(), cap, timeout) };
            if n < 0 {
                let err = std::io::Error::last_os_error();
                if err.kind() != std::io::ErrorKind::Interrupted {
                    // Persistent failure: pace the loop instead of
                    // spinning hot on a broken epoll fd.
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                return;
            }
            for ev in self.buf.iter().take(n as usize).copied() {
                // Copy packed fields by value — never by reference.
                let events = ev.events;
                let data = ev.data;
                out.push(Event {
                    token: data,
                    readable: events & EPOLLIN != 0,
                    writable: events & EPOLLOUT != 0,
                    err: events & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

/// Portable fallback backend: a paced busy-poll that reports every
/// registered interest as ready each tick. Spurious readiness is
/// harmless against nonblocking sockets (reads/writes just return
/// `WouldBlock`); the cost is a ~2 ms poll cadence instead of a true
/// kernel wait — correct everywhere, efficient only on Linux.
#[cfg(not(target_os = "linux"))]
mod sys {
    use super::Event;
    use std::collections::HashMap;

    pub struct Poller {
        interests: HashMap<u64, (bool, bool)>,
    }

    impl Poller {
        pub fn new() -> std::io::Result<Poller> {
            Ok(Poller { interests: HashMap::new() })
        }

        pub fn add(&mut self, _fd: i32, token: u64, r: bool, w: bool) -> std::io::Result<()> {
            self.interests.insert(token, (r, w));
            Ok(())
        }

        pub fn modify(&mut self, _fd: i32, token: u64, r: bool, w: bool) -> std::io::Result<()> {
            self.interests.insert(token, (r, w));
            Ok(())
        }

        pub fn remove(&mut self, _fd: i32, token: u64) -> std::io::Result<()> {
            self.interests.remove(&token);
            Ok(())
        }

        pub fn wait(&mut self, timeout_ms: u64, out: &mut Vec<Event>) {
            std::thread::sleep(std::time::Duration::from_millis(timeout_ms.clamp(1, 2)));
            for (&token, &(r, w)) in &self.interests {
                if r || w {
                    out.push(Event { token, readable: r, writable: w, err: false });
                }
            }
        }
    }
}

use sys::Poller;

/// Per-connection work item. `Close` is a *sentinel*: it sequences the
/// end of a connection (optionally with a final error frame) after
/// every earlier request's reply, preserving the pool server's strict
/// reply-then-error ordering under asynchronous workers.
enum Work {
    Request(String),
    Close(Option<String>),
}

/// Which deadline a connection is currently under. `Busy` = none (work
/// is in flight; progress is the worker's to make).
#[derive(Clone, Copy, Debug, PartialEq)]
enum DeadKind {
    Idle,
    ReadStall,
    WriteStall,
    Busy,
}

struct Conn {
    stream: TcpStream,
    /// Accumulated unparsed inbound bytes (at most one partial frame
    /// plus parse-paused residue).
    buf_in: Vec<u8>,
    /// Encoded outbound frames not yet accepted by the kernel.
    buf_out: Vec<u8>,
    /// Flushed prefix of `buf_out` (compacted on full flush).
    out_pos: usize,
    /// Decoded requests (and at most one trailing close sentinel)
    /// awaiting dispatch, in arrival order.
    pending: VecDeque<Work>,
    /// One request is with a worker; replies stay ordered because a
    /// connection never has two.
    in_flight: bool,
    /// No further bytes will be read (EOF, violation, or drain).
    read_closed: bool,
    /// Close once `buf_out` is fully flushed.
    closing: bool,
    /// Currently registered poller interest (avoids redundant `ctl`s).
    int_r: bool,
    int_w: bool,
    deadline: Option<u64>,
    kind: DeadKind,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            buf_in: Vec::new(),
            buf_out: Vec::new(),
            out_pos: 0,
            pending: VecDeque::new(),
            in_flight: false,
            read_closed: false,
            closing: false,
            int_r: true,
            int_w: false,
            deadline: None,
            kind: DeadKind::Idle,
        }
    }

    fn has_unflushed(&self) -> bool {
        self.out_pos < self.buf_out.len()
    }

    fn unflushed_len(&self) -> usize {
        self.buf_out.len() - self.out_pos
    }
}

/// Append one framed payload to an outbound buffer. `false` when the
/// payload exceeds the frame cap (caller closes, mirroring the pool
/// server's `encode_frame` failure path).
fn append_frame(buf: &mut Vec<u8>, payload: &str, max_frame_len: u32) -> bool {
    if payload.len() as u64 > max_frame_len as u64 {
        return false;
    }
    buf.reserve(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload.as_bytes());
    true
}

/// Flush as much of `buf_out` as the kernel will take. `Ok(bytes)` on
/// progress-or-block, `Err(())` on a dead peer.
fn flush_conn(conn: &mut Conn) -> Result<usize, ()> {
    // Injected write fault: the reply is lost mid-flush and the
    // connection is treated as dead, like a peer that closed on us.
    if conn.has_unflushed() && crate::faults::should_fail("rpc.write") {
        return Err(());
    }
    let mut wrote = 0usize;
    loop {
        if conn.out_pos >= conn.buf_out.len() {
            conn.buf_out.clear();
            conn.out_pos = 0;
            break;
        }
        match (&conn.stream).write(&conn.buf_out[conn.out_pos..]) {
            Ok(0) => return Err(()),
            Ok(n) => {
                conn.out_pos += n;
                wrote += n;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return Err(()),
        }
    }
    // Compact a large flushed prefix so a long-lived slow reader does
    // not pin the high-water mark forever.
    if conn.out_pos >= 64 * 1024 && conn.has_unflushed() {
        conn.buf_out.drain(..conn.out_pos);
        conn.out_pos = 0;
    }
    Ok(wrote)
}

struct JobState {
    queue: VecDeque<(u64, String)>,
    closed: bool,
}

/// Complete-requests queue between the event loop and the workers.
struct JobQueue {
    state: Mutex<JobState>,
    ready: Condvar,
}

impl JobQueue {
    fn new() -> JobQueue {
        JobQueue {
            state: Mutex::new(JobState { queue: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
        }
    }

    fn close(&self) {
        self.state.lock().expect("job queue").closed = true;
        self.ready.notify_all();
    }
}

/// State shared between the public handle, the workers, and the event
/// loop.
struct Shared {
    stop: AtomicBool,
    gauges: Arc<ServerGauges>,
    /// Write end of the loopback wakeup channel (nonblocking; one byte
    /// per nudge, coalesced by the event loop's drain).
    wake_tx: TcpStream,
    /// Completed (connection token, reply payload) pairs awaiting the
    /// event loop.
    done: Mutex<Vec<(u64, String)>>,
}

impl Shared {
    fn wake(&self) {
        // `WouldBlock` means bytes are already pending — the loop will
        // wake regardless, so every error here is ignorable.
        let _ = (&self.wake_tx).write(&[1u8]);
    }
}

/// A loopback socket pair standing in for `pipe(2)`: std-only, works
/// under both poller backends. The accept is verified against the
/// connector's local address so a stray connect to the ephemeral
/// listener cannot become our wakeup channel.
fn wake_pair() -> anyhow::Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind((std::net::Ipv4Addr::LOCALHOST, 0))
        .map_err(|e| anyhow::anyhow!("binding wakeup listener: {e}"))?;
    let addr = listener.local_addr()?;
    let tx = TcpStream::connect(addr).map_err(|e| anyhow::anyhow!("wakeup connect: {e}"))?;
    let local = tx.local_addr()?;
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let (rx, peer) = listener.accept().map_err(|e| anyhow::anyhow!("wakeup accept: {e}"))?;
        if peer == local {
            tx.set_nonblocking(true)?;
            rx.set_nonblocking(true)?;
            return Ok((tx, rx));
        }
        if Instant::now() > deadline {
            anyhow::bail!("wakeup channel: could not pair loopback sockets");
        }
        // A stray connection raced our pair: drop it and re-accept.
        drop(rx);
    }
}

fn worker_loop(shared: &Shared, jobs: &JobQueue, handler: &Handler) {
    loop {
        let job = {
            let mut st = jobs.state.lock().expect("job queue");
            loop {
                if let Some(j) = st.queue.pop_front() {
                    shared.gauges.queue_depth.store(st.queue.len(), Ordering::Relaxed);
                    break Some(j);
                }
                if st.closed {
                    break None;
                }
                st = jobs.ready.wait(st).expect("job queue");
            }
        };
        let Some((token, payload)) = job else { return };
        let started = Instant::now();
        let reply = handler(&payload);
        // Drain-rate gauges (wire v6): the adaptive retry hint reads
        // these to estimate how long the current queue takes to clear.
        shared
            .gauges
            .busy_micros
            .fetch_add(started.elapsed().as_micros() as u64, Ordering::Relaxed);
        shared.gauges.jobs_done.fetch_add(1, Ordering::Relaxed);
        shared.done.lock().expect("done list").push((token, reply));
        shared.wake();
    }
}

/// The readiness-driven server core. Public API mirrors what
/// [`RpcServer`](crate::service::rpc::RpcServer) needs: start, address,
/// gauges, graceful shutdown.
pub struct Reactor {
    addr: SocketAddr,
    shared: Arc<Shared>,
    evloop: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Reactor {
    /// Bind `bind` and start the event loop plus worker threads.
    pub fn start(
        bind: &str,
        handler: Handler,
        violation: ViolationHook,
        shed: ShedHook,
        cfg: ReactorConfig,
        gauges: Arc<ServerGauges>,
    ) -> anyhow::Result<Reactor> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| anyhow::anyhow!("binding RPC listener on {bind}: {e}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let (wake_tx, wake_rx) = wake_pair()?;
        let mut poller = Poller::new().map_err(|e| anyhow::anyhow!("creating poller: {e}"))?;
        poller
            .add(sock_fd(&listener), TOK_LISTENER, true, false)
            .map_err(|e| anyhow::anyhow!("registering listener: {e}"))?;
        poller
            .add(sock_fd(&wake_rx), TOK_WAKE, true, false)
            .map_err(|e| anyhow::anyhow!("registering wakeup socket: {e}"))?;
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            gauges,
            wake_tx,
            done: Mutex::new(Vec::new()),
        });
        let jobs = Arc::new(JobQueue::new());
        let n_workers =
            if cfg.jobs == 0 { crate::coordinator::effective_jobs(0) } else { cfg.jobs };
        let mut workers = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let w_shared = shared.clone();
            let w_jobs = jobs.clone();
            let w_handler = handler.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("tt-rpc-{wi}"))
                .spawn(move || worker_loop(&w_shared, &w_jobs, &w_handler));
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    jobs.close();
                    for worker in workers {
                        let _ = worker.join();
                    }
                    return Err(anyhow::anyhow!("spawning RPC worker {wi}: {e}"));
                }
            }
        }
        let ev = EvLoop {
            listener: Some(listener),
            poller,
            wake_rx,
            conns: HashMap::new(),
            wheel: TimerWheel::new(),
            t0: Instant::now(),
            next_token: TOK_FIRST_CONN,
            shared: shared.clone(),
            jobs: jobs.clone(),
            cfg,
            violation,
            shed,
            live_jobs: 0,
            draining: false,
            listener_paused: false,
        };
        let spawned =
            std::thread::Builder::new().name("tt-rpc-evloop".to_string()).spawn(move || ev.run());
        let evloop = match spawned {
            Ok(handle) => handle,
            Err(e) => {
                jobs.close();
                for worker in workers {
                    let _ = worker.join();
                }
                return Err(anyhow::anyhow!("spawning RPC event loop: {e}"));
            }
        };
        Ok(Reactor { addr, shared, evloop: Some(evloop), workers })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The live serving gauges (shared with whoever answers `stats`).
    pub fn gauges(&self) -> Arc<ServerGauges> {
        self.shared.gauges.clone()
    }

    /// Graceful shutdown: stop accepting, discard unread/undecoded
    /// input, flush every in-flight reply (bounded by the write-stall
    /// deadline), then join all threads.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake();
        if let Some(handle) = self.evloop.take() {
            let _ = handle.join();
        }
        // The event loop closes the job queue on exit, so the worker
        // joins below always terminate.
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        if self.evloop.is_some() {
            self.stop_and_join();
        }
    }
}

/// The event-loop state, owned by its thread.
struct EvLoop {
    listener: Option<TcpListener>,
    poller: Poller,
    wake_rx: TcpStream,
    conns: HashMap<u64, Conn>,
    wheel: TimerWheel,
    t0: Instant,
    next_token: u64,
    shared: Arc<Shared>,
    jobs: Arc<JobQueue>,
    cfg: ReactorConfig,
    violation: ViolationHook,
    shed: ShedHook,
    /// Jobs submitted but not yet drained from `done` (drain exit gate).
    live_jobs: usize,
    draining: bool,
    listener_paused: bool,
}

impl EvLoop {
    fn now_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }

    fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        let mut fired: Vec<u64> = Vec::new();
        loop {
            if !self.draining && self.shared.stop.load(Ordering::SeqCst) {
                self.begin_drain();
            }
            if self.draining && self.conns.is_empty() && self.live_jobs == 0 {
                break;
            }
            // Idle server: nothing is deadline-bound, sleep long. Any
            // live connection: wake at timer granularity so deadlines
            // fire on time.
            let timeout = if self.conns.is_empty() && !self.draining { 500 } else { TICK_MS };
            events.clear();
            self.poller.wait(timeout, &mut events);
            for ev in &events {
                match ev.token {
                    TOK_LISTENER => self.on_accept(),
                    TOK_WAKE => self.drain_wake(),
                    tok => self.on_conn_event(tok, *ev),
                }
            }
            self.drain_done();
            let now = self.now_ms();
            fired.clear();
            self.wheel.advance(now, &mut fired);
            for &tok in &fired {
                // Lazy cancellation: the wheel may report stale or
                // re-armed entries; the connection's own deadline is
                // authoritative.
                let due = self.conns.get(&tok).map(|c| (c.deadline, c.kind));
                if let Some((Some(d), kind)) = due {
                    if d <= now {
                        // Deadlines close silently: a timed-out
                        // connection is a clean end, no error frame
                        // (same contract as the pool server's
                        // read/write timeouts). Count the eviction by
                        // the deadline kind that fired (`Busy`
                        // connections carry no deadline, so only the
                        // three timeout kinds can land here).
                        let counter = match kind {
                            DeadKind::Idle => &self.shared.gauges.evicted_idle,
                            DeadKind::ReadStall => &self.shared.gauges.evicted_read_stall,
                            DeadKind::WriteStall => &self.shared.gauges.evicted_write_stall,
                            DeadKind::Busy => unreachable!("Busy connections have no deadline"),
                        };
                        counter.fetch_add(1, Ordering::Relaxed);
                        self.close_conn(tok);
                    }
                }
            }
        }
        self.jobs.close();
    }

    fn on_accept(&mut self) {
        loop {
            if self.draining || self.listener_paused {
                return;
            }
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    // Injected accept fault: the connection is dropped
                    // before registration, exactly like a peer that
                    // vanished between accept(2) and first byte.
                    if crate::faults::should_fail("rpc.accept") {
                        continue;
                    }
                    let tok = self.next_token;
                    self.next_token += 1;
                    let fd = sock_fd(&stream);
                    if self.poller.add(fd, tok, true, false).is_err() {
                        // Refuse (close by drop) rather than hold a
                        // connection the loop cannot observe.
                        continue;
                    }
                    let now = self.now_ms();
                    let mut conn = Conn::new(stream);
                    conn.deadline = Some(now + dur_ms(self.cfg.idle_timeout));
                    conn.kind = DeadKind::Idle;
                    self.wheel.schedule(tok, now + dur_ms(self.cfg.idle_timeout));
                    self.conns.insert(tok, conn);
                    self.shared.gauges.connections.store(self.conns.len(), Ordering::Relaxed);
                    if self.conns.len() >= self.cfg.max_conns {
                        self.set_listener_interest(false);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn set_listener_interest(&mut self, on: bool) {
        if let Some(listener) = &self.listener {
            let fd = sock_fd(listener);
            let _ = self.poller.modify(fd, TOK_LISTENER, on, false);
        }
        self.listener_paused = !on;
    }

    fn drain_wake(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => continue,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn on_conn_event(&mut self, tok: u64, ev: Event) {
        if !self.conns.contains_key(&tok) {
            return;
        }
        let mut progress = false;
        if ev.readable {
            progress = self.on_readable(tok);
        }
        if ev.err && !ev.readable {
            // Error/hangup with nothing left to read: the peer is gone.
            self.close_conn(tok);
            return;
        }
        if (ev.readable || ev.writable) && self.conns.contains_key(&tok) {
            self.advance_conn(tok, progress);
        }
    }

    /// Read what the kernel has (bounded per event), parse complete
    /// frames into the work queue. Returns whether any bytes arrived.
    fn on_readable(&mut self, tok: u64) -> bool {
        let mut progress = false;
        // None = still open; Some(true) = EOF; Some(false) = I/O error
        // (both end reads; only a mid-frame EOF earns an error frame).
        let mut end: Option<bool> = None;
        // Injected read fault: surfaces as an I/O error on the stream
        // (connection torn away mid-read) — ends reads, closes cleanly.
        if crate::faults::should_fail("rpc.read") {
            self.mark_read_end(tok, false);
            return true;
        }
        {
            let Some(conn) = self.conns.get_mut(&tok) else { return false };
            let mut chunk = [0u8; 16 * 1024];
            let mut rounds = 0;
            loop {
                if !conn.read_closed && conn.pending.len() >= PENDING_LIMIT {
                    break;
                }
                if rounds >= 8 {
                    break;
                }
                rounds += 1;
                match (&conn.stream).read(&mut chunk) {
                    Ok(0) => {
                        end = Some(true);
                        break;
                    }
                    Ok(n) => {
                        progress = true;
                        // After a violation/drain the stream is dead to
                        // us: drain-and-discard so level-triggered
                        // readiness cannot spin.
                        if !conn.read_closed {
                            conn.buf_in.extend_from_slice(&chunk[..n]);
                        }
                        if n < chunk.len() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        end = Some(false);
                        break;
                    }
                }
            }
        }
        self.parse_frames(tok);
        if let Some(eof) = end {
            self.mark_read_end(tok, eof);
            progress = true;
        }
        progress
    }

    /// Split `buf_in` into complete frames. A framing violation queues
    /// a close sentinel (with the hook's error payload) and stops
    /// reading — the stream cannot be resynchronized.
    fn parse_frames(&mut self, tok: u64) {
        let max_frame_len = self.cfg.max_frame_len;
        let violation = self.violation.clone();
        let draining = self.draining;
        let Some(conn) = self.conns.get_mut(&tok) else { return };
        loop {
            if conn.read_closed || conn.pending.len() >= PENDING_LIMIT {
                break;
            }
            if conn.buf_in.len() < 4 {
                break;
            }
            let len =
                u32::from_be_bytes([conn.buf_in[0], conn.buf_in[1], conn.buf_in[2], conn.buf_in[3]]);
            if len > max_frame_len {
                let err = if draining {
                    None
                } else {
                    Some(violation(&FrameViolation::Oversized(len)))
                };
                conn.pending.push_back(Work::Close(err));
                conn.read_closed = true;
                conn.buf_in.clear();
                break;
            }
            let total = 4 + len as usize;
            if conn.buf_in.len() < total {
                break;
            }
            match std::str::from_utf8(&conn.buf_in[4..total]) {
                Ok(payload) => {
                    conn.pending.push_back(Work::Request(payload.to_string()));
                    conn.buf_in.drain(..total);
                }
                Err(_) => {
                    let err =
                        if draining { None } else { Some(violation(&FrameViolation::Utf8)) };
                    conn.pending.push_back(Work::Close(err));
                    conn.read_closed = true;
                    conn.buf_in.clear();
                    break;
                }
            }
        }
    }

    /// Reads are over (EOF or I/O error). A mid-frame EOF is a
    /// truncation violation; anything else is a clean end. Either way
    /// a close sentinel sequences the end after every queued request.
    fn mark_read_end(&mut self, tok: u64, eof: bool) {
        let violation = self.violation.clone();
        let draining = self.draining;
        let Some(conn) = self.conns.get_mut(&tok) else { return };
        if conn.read_closed {
            return;
        }
        conn.read_closed = true;
        let err = if eof && !conn.buf_in.is_empty() && !draining {
            Some(violation(&FrameViolation::Truncated))
        } else {
            None
        };
        conn.buf_in.clear();
        conn.pending.push_back(Work::Close(err));
    }

    /// Dispatch the connection's next work item (one request in flight
    /// at a time), then flush, re-deadline, and re-register interest.
    /// Requests arriving while the job queue sits at `max_queue` are
    /// **shed**: answered immediately with the [`ShedHook`]'s typed
    /// reply (in request order, like any other reply) and never
    /// queued — the connection stays open, so a well-behaved client
    /// backs off and retries instead of reconnecting.
    fn advance_conn(&mut self, tok: u64, progress: bool) {
        enum Next {
            Submit(String),
            Shed,
            Done,
        }
        let mut progress = progress;
        loop {
            // Queue depth is sampled per dispatch, outside the conns
            // borrow; workers draining concurrently only make the
            // sample conservative (we shed at the observed depth).
            let depth = self.jobs.state.lock().expect("job queue").queue.len();
            let queue_full = self.cfg.max_queue != 0 && depth >= self.cfg.max_queue;
            let next = {
                let Some(conn) = self.conns.get_mut(&tok) else { return };
                if conn.in_flight || conn.closing {
                    Next::Done
                } else {
                    match conn.pending.pop_front() {
                        None => Next::Done,
                        Some(Work::Request(payload)) => {
                            if queue_full {
                                Next::Shed
                            } else {
                                conn.in_flight = true;
                                Next::Submit(payload)
                            }
                        }
                        Some(Work::Close(err)) => {
                            if let Some(payload) = err {
                                // Best-effort error frame before close;
                                // an over-cap payload just closes.
                                let _ = append_frame(
                                    &mut conn.buf_out,
                                    &payload,
                                    self.cfg.max_frame_len,
                                );
                            }
                            conn.closing = true;
                            progress = true;
                            Next::Done
                        }
                    }
                }
            };
            match next {
                Next::Submit(payload) => {
                    self.submit(tok, payload);
                    progress = true;
                    break;
                }
                Next::Shed => {
                    let payload = (self.shed)(depth);
                    self.shared.gauges.shed_total.fetch_add(1, Ordering::Relaxed);
                    let Some(conn) = self.conns.get_mut(&tok) else { return };
                    if !append_frame(&mut conn.buf_out, &payload, self.cfg.max_frame_len) {
                        conn.closing = true;
                    }
                    progress = true;
                    // Keep draining: later pending requests shed too
                    // (or submit, if a worker freed a slot meanwhile).
                }
                Next::Done => break,
            }
        }
        self.finish_conn_io(tok, progress);
    }

    fn submit(&mut self, tok: u64, payload: String) {
        self.live_jobs += 1;
        let mut st = self.jobs.state.lock().expect("job queue");
        st.queue.push_back((tok, payload));
        self.shared.gauges.queue_depth.store(st.queue.len(), Ordering::Relaxed);
        drop(st);
        self.jobs.ready.notify_one();
    }

    /// Flush, close-if-drained, recompute the deadline, and update
    /// poller interest for one connection.
    fn finish_conn_io(&mut self, tok: u64, progress: bool) {
        let now = self.now_ms();
        let max_out = self.cfg.max_frame_len as usize;
        let idle = dur_ms(self.cfg.idle_timeout);
        let read_stall = dur_ms(self.cfg.read_stall);
        let write_stall = dur_ms(self.cfg.write_stall);
        let mut remove = false;
        let mut schedule: Option<u64> = None;
        let mut modify: Option<(i32, bool, bool)> = None;
        {
            let Some(conn) = self.conns.get_mut(&tok) else { return };
            let mut progress = progress;
            match flush_conn(conn) {
                Err(()) => remove = true,
                Ok(wrote) => {
                    progress = progress || wrote > 0;
                    if conn.closing && !conn.has_unflushed() {
                        remove = true;
                    } else {
                        let kind = if conn.has_unflushed() {
                            DeadKind::WriteStall
                        } else if conn.in_flight || !conn.pending.is_empty() {
                            DeadKind::Busy
                        } else if !conn.buf_in.is_empty() {
                            DeadKind::ReadStall
                        } else {
                            DeadKind::Idle
                        };
                        // Refresh the deadline only on a kind change or
                        // real progress: a spurious readiness report
                        // (fallback poller, stray event) must not keep
                        // a stalled connection alive.
                        if kind != conn.kind || progress {
                            conn.kind = kind;
                            conn.deadline = match kind {
                                DeadKind::Busy => None,
                                DeadKind::Idle => Some(now + idle),
                                DeadKind::ReadStall => Some(now + read_stall),
                                DeadKind::WriteStall => Some(now + write_stall),
                            };
                            schedule = conn.deadline;
                        }
                        let want_r = !conn.read_closed
                            && !conn.closing
                            && conn.pending.len() < PENDING_PAUSE
                            && conn.unflushed_len() <= max_out;
                        let want_w = conn.has_unflushed();
                        if want_r != conn.int_r || want_w != conn.int_w {
                            conn.int_r = want_r;
                            conn.int_w = want_w;
                            modify = Some((sock_fd(&conn.stream), want_r, want_w));
                        }
                    }
                }
            }
        }
        if remove {
            self.close_conn(tok);
            return;
        }
        if let Some(due) = schedule {
            self.wheel.schedule(tok, due);
        }
        if let Some((fd, r, w)) = modify {
            let _ = self.poller.modify(fd, tok, r, w);
        }
    }

    /// Hand completed replies back to their connections.
    fn drain_done(&mut self) {
        let done: Vec<(u64, String)> = {
            let mut d = self.shared.done.lock().expect("done list");
            std::mem::take(&mut *d)
        };
        for (tok, reply) in done {
            self.live_jobs -= 1;
            let exists = match self.conns.get_mut(&tok) {
                None => false, // connection died while its job ran
                Some(conn) => {
                    conn.in_flight = false;
                    if !append_frame(&mut conn.buf_out, &reply, self.cfg.max_frame_len) {
                        conn.closing = true;
                    }
                    true
                }
            };
            if exists {
                self.advance_conn(tok, true);
            }
        }
    }

    fn close_conn(&mut self, tok: u64) {
        if let Some(conn) = self.conns.remove(&tok) {
            let fd = sock_fd(&conn.stream);
            let _ = self.poller.remove(fd, tok);
            // Dropping the stream closes the socket.
        }
        self.shared.gauges.connections.store(self.conns.len(), Ordering::Relaxed);
        if self.listener_paused && !self.draining && self.conns.len() < self.cfg.max_conns {
            self.set_listener_interest(true);
        }
    }

    /// Enter drain: stop accepting, drop queued-but-unstarted work
    /// (their connections close unanswered — accepting no new work is
    /// what shutdown means), discard all unread input, and keep only
    /// connections with an in-flight request or unflushed reply bytes,
    /// each bounded by the write-stall deadline.
    fn begin_drain(&mut self) {
        self.draining = true;
        if let Some(listener) = self.listener.take() {
            let _ = self.poller.remove(sock_fd(&listener), TOK_LISTENER);
        }
        let cleared: Vec<u64> = {
            let mut st = self.jobs.state.lock().expect("job queue");
            let toks = st.queue.drain(..).map(|(t, _)| t).collect();
            self.shared.gauges.queue_depth.store(0, Ordering::Relaxed);
            toks
        };
        for tok in cleared {
            self.live_jobs -= 1;
            if let Some(conn) = self.conns.get_mut(&tok) {
                conn.in_flight = false;
            }
            self.close_conn(tok);
        }
        let now = self.now_ms();
        let write_stall = dur_ms(self.cfg.write_stall);
        let toks: Vec<u64> = self.conns.keys().copied().collect();
        for tok in toks {
            let (remove, schedule, modify) = {
                let Some(conn) = self.conns.get_mut(&tok) else { continue };
                conn.buf_in.clear();
                conn.pending.clear();
                conn.read_closed = true;
                if conn.in_flight {
                    // Flush the reply when it lands, then close.
                    conn.pending.push_back(Work::Close(None));
                    conn.kind = DeadKind::Busy;
                    conn.deadline = None;
                    let want_w = conn.has_unflushed();
                    let m = interest_delta(conn, false, want_w);
                    (false, None, m)
                } else if conn.has_unflushed() {
                    conn.closing = true;
                    conn.kind = DeadKind::WriteStall;
                    conn.deadline = Some(now + write_stall);
                    let m = interest_delta(conn, false, true);
                    (false, conn.deadline, m)
                } else {
                    (true, None, None)
                }
            };
            if remove {
                self.close_conn(tok);
                continue;
            }
            if let Some(due) = schedule {
                self.wheel.schedule(tok, due);
            }
            if let Some((fd, r, w)) = modify {
                let _ = self.poller.modify(fd, tok, r, w);
            }
        }
    }
}

/// Compute (and record) an interest change for `conn`, returning the
/// `modify` call to make, if any.
fn interest_delta(conn: &mut Conn, want_r: bool, want_w: bool) -> Option<(i32, bool, bool)> {
    if want_r == conn.int_r && want_w == conn.int_w {
        return None;
    }
    conn.int_r = want_r;
    conn.int_w = want_w;
    Some((sock_fd(&conn.stream), want_r, want_w))
}
