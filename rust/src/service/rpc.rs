//! The RPC front end: length-prefixed JSONL over TCP.
//!
//! `repro serve --listen ADDR` promotes the [`ScheduleService`] from a
//! one-shot request-file loop to a real multi-threaded server. The wire
//! protocol is deliberately minimal and dependency-free:
//!
//! ```text
//! frame    := u32_be(length) payload
//! payload  := one UTF-8 JSON object, length bytes, no trailing newline
//! ```
//!
//! Each request frame holds one session request (same schema as the
//! `--requests` JSONL file: `{"model":..,"device":..,"budget_s":..,
//! "seed":..}`); each response frame holds either
//! `{"ok":true,"reply":{..}}` or `{"ok":false,"error":{"code":..,
//! "message":..}}`. A connection is a session loop: frames are
//! answered in order until the client closes. Malformed *JSON* gets a
//! structured `bad_json` error and the loop continues; malformed
//! *framing* (truncated, oversized, non-UTF-8) gets a best-effort
//! structured error and the connection closes, because resynchronizing
//! a byte stream after a broken length prefix is guesswork. The codec
//! never panics on hostile input — `rust/tests/rpc_codec.rs` proves it.
//!
//! Replies carry the store `epoch` (see [`SessionReply::epoch`]): with
//! a streaming zoo build publishing sources while the server runs, a
//! reply is a pure function of (target, device, budget, seed, epoch).

use super::{ScheduleService, SessionReply, SessionRequest};
use crate::device::DeviceProfile;
use crate::sched::serialize;
use crate::util::json::{self, Json};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Hard cap on one frame's payload, both directions. Replies are a few
/// hundred KiB at worst (one schedule per target kernel); 16 MiB keeps
/// a hostile length prefix from allocating the machine away.
pub const MAX_FRAME_LEN: u32 = 16 * 1024 * 1024;

/// Framing-layer failure. Everything above the byte stream (bad JSON,
/// bad request fields) is reported in-band as an [`RpcError`] instead.
#[derive(Debug)]
pub enum FrameError {
    /// Clean end of stream *between* frames (normal client hang-up).
    Closed,
    /// Stream ended inside a header or payload.
    Truncated,
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversized(u32),
    /// Payload bytes are not UTF-8.
    Utf8,
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "stream ended mid-frame"),
            FrameError::Oversized(n) => {
                write!(f, "frame of {n} bytes exceeds the {MAX_FRAME_LEN}-byte limit")
            }
            FrameError::Utf8 => write!(f, "frame payload is not UTF-8"),
            FrameError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

/// Frame a payload: 4-byte big-endian length, then the bytes.
pub fn encode_frame(payload: &str) -> Result<Vec<u8>, FrameError> {
    if payload.len() as u64 > MAX_FRAME_LEN as u64 {
        return Err(FrameError::Oversized(payload.len() as u32));
    }
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload.as_bytes());
    Ok(buf)
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], on_eof: FrameError) -> Result<(), FrameError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Err(on_eof),
        Err(e) => Err(FrameError::Io(e)),
    }
}

/// Read one frame's payload. Distinguishes a clean close (EOF before
/// any header byte → [`FrameError::Closed`]) from a truncation (EOF
/// anywhere inside a frame). An oversized declared length is rejected
/// *before* any payload allocation.
pub fn read_frame(r: &mut impl Read) -> Result<String, FrameError> {
    let mut header = [0u8; 4];
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(FrameError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    header[0] = first[0];
    read_exact_or(r, &mut header[1..], FrameError::Truncated)?;
    let len = u32::from_be_bytes(header);
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload, FrameError::Truncated)?;
    String::from_utf8(payload).map_err(|_| FrameError::Utf8)
}

/// Server-side defaults for optional request fields (`device`, `seed`),
/// mirroring the `--requests` file mode's CLI-flag defaults.
#[derive(Clone, Debug)]
pub struct RpcDefaults {
    pub device: DeviceProfile,
    pub seed: u64,
}

/// A structured in-band error (`{"ok":false,"error":{..}}`). Codes:
///
/// | code              | meaning                                        |
/// |-------------------|------------------------------------------------|
/// | `bad_json`        | request payload is not valid JSON              |
/// | `bad_request`     | missing/ill-typed request field                |
/// | `unknown_device`  | `device` names no profile (server\|edge)       |
/// | `unknown_model`   | `model` names no servable graph                |
/// | `bad_frame`       | truncated or non-UTF-8 frame (connection ends) |
/// | `oversized_frame` | length prefix above [`MAX_FRAME_LEN`] (ends)   |
/// | `internal`        | session failed for another reason              |
#[derive(Clone, Debug, PartialEq)]
pub struct RpcError {
    pub code: String,
    pub message: String,
}

impl RpcError {
    pub fn new(code: &str, message: impl Into<String>) -> RpcError {
        RpcError { code: code.to_string(), message: message.into() }
    }
}

fn bad_request(message: impl Into<String>) -> RpcError {
    RpcError::new("bad_request", message)
}

/// Parse one request payload into a [`SessionRequest`]. Pure, so the
/// TCP loop and the `--requests` replay mode cannot drift.
pub fn parse_request(line: &str, defaults: &RpcDefaults) -> Result<SessionRequest, RpcError> {
    let j = json::parse(line).map_err(|e| RpcError::new("bad_json", e.to_string()))?;
    let model = match j.get("model") {
        Some(Json::Str(s)) if !s.is_empty() => s.clone(),
        Some(_) => return Err(bad_request("`model` must be a non-empty string")),
        None => return Err(bad_request("missing `model`")),
    };
    let device = match j.get("device") {
        None | Some(Json::Null) => defaults.device.clone(),
        Some(v) => {
            let name = v.as_str().ok_or_else(|| bad_request("`device` must be a string"))?;
            DeviceProfile::by_name(name).ok_or_else(|| {
                RpcError::new("unknown_device", format!("unknown device `{name}` (server|edge)"))
            })?
        }
    };
    let budget_s = match j.get("budget_s") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let b = v
                .as_f64()
                .filter(|b| b.is_finite() && *b >= 0.0)
                .ok_or_else(|| bad_request("`budget_s` must be a finite number >= 0"))?;
            Some(b)
        }
    };
    let seed = match j.get("seed") {
        None | Some(Json::Null) => defaults.seed,
        Some(v) => v
            .as_f64()
            .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= 2f64.powi(53))
            .map(|x| x as u64)
            .ok_or_else(|| bad_request("`seed` must be a non-negative integer (< 2^53)"))?,
    };
    Ok(SessionRequest { model, device, budget_s, seed })
}

/// Encode a successful reply as the full response object.
pub fn response_json(reply: &SessionReply) -> Json {
    let choices = reply.choices.iter().map(|c| {
        Json::obj(vec![
            ("kernel", Json::num(c.kernel as f64)),
            ("class", Json::str(c.class_sig.as_str())),
            (
                "source_model",
                match &c.source_model {
                    Some(s) => Json::str(s.as_str()),
                    None => Json::Null,
                },
            ),
            (
                "source_input_shape",
                Json::arr(c.source_input_shape.iter().map(|&x| Json::num(x as f64))),
            ),
            ("standalone_s", Json::num(c.standalone_s)),
            ("schedule", serialize::to_json(&c.schedule)),
        ])
    });
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "reply",
            Json::obj(vec![
                ("target", Json::str(reply.target.as_str())),
                ("device", Json::str(reply.device)),
                ("seed", Json::num(reply.seed as f64)),
                ("epoch", Json::num(reply.epoch as f64)),
                ("sources", Json::arr(reply.sources.iter().map(|s| Json::str(s.as_str())))),
                ("untuned_model_s", Json::num(reply.untuned_model_s)),
                ("tuned_model_s", Json::num(reply.tuned_model_s)),
                ("predicted_speedup", Json::num(reply.predicted_speedup())),
                ("standalone_search_time_s", Json::num(reply.standalone_search_time_s)),
                ("charged_search_time_s", Json::num(reply.charged_search_time_s)),
                ("choices", Json::arr(choices)),
            ]),
        ),
    ])
}

/// Encode a structured error as the full response object.
pub fn error_json(err: &RpcError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::str(err.code.as_str())),
                ("message", Json::str(err.message.as_str())),
            ]),
        ),
    ])
}

/// A decoded response payload (client side).
#[derive(Debug)]
pub enum RpcResponse {
    /// The `reply` object of an `{"ok":true}` response.
    Reply(Json),
    Error(RpcError),
}

/// Decode a response payload (the client half of the codec).
pub fn parse_response(line: &str) -> anyhow::Result<RpcResponse> {
    let j = json::parse(line)?;
    match j.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => Ok(RpcResponse::Reply(j.req("reply")?.clone())),
        Some(false) => {
            let e = j.req("error")?;
            Ok(RpcResponse::Error(RpcError {
                code: e.req("code")?.as_str().unwrap_or_default().to_string(),
                message: e.req("message")?.as_str().unwrap_or_default().to_string(),
            }))
        }
        None => anyhow::bail!("response missing boolean `ok`"),
    }
}

/// Serve one request payload end to end: parse, open the session,
/// encode. Never fails — every failure becomes a structured error
/// response.
pub fn handle_request(service: &ScheduleService, defaults: &RpcDefaults, line: &str) -> Json {
    match parse_request(line, defaults) {
        Err(e) => error_json(&e),
        Ok(req) => match service.open_session(&req) {
            Ok(reply) => response_json(&reply),
            Err(e) => {
                // Classify by re-probing the service, not by sniffing
                // the anyhow message (whose wording is not a contract).
                let code =
                    if service.can_resolve(&req.model) { "internal" } else { "unknown_model" };
                error_json(&RpcError::new(code, e.to_string()))
            }
        },
    }
}

/// Live-connection registry: worker id -> read-half handle, used to
/// unblock readers on shutdown. Entries are removed when their worker
/// exits, so a long-lived server does not leak one fd per connection.
type ConnMap = Arc<Mutex<std::collections::HashMap<u64, TcpStream>>>;

/// The multi-threaded TCP server: an accept loop handing each
/// connection to its own OS thread, all threads sharing one
/// [`ScheduleService`] handle (sessions contend only on the sharded
/// measurement cache). [`RpcServer::shutdown`] stops accepting,
/// unblocks every connection's reader, and joins all workers.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: ConnMap,
    accept: Option<JoinHandle<()>>,
}

impl RpcServer {
    /// Bind `bind` (e.g. `"127.0.0.1:7461"`, port 0 for ephemeral) and
    /// start serving `service` in background threads.
    pub fn start(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
    ) -> anyhow::Result<RpcServer> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| anyhow::anyhow!("binding RPC listener on {bind}: {e}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: ConnMap = Arc::new(Mutex::new(std::collections::HashMap::new()));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::spawn(move || accept_loop(listener, service, defaults, stop, conns))
        };
        Ok(RpcServer { addr, stop, conns, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, close every live connection,
    /// join all threads. Both stream halves are shut down — closing
    /// only the read half would leave a worker stuck in `write_all`
    /// toward a client that stopped reading, and the join below would
    /// never return.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection (the flag
        // is already visible when it wakes). Wildcard binds (0.0.0.0)
        // may not be dialable as-is; fall back to loopback.
        if TcpStream::connect(self.addr).is_err() {
            let _ =
                TcpStream::connect((std::net::Ipv4Addr::LOCALHOST, self.addr.port()));
        }
        for conn in self.conns.lock().expect("conns lock").values() {
            let _ = conn.shutdown(Shutdown::Both);
        }
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_and_join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    service: ScheduleService,
    defaults: RpcDefaults,
    stop: Arc<AtomicBool>,
    conns: ConnMap,
) {
    let mut workers: Vec<JoinHandle<()>> = Vec::new();
    let mut next_id: u64 = 0;
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // Transient accept failure (e.g. fd pressure): back off
                // instead of spinning the accept thread hot.
                std::thread::sleep(std::time::Duration::from_millis(20));
                continue;
            }
        };
        let id = next_id;
        next_id += 1;
        // Register the read-half BEFORE spawning: every worker must be
        // unblockable at shutdown. If the handle cannot be duplicated
        // (fd pressure), refuse the connection rather than spawn a
        // reader that shutdown() could never wake.
        let Ok(handle) = stream.try_clone() else { continue };
        conns.lock().expect("conns lock").insert(id, handle);
        let service = service.clone();
        let defaults = defaults.clone();
        let stop = stop.clone();
        let conns = conns.clone();
        workers.push(std::thread::spawn(move || {
            connection_loop(stream, &service, &defaults, &stop);
            // Drop this connection's registry entry so a long-lived
            // server's fd usage tracks *live* connections only.
            conns.lock().expect("conns lock").remove(&id);
        }));
        // Reap finished workers opportunistically so the handle list
        // does not grow with total connections served.
        workers.retain(|w| !w.is_finished());
    }
    for worker in workers {
        let _ = worker.join();
    }
}

/// One connection's session loop: answer frames in order until the
/// client closes, the framing breaks, or the server shuts down.
fn connection_loop(
    stream: TcpStream,
    service: &ScheduleService,
    defaults: &RpcDefaults,
    stop: &AtomicBool,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = stream;
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match read_frame(&mut reader) {
            Ok(line) => {
                let response = handle_request(service, defaults, &line).to_compact();
                match encode_frame(&response) {
                    Ok(buf) => {
                        if writer.write_all(&buf).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
            Err(FrameError::Closed) | Err(FrameError::Io(_)) => break,
            Err(e) => {
                // Framing violation: best-effort structured error, then
                // close (the stream cannot be resynchronized).
                if !stop.load(Ordering::SeqCst) {
                    let code = match e {
                        FrameError::Oversized(_) => "oversized_frame",
                        _ => "bad_frame",
                    };
                    let response = error_json(&RpcError::new(code, e.to_string())).to_compact();
                    if let Ok(buf) = encode_frame(&response) {
                        let _ = writer.write_all(&buf);
                    }
                }
                break;
            }
        }
    }
    let _ = writer.shutdown(Shutdown::Both);
}
