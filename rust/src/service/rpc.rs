//! The RPC front end: length-prefixed JSONL over TCP.
//!
//! `repro serve --listen ADDR` promotes the [`ScheduleService`] from a
//! one-shot request-file loop to a real multi-threaded server. The wire
//! protocol is deliberately minimal and dependency-free:
//!
//! ```text
//! frame    := u32_be(length) payload
//! payload  := one UTF-8 JSON object, length bytes, no trailing newline
//! ```
//!
//! Each request frame holds one JSON object. A frame without an `op`
//! field is a session request (same schema as the `--requests` JSONL
//! file: `{"model":..,"device":..,"budget_s":..,"seed":..}`); a frame
//! with an `op` field is an **admin request** — `{"op":"stats"}`,
//! `{"op":"shutdown"}`, or `{"op":"republish","model":..}` — handled by
//! the server's [`AdminHook`] (the serve loop wires shutdown/republish
//! to its control thread; a bare [`RpcServer`] answers `stats` and
//! rejects the rest with `admin_unavailable`). Each response frame
//! holds either `{"ok":true,..}` or `{"ok":false,"error":{"code":..,
//! "message":..}}`. A connection is a session loop: frames are
//! answered in order until the client closes. Malformed *JSON* gets a
//! structured `bad_json` error and the loop continues; malformed
//! *framing* (truncated, oversized, non-UTF-8) gets a best-effort
//! structured error and the connection closes, because resynchronizing
//! a byte stream after a broken length prefix is guesswork. The codec
//! never panics on hostile input — `rust/tests/rpc_codec.rs` proves it.
//!
//! Replies carry the store `epoch` (see [`SessionReply::epoch`]): with
//! a streaming zoo build publishing sources while the server runs, a
//! reply is a pure function of (target, device, budget, seed, epoch).
//!
//! **Concurrency model.** Connections are owned by a readiness-driven
//! reactor (see [`crate::service::reactor`]): one event-loop thread
//! holds every socket nonblocking behind an epoll instance, reads and
//! accumulates partial frames, and enforces idle/read-stall/write-stall
//! deadlines from a timer wheel. Only *complete decoded* requests reach
//! the worker pool sized by the global `--jobs`/`TT_JOBS` knob (the
//! same knob as every other host fan-out — see `coordinator::jobs`),
//! so a connection costs a thread only while one of its requests is
//! executing: thousands of idle sessions cost buffers, not threads,
//! and a hung or hostile client cannot pin a worker. Per-connection
//! semantics are unchanged from the pool server — frames are answered
//! strictly in order, one request of a connection in flight at a time.

use super::reactor::{self, FrameViolation, Reactor, ReactorConfig, ShedHook};
use super::{ScheduleService, SessionReply, SessionRequest};
use crate::coordinator::CacheStats;
use crate::device::DeviceProfile;
use crate::report::ZooBuildStats;
use crate::sched::serialize;
use crate::util::json::{self, Json};
use std::io::Read;
use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

pub use super::reactor::ServerGauges;

/// Hard cap on one frame's payload, both directions. Replies are a few
/// hundred KiB at worst (one schedule per target kernel); 16 MiB keeps
/// a hostile length prefix from allocating the machine away.
pub const MAX_FRAME_LEN: u32 = 16 * 1024 * 1024;

/// Version of the wire schema: the frame format plus the request,
/// response, and admin JSON shapes. v1 = session requests only (PR 3);
/// v2 = admin ops (`stats` / `shutdown` / `republish`); v3 = the
/// `stats` reply gains `source_records` + `server` gauges and
/// `republish` accepts `"all":true`; v4 = the `server` block gains
/// per-kind eviction counters (`evicted_idle` / `evicted_read_stall` /
/// `evicted_write_stall`); v5 = load shedding — the `overloaded`
/// error code (carrying a `retry_after_ms` hint inside the `error`
/// object) answers requests landing on a full worker queue
/// (`--max-queue`), and the `server` block gains `shed_total` and
/// `quarantined`; v6 = fleet serving — a `repro fleet` router's
/// `stats` reply carries a `fleet` block (ring placement + per-
/// instance routing/health gauges, see
/// [`fleet_stats_json`](super::fleet::fleet_stats_json)), the
/// `fleet_unavailable` error code answers a session whose every
/// replica is down, and a live server's `retry_after_ms` hint is
/// adaptive — derived from the measured worker drain rate, never
/// below the fixed [`OVERLOADED_RETRY_AFTER_MS`] floor (see
/// [`adaptive_retry_after_ms`]). Bump this with **any** protocol
/// change, and update README §Wire protocol,
/// `rust/tests/rpc_codec.rs`, and `rust/tests/integration_rpc.rs` in
/// the same commit — CI's `format-drift` job fails a change to this
/// file that does not touch all three together.
pub const WIRE_PROTOCOL_VERSION: u64 = 6;

/// How long a connection's outbound buffer may make no progress (a
/// client that stopped reading its replies) before the connection is
/// declared dead. Bounds the drain phase of a shutdown: every
/// unflushed reply either reaches its client or its connection is
/// evicted within this window, so teardown always terminates.
pub const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Default for two reactor deadlines: how long a connection may sit
/// **idle** (no request frame arriving; `--idle-timeout` overrides)
/// and how long it may sit **mid-frame** without a byte of progress (a
/// slowloris drip). Under the pool server either case pinned a worker
/// for this long; under the reactor it only holds a buffer — the
/// deadline now bounds resource tenure, not worker starvation. A
/// timed-out connection is treated as a clean end: the stream closes
/// with no error frame, and the client is free to reconnect.
pub const READ_STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Default cap on simultaneously-registered connections
/// (`--max-conns` overrides). At the cap the listener pauses — further
/// connects wait in the kernel backlog until a slot frees — so fd
/// exhaustion degrades into queueing, never into accept-loop errors.
pub const DEFAULT_MAX_CONNS: usize = 16384;

/// Framing-layer failure. Everything above the byte stream (bad JSON,
/// bad request fields) is reported in-band as an [`RpcError`] instead.
#[derive(Debug)]
pub enum FrameError {
    /// Clean end of stream *between* frames (normal client hang-up).
    Closed,
    /// Stream ended inside a header or payload.
    Truncated,
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversized(u32),
    /// Payload bytes are not UTF-8.
    Utf8,
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "stream ended mid-frame"),
            FrameError::Oversized(n) => {
                write!(f, "frame of {n} bytes exceeds the {MAX_FRAME_LEN}-byte limit")
            }
            FrameError::Utf8 => write!(f, "frame payload is not UTF-8"),
            FrameError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

/// Frame a payload: 4-byte big-endian length, then the bytes.
pub fn encode_frame(payload: &str) -> Result<Vec<u8>, FrameError> {
    if payload.len() as u64 > MAX_FRAME_LEN as u64 {
        return Err(FrameError::Oversized(payload.len() as u32));
    }
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload.as_bytes());
    Ok(buf)
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], on_eof: FrameError) -> Result<(), FrameError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Err(on_eof),
        Err(e) => Err(FrameError::Io(e)),
    }
}

/// Read one frame's payload. Distinguishes a clean close (EOF before
/// any header byte → [`FrameError::Closed`]) from a truncation (EOF
/// anywhere inside a frame). An oversized declared length is rejected
/// *before* any payload allocation.
pub fn read_frame(r: &mut impl Read) -> Result<String, FrameError> {
    let mut header = [0u8; 4];
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(FrameError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    header[0] = first[0];
    read_exact_or(r, &mut header[1..], FrameError::Truncated)?;
    let len = u32::from_be_bytes(header);
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload, FrameError::Truncated)?;
    String::from_utf8(payload).map_err(|_| FrameError::Utf8)
}

/// Server-side defaults for optional request fields (`device`, `seed`),
/// mirroring the `--requests` file mode's CLI-flag defaults.
#[derive(Clone, Debug)]
pub struct RpcDefaults {
    pub device: DeviceProfile,
    pub seed: u64,
}

/// A structured in-band error (`{"ok":false,"error":{..}}`). Codes:
///
/// | code                | meaning                                        |
/// |---------------------|------------------------------------------------|
/// | `bad_json`          | request payload is not valid JSON              |
/// | `bad_request`       | missing/ill-typed request field                |
/// | `unknown_device`    | `device` names no profile (server\|edge)       |
/// | `unknown_model`     | `model` names no servable graph                |
/// | `unknown_op`        | `op` names no admin operation                  |
/// | `admin_unavailable` | admin op has no operations loop, or not yet    |
/// | `bad_frame`         | truncated or non-UTF-8 frame (connection ends) |
/// | `oversized_frame`   | length prefix above [`MAX_FRAME_LEN`] (ends)   |
/// | `overloaded`        | worker queue full (`--max-queue`); retry later |
/// | `fleet_unavailable` | fleet router: every replica for the key is down|
/// | `internal`          | session or admin op failed for another reason  |
///
/// `overloaded` is the one error whose object carries an extra field:
/// `retry_after_ms`, a client backoff hint (see [`overloaded_json`]).
/// It is transient by contract — `repro call --retries` retries it,
/// and only it, among in-band errors. `fleet_unavailable` (wire v6) is
/// sent only by a `repro fleet` router, after connect/forward failures
/// marked every candidate instance for the request's routing key down.
#[derive(Clone, Debug, PartialEq)]
pub struct RpcError {
    pub code: String,
    pub message: String,
}

impl RpcError {
    pub fn new(code: &str, message: impl Into<String>) -> RpcError {
        RpcError { code: code.to_string(), message: message.into() }
    }
}

fn bad_request(message: impl Into<String>) -> RpcError {
    RpcError::new("bad_request", message)
}

/// An admin operation, as carried by a request frame with an `op`
/// field. These drive the *server*, not a session: `Stats` reports the
/// serving state, `Shutdown` asks the operations loop to drain and
/// persist, `Republish` re-tunes (or re-loads) one model and swaps it
/// into the live service at `epoch + 1`, and `RepublishAll`
/// (`{"op":"republish","all":true}`) does that for every zoo model
/// serially at consecutive epochs.
#[derive(Clone, Debug, PartialEq)]
pub enum AdminRequest {
    Stats,
    Shutdown,
    Republish { model: String },
    RepublishAll,
}

/// Any decoded request frame: a tenant session or an admin op.
#[derive(Clone, Debug)]
pub enum Request {
    Session(SessionRequest),
    Admin(AdminRequest),
}

/// Parse one request payload — session or admin. The `op` field
/// dispatches: absent (or `"session"`) means a session request, so
/// every pre-admin client payload keeps its exact meaning.
pub fn parse_any_request(line: &str, defaults: &RpcDefaults) -> Result<Request, RpcError> {
    let j = json::parse(line).map_err(|e| RpcError::new("bad_json", e.to_string()))?;
    let op = match j.get("op") {
        None => return Ok(Request::Session(session_from_json(&j, defaults)?)),
        Some(v) => v
            .as_str()
            .ok_or_else(|| bad_request("`op` must be a string"))?,
    };
    match op {
        "session" => Ok(Request::Session(session_from_json(&j, defaults)?)),
        "stats" => Ok(Request::Admin(AdminRequest::Stats)),
        "shutdown" => Ok(Request::Admin(AdminRequest::Shutdown)),
        "republish" => {
            let all = match j.get("all") {
                None => false,
                Some(Json::Bool(b)) => *b,
                Some(_) => return Err(bad_request("`all` must be a boolean")),
            };
            let model = match j.get("model") {
                Some(Json::Str(s)) if !s.is_empty() => Some(s.clone()),
                Some(_) => return Err(bad_request("`model` must be a non-empty string")),
                None => None,
            };
            match (all, model) {
                (true, Some(_)) => {
                    Err(bad_request("republish takes `model` or `all:true`, not both"))
                }
                (true, None) => Ok(Request::Admin(AdminRequest::RepublishAll)),
                (false, Some(model)) => Ok(Request::Admin(AdminRequest::Republish { model })),
                (false, None) => Err(bad_request("republish needs `model`")),
            }
        }
        other => Err(RpcError::new(
            "unknown_op",
            format!("unknown op `{other}` (session|stats|shutdown|republish)"),
        )),
    }
}

/// Parse one *session* request payload. Pure, so the TCP loop and the
/// `--requests` replay mode cannot drift (replay files carry sessions
/// only; admin ops exist on live connections).
pub fn parse_request(line: &str, defaults: &RpcDefaults) -> Result<SessionRequest, RpcError> {
    let j = json::parse(line).map_err(|e| RpcError::new("bad_json", e.to_string()))?;
    session_from_json(&j, defaults)
}

fn session_from_json(j: &Json, defaults: &RpcDefaults) -> Result<SessionRequest, RpcError> {
    let model = match j.get("model") {
        Some(Json::Str(s)) if !s.is_empty() => s.clone(),
        Some(_) => return Err(bad_request("`model` must be a non-empty string")),
        None => return Err(bad_request("missing `model`")),
    };
    let device = match j.get("device") {
        None | Some(Json::Null) => defaults.device.clone(),
        Some(v) => {
            let name = v.as_str().ok_or_else(|| bad_request("`device` must be a string"))?;
            DeviceProfile::by_name(name).ok_or_else(|| {
                RpcError::new("unknown_device", format!("unknown device `{name}` (server|edge)"))
            })?
        }
    };
    let budget_s = match j.get("budget_s") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let b = v
                .as_f64()
                .filter(|b| b.is_finite() && *b >= 0.0)
                .ok_or_else(|| bad_request("`budget_s` must be a finite number >= 0"))?;
            Some(b)
        }
    };
    let seed = match j.get("seed") {
        None | Some(Json::Null) => defaults.seed,
        Some(v) => v
            .as_f64()
            .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= 2f64.powi(53))
            .map(|x| x as u64)
            .ok_or_else(|| bad_request("`seed` must be a non-negative integer (< 2^53)"))?,
    };
    Ok(SessionRequest { model, device, budget_s, seed })
}

/// Encode a successful reply as the full response object.
pub fn response_json(reply: &SessionReply) -> Json {
    let choices = reply.choices.iter().map(|c| {
        Json::obj(vec![
            ("kernel", Json::num(c.kernel as f64)),
            ("class", Json::str(c.class_sig.as_str())),
            (
                "source_model",
                match &c.source_model {
                    Some(s) => Json::str(s.as_str()),
                    None => Json::Null,
                },
            ),
            (
                "source_input_shape",
                Json::arr(c.source_input_shape.iter().map(|&x| Json::num(x as f64))),
            ),
            ("standalone_s", Json::num(c.standalone_s)),
            ("schedule", serialize::to_json(&c.schedule)),
        ])
    });
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "reply",
            Json::obj(vec![
                ("target", Json::str(reply.target.as_str())),
                ("device", Json::str(reply.device)),
                ("seed", Json::num(reply.seed as f64)),
                ("epoch", Json::num(reply.epoch as f64)),
                ("sources", Json::arr(reply.sources.iter().map(|s| Json::str(s.as_str())))),
                ("untuned_model_s", Json::num(reply.untuned_model_s)),
                ("tuned_model_s", Json::num(reply.tuned_model_s)),
                ("predicted_speedup", Json::num(reply.predicted_speedup())),
                ("standalone_search_time_s", Json::num(reply.standalone_search_time_s)),
                ("charged_search_time_s", Json::num(reply.charged_search_time_s)),
                ("choices", Json::arr(choices)),
            ]),
        ),
    ])
}

/// Encode a structured error as the full response object.
pub fn error_json(err: &RpcError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::str(err.code.as_str())),
                ("message", Json::str(err.message.as_str())),
            ]),
        ),
    ])
}

/// Default `retry_after_ms` hint inside an `overloaded` error: long
/// enough for a worker to drain one typical request, short enough that
/// a shed client re-arrives while the burst is still the live story.
/// Since wire v6 this is the **cold-start floor** of the adaptive hint
/// (see [`adaptive_retry_after_ms`]): a live server that has finished
/// at least one request scales the hint with its measured drain rate,
/// but never hints below this.
pub const OVERLOADED_RETRY_AFTER_MS: u64 = 250;

/// Ceiling on the adaptive `retry_after_ms` hint: even a deeply backed
/// up queue should re-attract its shed clients within a human-scale
/// wait, and an absurd hint (one garbage-long request skewing the mean)
/// must not park them forever.
pub const MAX_RETRY_AFTER_MS: u64 = 10_000;

/// The adaptive v6 `retry_after_ms` hint: estimated time for the
/// current queue to drain, from the measured mean per-request service
/// time (`busy_micros / jobs_done`, the reactor's cumulative worker
/// gauges) spread across `workers` threads. Pure in its inputs so the
/// wire tests can pin it. Falls back to the fixed
/// [`OVERLOADED_RETRY_AFTER_MS`] floor before the first request
/// completes (cold start), and is clamped to
/// [[`OVERLOADED_RETRY_AFTER_MS`], [`MAX_RETRY_AFTER_MS`]] — routers
/// back off proportionally to real load, inside sane bounds.
pub fn adaptive_retry_after_ms(
    depth: usize,
    jobs_done: u64,
    busy_micros: u64,
    workers: usize,
) -> u64 {
    if jobs_done == 0 {
        return OVERLOADED_RETRY_AFTER_MS;
    }
    let mean_ms = busy_micros / jobs_done / 1_000;
    let drain_ms = mean_ms.saturating_mul(depth.max(1) as u64) / workers.max(1) as u64;
    drain_ms.clamp(OVERLOADED_RETRY_AFTER_MS, MAX_RETRY_AFTER_MS)
}

/// Encode the v5 `overloaded` response with the fixed
/// [`OVERLOADED_RETRY_AFTER_MS`] hint — the cold-start shape, and what
/// a raw [`Reactor`] shed hook without gauges emits. [`RpcServer`]
/// installs [`overloaded_json_with_hint`] fed by
/// [`adaptive_retry_after_ms`] instead.
pub fn overloaded_json(depth: usize) -> Json {
    overloaded_json_with_hint(depth, OVERLOADED_RETRY_AFTER_MS)
}

/// Encode the `overloaded` response: a structured error whose `error`
/// object carries a `retry_after_ms` backoff hint on top of the usual
/// `code`/`message`. Sent by the reactor's shed hook when a request
/// frame lands on a full worker queue (`--max-queue`), *before* the
/// request is parsed — shedding must cost no work. `depth` is the
/// observed queue depth, echoed in the message for operators.
pub fn overloaded_json_with_hint(depth: usize, retry_after_ms: u64) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::str("overloaded")),
                (
                    "message",
                    Json::str(format!(
                        "server overloaded: worker queue full ({depth} queued); retry later"
                    )),
                ),
                ("retry_after_ms", Json::num(retry_after_ms as f64)),
            ]),
        ),
    ])
}

/// A decoded response payload (client side).
#[derive(Debug)]
pub enum RpcResponse {
    /// The `reply` object of an `{"ok":true}` response.
    Reply(Json),
    Error(RpcError),
}

/// Decode a response payload (the client half of the codec).
pub fn parse_response(line: &str) -> anyhow::Result<RpcResponse> {
    let j = json::parse(line)?;
    match j.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => Ok(RpcResponse::Reply(j.req("reply")?.clone())),
        Some(false) => {
            let e = j.req("error")?;
            Ok(RpcResponse::Error(RpcError {
                code: e.req("code")?.as_str().unwrap_or_default().to_string(),
                message: e.req("message")?.as_str().unwrap_or_default().to_string(),
            }))
        }
        None => anyhow::bail!("response missing boolean `ok`"),
    }
}

/// A point-in-time snapshot of the reactor gauges for the `server:{}`
/// block of the `stats` reply: live connections, worker queue depth,
/// the cumulative per-kind eviction counts (wire v4), the cumulative
/// shed count, and the artifact-store quarantine count from the last
/// recovery pass (wire v5). Plain numbers — the encoding below stays
/// a pure, testable function.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub connections: usize,
    pub queue_depth: usize,
    pub evicted_idle: usize,
    pub evicted_read_stall: usize,
    pub evicted_write_stall: usize,
    /// Requests answered with `overloaded` instead of being queued.
    pub shed_total: usize,
    /// Torn/half-committed artifacts moved to `quarantine/` when the
    /// serve loop opened its `--cache-dir` (0 when no store attached).
    pub quarantined: usize,
}

impl ServerStats {
    /// Snapshot the live gauges (relaxed loads — each field is
    /// individually coherent, the set is advisory).
    pub fn snapshot(gauges: &ServerGauges) -> ServerStats {
        ServerStats {
            connections: gauges.connections.load(Ordering::Relaxed),
            queue_depth: gauges.queue_depth.load(Ordering::Relaxed),
            evicted_idle: gauges.evicted_idle.load(Ordering::Relaxed),
            evicted_read_stall: gauges.evicted_read_stall.load(Ordering::Relaxed),
            evicted_write_stall: gauges.evicted_write_stall.load(Ordering::Relaxed),
            shed_total: gauges.shed_total.load(Ordering::Relaxed),
            quarantined: gauges.quarantined.load(Ordering::Relaxed),
        }
    }
}

/// Encode the `{"ok":true,"stats":{..}}` response of an admin `stats`
/// op. The `zoo` half (build accounting + completion flag) exists only
/// when an operations loop is attached — a bare [`RpcServer`] reports
/// the serving state alone. The `server` half — a [`ServerStats`]
/// gauge snapshot — exists when the answering hook has a handle on the
/// reactor's [`ServerGauges`].
pub fn stats_json(
    service: &ScheduleService,
    zoo: Option<(&ZooBuildStats, bool)>,
    server: Option<ServerStats>,
) -> Json {
    let cache: CacheStats = service.cache_stats();
    let source_records = service
        .source_record_counts()
        .into_iter()
        .map(|(name, count)| (name, Json::num(count as f64)))
        .collect::<Vec<_>>();
    let mut stats = vec![
        ("protocol", Json::num(WIRE_PROTOCOL_VERSION as f64)),
        ("epoch", Json::num(service.epoch() as f64)),
        ("sources", Json::arr(service.live_sources().into_iter().map(Json::Str))),
        ("store_records", Json::num(service.store_records() as f64)),
        (
            "source_records",
            Json::obj(source_records.iter().map(|(n, c)| (n.as_str(), c.clone())).collect()),
        ),
        (
            "cache",
            Json::obj(vec![
                ("entries", Json::num(service.cache_len() as f64)),
                ("hits", Json::num(cache.hits as f64)),
                ("dedup_hits", Json::num(cache.dedup_hits as f64)),
                ("misses", Json::num(cache.misses as f64)),
                ("inserts", Json::num(cache.inserts as f64)),
                ("evictions", Json::num(cache.evictions as f64)),
                ("hit_rate", Json::num(cache.hit_rate())),
            ]),
        ),
    ];
    if let Some(s) = server {
        stats.push((
            "server",
            Json::obj(vec![
                ("connections", Json::num(s.connections as f64)),
                ("queue_depth", Json::num(s.queue_depth as f64)),
                ("evicted_idle", Json::num(s.evicted_idle as f64)),
                ("evicted_read_stall", Json::num(s.evicted_read_stall as f64)),
                ("evicted_write_stall", Json::num(s.evicted_write_stall as f64)),
                ("shed_total", Json::num(s.shed_total as f64)),
                ("quarantined", Json::num(s.quarantined as f64)),
            ]),
        ));
    }
    if let Some((z, complete)) = zoo {
        stats.push((
            "zoo",
            Json::obj(vec![
                ("models_tuned", Json::num(z.models_tuned as f64)),
                ("models_from_artifacts", Json::num(z.models_from_artifacts as f64)),
                ("trials_run", Json::num(z.trials_run as f64)),
                ("tuning_seconds_charged", Json::num(z.tuning_seconds_charged)),
                ("complete", Json::Bool(complete)),
            ]),
        ));
    }
    Json::obj(vec![("ok", Json::Bool(true)), ("stats", Json::obj(stats))])
}

/// Encode the `{"ok":true,"admin":{"op":..,..}}` acknowledgement of a
/// state-changing admin op (`shutdown`, `republish`).
pub fn admin_ack_json(op: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut admin = vec![("op", Json::str(op))];
    admin.extend(fields);
    Json::obj(vec![("ok", Json::Bool(true)), ("admin", Json::obj(admin))])
}

/// The server's admin dispatcher: every [`AdminRequest`] a connection
/// decodes is answered by this hook. The serve loop installs one that
/// forwards `shutdown`/`republish` to its control thread; anything
/// running a bare [`RpcServer`] gets [`default_admin`].
pub type AdminHook = Arc<dyn Fn(&AdminRequest, &ScheduleService) -> Json + Send + Sync>;

/// The hook used when no operations loop is attached: `stats` is a pure
/// function of the service and always answers; `shutdown`/`republish`
/// need an owner for the process and artifact store, so they are
/// refused with `admin_unavailable` rather than half-done.
pub fn default_admin() -> AdminHook {
    Arc::new(|req, service| match req {
        AdminRequest::Stats => stats_json(service, None, None),
        AdminRequest::Shutdown
        | AdminRequest::Republish { .. }
        | AdminRequest::RepublishAll => error_json(&RpcError::new(
            "admin_unavailable",
            "this server has no operations loop attached (stats only)",
        )),
    })
}

/// [`default_admin`] plus live server gauges in the `stats` reply —
/// what a bare [`RpcServer`] installs so its own reactor's connection
/// count and queue depth are visible over the wire.
pub fn default_admin_with_gauges(gauges: Arc<ServerGauges>) -> AdminHook {
    Arc::new(move |req, service| match req {
        AdminRequest::Stats => stats_json(service, None, Some(ServerStats::snapshot(&gauges))),
        AdminRequest::Shutdown
        | AdminRequest::Republish { .. }
        | AdminRequest::RepublishAll => error_json(&RpcError::new(
            "admin_unavailable",
            "this server has no operations loop attached (stats only)",
        )),
    })
}

/// Serve one request payload end to end: parse, dispatch (session or
/// admin), encode. Never fails — every failure becomes a structured
/// error response.
pub fn handle_request_with(
    service: &ScheduleService,
    defaults: &RpcDefaults,
    admin: &AdminHook,
    line: &str,
) -> Json {
    match parse_any_request(line, defaults) {
        Err(e) => error_json(&e),
        Ok(Request::Admin(req)) => admin(&req, service),
        Ok(Request::Session(req)) => match service.open_session(&req) {
            Ok(reply) => response_json(&reply),
            Err(e) => {
                // Classify by re-probing the service, not by sniffing
                // the anyhow message (whose wording is not a contract).
                let code =
                    if service.can_resolve(&req.model) { "internal" } else { "unknown_model" };
                error_json(&RpcError::new(code, e.to_string()))
            }
        },
    }
}

/// [`handle_request_with`] under [`default_admin`] — the oracle the
/// wire tests compare against, and the `--requests` replay's sibling.
pub fn handle_request(service: &ScheduleService, defaults: &RpcDefaults, line: &str) -> Json {
    handle_request_with(service, defaults, &default_admin(), line)
}

/// Server-level knobs surfaced to `main.rs` (`--max-conns`,
/// `--idle-timeout`) and to tests (millisecond stall deadlines). The
/// frame cap is not a knob: [`MAX_FRAME_LEN`] is part of the wire
/// contract.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Live-connection cap; the listener pauses at the cap.
    pub max_conns: usize,
    /// Idle deadline (connected, no request in flight, no bytes).
    pub idle_timeout: Duration,
    /// Mid-frame progress deadline (slowloris bound).
    pub read_stall: Duration,
    /// Outbound-progress deadline (client stopped reading).
    pub write_stall: Duration,
    /// Worker-queue bound (`--max-queue`): a request frame landing when
    /// this many decoded requests are already waiting is answered at
    /// once with the v5 `overloaded` error instead of queueing. 0 (the
    /// default) disables shedding — pre-v5 behavior.
    pub max_queue: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: DEFAULT_MAX_CONNS,
            idle_timeout: READ_STALL_TIMEOUT,
            read_stall: READ_STALL_TIMEOUT,
            write_stall: WRITE_STALL_TIMEOUT,
            max_queue: 0,
        }
    }
}

/// The TCP server: a thin wire-protocol binding over the readiness
/// [`Reactor`]. One event-loop thread owns every connection; a worker
/// pool sized by the global `--jobs`/`TT_JOBS` knob (via
/// [`effective_jobs`](crate::coordinator::effective_jobs)) executes
/// complete decoded requests, all workers sharing one
/// [`ScheduleService`] handle (sessions contend only on the sharded
/// measurement cache). Connections beyond `max_conns` wait in the
/// kernel backlog — served in arrival order, never dropped.
/// [`RpcServer::shutdown`] stops accepting, flushes in-flight replies
/// (bounded by [`WRITE_STALL_TIMEOUT`]), and joins all threads.
pub struct RpcServer {
    inner: Reactor,
}

/// Configures and starts an [`RpcServer`]: the one construction path
/// (obtained via [`RpcServer::builder`]) that PR 10 collapsed the
/// accumulated `start_with_timeouts` / `start_with_admin` /
/// `start_with_config` constructors into. Every knob has the same
/// default the old `start` applied, so
/// `RpcServer::builder().start(bind, service)` is the minimal form;
/// chain setters for the rest:
///
/// ```ignore
/// let server = RpcServer::builder()
///     .defaults(defaults)
///     .max_conns(1024)
///     .idle_timeout(Duration::from_secs(10))
///     .admin(hook)
///     .gauges(gauges)
///     .start("127.0.0.1:0", service)?;
/// ```
#[derive(Clone)]
pub struct ServerBuilder {
    config: ServerConfig,
    defaults: Option<RpcDefaults>,
    admin: Option<AdminHook>,
    gauges: Option<Arc<ServerGauges>>,
}

impl Default for ServerBuilder {
    fn default() -> ServerBuilder {
        RpcServer::builder()
    }
}

impl ServerBuilder {
    /// Server-side defaults for optional request fields. When not set:
    /// the CLI defaults (server device, seed `0xA45`).
    pub fn defaults(mut self, defaults: RpcDefaults) -> ServerBuilder {
        self.defaults = Some(defaults);
        self
    }

    /// Live-connection cap (see [`DEFAULT_MAX_CONNS`]); clamped to 1.
    pub fn max_conns(mut self, max_conns: usize) -> ServerBuilder {
        self.config.max_conns = max_conns;
        self
    }

    /// Idle-connection deadline (see [`READ_STALL_TIMEOUT`]).
    pub fn idle_timeout(mut self, d: Duration) -> ServerBuilder {
        self.config.idle_timeout = d;
        self
    }

    /// Mid-frame progress deadline (slowloris bound).
    pub fn read_stall(mut self, d: Duration) -> ServerBuilder {
        self.config.read_stall = d;
        self
    }

    /// Outbound-progress deadline (client stopped reading).
    pub fn write_stall(mut self, d: Duration) -> ServerBuilder {
        self.config.write_stall = d;
        self
    }

    /// One knob for both read-side deadlines (`idle_timeout` +
    /// `read_stall`) — what the deprecated `start_with_timeouts`
    /// offered, kept because tests exercising hung-client paths want
    /// both in milliseconds.
    pub fn timeouts(mut self, read_timeout: Duration) -> ServerBuilder {
        self.config.idle_timeout = read_timeout;
        self.config.read_stall = read_timeout;
        self
    }

    /// Worker-queue bound (`--max-queue`); 0 disables shedding.
    pub fn max_queue(mut self, max_queue: usize) -> ServerBuilder {
        self.config.max_queue = max_queue;
        self
    }

    /// Replace the whole [`ServerConfig`] at once (the `main.rs` path,
    /// which assembles one from CLI flags). Individual setters applied
    /// after this call still override their field.
    pub fn config(mut self, config: ServerConfig) -> ServerBuilder {
        self.config = config;
        self
    }

    /// Install an explicit [`AdminHook`] — how the serve loop wires
    /// `shutdown` and `republish` to its control thread. The hook owns
    /// `stats` entirely; pass [`ServerBuilder::gauges`] a clone of the
    /// `Arc` the hook reads so its `stats` reflect this server's
    /// reactor. When not set: [`default_admin_with_gauges`] over this
    /// server's own gauges.
    pub fn admin(mut self, admin: AdminHook) -> ServerBuilder {
        self.admin = Some(admin);
        self
    }

    /// The gauges instance the reactor updates (and the admin hook
    /// should read). When not set, a fresh instance is created.
    pub fn gauges(mut self, gauges: Arc<ServerGauges>) -> ServerBuilder {
        self.gauges = Some(gauges);
        self
    }

    /// Bind `bind` (e.g. `"127.0.0.1:7461"`, port 0 for ephemeral) and
    /// start serving `service` in background threads.
    pub fn start(self, bind: &str, service: ScheduleService) -> anyhow::Result<RpcServer> {
        let ServerBuilder { config, defaults, admin, gauges } = self;
        let defaults = defaults.unwrap_or_else(|| RpcDefaults {
            device: DeviceProfile::xeon_e5_2620(),
            seed: 0xA45,
        });
        let gauges = gauges.unwrap_or_default();
        let admin = admin.unwrap_or_else(|| default_admin_with_gauges(gauges.clone()));
        // The reactor owns bytes and deadlines; this closure is the
        // entire request plane — a pure (payload -> reply) function,
        // exactly the oracle `handle_request_with` is. The fault site
        // lets tests slow the plane down deterministically (a stand-in
        // for an expensive session) without touching real tuning knobs.
        let handler: reactor::Handler = Arc::new(move |line: &str| {
            crate::faults::sleep_site("rpc.handler");
            handle_request_with(&service, &defaults, &admin, line).to_compact()
        });
        // Shedding is answered by the event loop itself, so the frame
        // stays owned by this module: the reactor only ever sends what
        // this hook hands it. The hint is adaptive (v6): estimated
        // drain time of the observed queue depth from the live
        // jobs_done/busy_micros gauges, floored at the fixed v5 hint.
        // Resolved the same way the reactor resolves its pool size
        // (jobs: 0 below), so the estimate divides by the real worker
        // count.
        let workers = crate::coordinator::effective_jobs(0).max(1);
        let shed_gauges = gauges.clone();
        let shed: ShedHook = Arc::new(move |depth: usize| {
            let jobs_done = shed_gauges.jobs_done.load(Ordering::Relaxed) as u64;
            let busy_micros = shed_gauges.busy_micros.load(Ordering::Relaxed);
            let hint = adaptive_retry_after_ms(depth, jobs_done, busy_micros, workers);
            overloaded_json_with_hint(depth, hint).to_compact()
        });
        let rcfg = ReactorConfig {
            jobs: 0, // resolve via the global --jobs/TT_JOBS knob
            max_conns: config.max_conns.max(1),
            idle_timeout: config.idle_timeout,
            read_stall: config.read_stall,
            write_stall: config.write_stall,
            max_frame_len: MAX_FRAME_LEN,
            max_queue: config.max_queue,
        };
        let inner = Reactor::start(bind, handler, violation_hook(), shed, rcfg, gauges)?;
        Ok(RpcServer { inner })
    }
}

/// Framing-violation replies stay owned by this module so the reactor
/// stays JSON-free and the wire shapes cannot fork — shared by
/// [`RpcServer`] and the [`fleet`](super::fleet) router (both speak
/// the same frames, so both must answer violations identically).
pub fn violation_hook() -> reactor::ViolationHook {
    Arc::new(|v: &FrameViolation| {
        let (code, err) = match v {
            FrameViolation::Oversized(n) => ("oversized_frame", FrameError::Oversized(*n)),
            FrameViolation::Truncated => ("bad_frame", FrameError::Truncated),
            FrameViolation::Utf8 => ("bad_frame", FrameError::Utf8),
        };
        error_json(&RpcError::new(code, err.to_string())).to_compact()
    })
}

impl RpcServer {
    /// The construction path: every knob, with the defaults
    /// [`RpcServer::start`] applies. See [`ServerBuilder`].
    pub fn builder() -> ServerBuilder {
        ServerBuilder {
            config: ServerConfig::default(),
            defaults: None,
            admin: None,
            gauges: None,
        }
    }

    /// Bind `bind` (e.g. `"127.0.0.1:7461"`, port 0 for ephemeral) and
    /// start serving `service` in background threads, with
    /// [`default_admin_with_gauges`] answering admin ops (so `stats`
    /// reports this server's own connection/queue gauges). Shorthand
    /// for `RpcServer::builder().defaults(defaults).start(bind,
    /// service)`.
    pub fn start(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
    ) -> anyhow::Result<RpcServer> {
        Self::builder().defaults(defaults).start(bind, service)
    }

    /// [`RpcServer::start`] with an explicit idle/read-stall deadline
    /// in place of [`READ_STALL_TIMEOUT`].
    #[deprecated(note = "use RpcServer::builder().timeouts(..).start(..)")]
    pub fn start_with_timeouts(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
        read_timeout: Duration,
    ) -> anyhow::Result<RpcServer> {
        Self::builder().defaults(defaults).timeouts(read_timeout).start(bind, service)
    }

    /// [`RpcServer::start`] with an explicit [`AdminHook`].
    #[deprecated(note = "use RpcServer::builder().admin(..).start(..)")]
    pub fn start_with_admin(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
        admin: AdminHook,
    ) -> anyhow::Result<RpcServer> {
        Self::builder().defaults(defaults).admin(admin).start(bind, service)
    }

    /// Fully-explicit start: admin hook, server knobs, and the gauges
    /// instance the hook reads.
    #[deprecated(note = "use RpcServer::builder().config(..).admin(..).gauges(..).start(..)")]
    pub fn start_with_config(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
        admin: AdminHook,
        config: ServerConfig,
        gauges: Arc<ServerGauges>,
    ) -> anyhow::Result<RpcServer> {
        Self::builder()
            .defaults(defaults)
            .config(config)
            .admin(admin)
            .gauges(gauges)
            .start(bind, service)
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr()
    }

    /// The live serving gauges (connection count, queue depth).
    pub fn gauges(&self) -> Arc<ServerGauges> {
        self.inner.gauges()
    }

    /// Graceful shutdown: stop accepting, discard undecoded input,
    /// flush every in-flight reply — a reply already being computed or
    /// written still reaches its client, bounded by
    /// [`WRITE_STALL_TIMEOUT`] so the joins always terminate — and
    /// join all threads. Queued-but-unstarted requests are dropped and
    /// their connections closed unanswered — accepting no new work is
    /// what shutdown means.
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}
