//! The RPC front end: length-prefixed JSONL over TCP.
//!
//! `repro serve --listen ADDR` promotes the [`ScheduleService`] from a
//! one-shot request-file loop to a real multi-threaded server. The wire
//! protocol is deliberately minimal and dependency-free:
//!
//! ```text
//! frame    := u32_be(length) payload
//! payload  := one UTF-8 JSON object, length bytes, no trailing newline
//! ```
//!
//! Each request frame holds one JSON object. A frame without an `op`
//! field is a session request (same schema as the `--requests` JSONL
//! file: `{"model":..,"device":..,"budget_s":..,"seed":..}`); a frame
//! with an `op` field is an **admin request** — `{"op":"stats"}`,
//! `{"op":"shutdown"}`, or `{"op":"republish","model":..}` — handled by
//! the server's [`AdminHook`] (the serve loop wires shutdown/republish
//! to its control thread; a bare [`RpcServer`] answers `stats` and
//! rejects the rest with `admin_unavailable`). Each response frame
//! holds either `{"ok":true,..}` or `{"ok":false,"error":{"code":..,
//! "message":..}}`. A connection is a session loop: frames are
//! answered in order until the client closes. Malformed *JSON* gets a
//! structured `bad_json` error and the loop continues; malformed
//! *framing* (truncated, oversized, non-UTF-8) gets a best-effort
//! structured error and the connection closes, because resynchronizing
//! a byte stream after a broken length prefix is guesswork. The codec
//! never panics on hostile input — `rust/tests/rpc_codec.rs` proves it.
//!
//! Replies carry the store `epoch` (see [`SessionReply::epoch`]): with
//! a streaming zoo build publishing sources while the server runs, a
//! reply is a pure function of (target, device, budget, seed, epoch).
//!
//! **Concurrency model.** Connections are served by a bounded worker
//! pool sized by the global `--jobs`/`TT_JOBS` knob (the same knob as
//! every other host fan-out — see `coordinator::jobs`), not by one
//! thread per connection: excess connections queue at the acceptor and
//! are served as workers free up, never dropped. A connection is a
//! *session* and occupies its worker until the client closes, so
//! long-lived idle clients at a tiny `--jobs` can starve the queue —
//! operators should size `--jobs` for their expected concurrent
//! sessions (the signal path to shutdown never queues).

use super::{ScheduleService, SessionReply, SessionRequest};
use crate::coordinator::CacheStats;
use crate::device::DeviceProfile;
use crate::report::ZooBuildStats;
use crate::sched::serialize;
use crate::util::json::{self, Json};
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Hard cap on one frame's payload, both directions. Replies are a few
/// hundred KiB at worst (one schedule per target kernel); 16 MiB keeps
/// a hostile length prefix from allocating the machine away.
pub const MAX_FRAME_LEN: u32 = 16 * 1024 * 1024;

/// Version of the wire schema: the frame format plus the request,
/// response, and admin JSON shapes. v1 = session requests only (PR 3);
/// v2 = admin ops (`stats` / `shutdown` / `republish`). Bump this with
/// **any** protocol change, and update README §Wire protocol,
/// `rust/tests/rpc_codec.rs`, and `rust/tests/integration_rpc.rs` in
/// the same commit — CI's `format-drift` job fails a change to this
/// file that does not touch all three together.
pub const WIRE_PROTOCOL_VERSION: u64 = 2;

/// How long a reply write may stall before the connection is declared
/// dead. Bounds the drain phase of a shutdown: a worker mid-write
/// toward a client that stopped reading errors out instead of pinning
/// the join forever (the reason PR 3 closed both stream halves; the
/// timeout lets shutdown close only the read half and still terminate).
pub const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// How long a connection may sit idle (no request frame arriving)
/// before the server reclaims its pool worker. A client that connects
/// and then goes silent would otherwise pin a blocking read forever —
/// and the pool serves one connection per worker, so at `--jobs 1` a
/// single hung client starves every other connection. A timed-out read
/// is treated as a clean connection end: the stream closes with no
/// error frame, and the client is free to reconnect.
pub const READ_STALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Framing-layer failure. Everything above the byte stream (bad JSON,
/// bad request fields) is reported in-band as an [`RpcError`] instead.
#[derive(Debug)]
pub enum FrameError {
    /// Clean end of stream *between* frames (normal client hang-up).
    Closed,
    /// Stream ended inside a header or payload.
    Truncated,
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversized(u32),
    /// Payload bytes are not UTF-8.
    Utf8,
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::Truncated => write!(f, "stream ended mid-frame"),
            FrameError::Oversized(n) => {
                write!(f, "frame of {n} bytes exceeds the {MAX_FRAME_LEN}-byte limit")
            }
            FrameError::Utf8 => write!(f, "frame payload is not UTF-8"),
            FrameError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

/// Frame a payload: 4-byte big-endian length, then the bytes.
pub fn encode_frame(payload: &str) -> Result<Vec<u8>, FrameError> {
    if payload.len() as u64 > MAX_FRAME_LEN as u64 {
        return Err(FrameError::Oversized(payload.len() as u32));
    }
    let mut buf = Vec::with_capacity(4 + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    buf.extend_from_slice(payload.as_bytes());
    Ok(buf)
}

fn read_exact_or(r: &mut impl Read, buf: &mut [u8], on_eof: FrameError) -> Result<(), FrameError> {
    match r.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Err(on_eof),
        Err(e) => Err(FrameError::Io(e)),
    }
}

/// Read one frame's payload. Distinguishes a clean close (EOF before
/// any header byte → [`FrameError::Closed`]) from a truncation (EOF
/// anywhere inside a frame). An oversized declared length is rejected
/// *before* any payload allocation.
pub fn read_frame(r: &mut impl Read) -> Result<String, FrameError> {
    let mut header = [0u8; 4];
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Err(FrameError::Closed),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    header[0] = first[0];
    read_exact_or(r, &mut header[1..], FrameError::Truncated)?;
    let len = u32::from_be_bytes(header);
    if len > MAX_FRAME_LEN {
        return Err(FrameError::Oversized(len));
    }
    let mut payload = vec![0u8; len as usize];
    read_exact_or(r, &mut payload, FrameError::Truncated)?;
    String::from_utf8(payload).map_err(|_| FrameError::Utf8)
}

/// Server-side defaults for optional request fields (`device`, `seed`),
/// mirroring the `--requests` file mode's CLI-flag defaults.
#[derive(Clone, Debug)]
pub struct RpcDefaults {
    pub device: DeviceProfile,
    pub seed: u64,
}

/// A structured in-band error (`{"ok":false,"error":{..}}`). Codes:
///
/// | code                | meaning                                        |
/// |---------------------|------------------------------------------------|
/// | `bad_json`          | request payload is not valid JSON              |
/// | `bad_request`       | missing/ill-typed request field                |
/// | `unknown_device`    | `device` names no profile (server\|edge)       |
/// | `unknown_model`     | `model` names no servable graph                |
/// | `unknown_op`        | `op` names no admin operation                  |
/// | `admin_unavailable` | admin op has no operations loop, or not yet    |
/// | `bad_frame`         | truncated or non-UTF-8 frame (connection ends) |
/// | `oversized_frame`   | length prefix above [`MAX_FRAME_LEN`] (ends)   |
/// | `internal`          | session or admin op failed for another reason  |
#[derive(Clone, Debug, PartialEq)]
pub struct RpcError {
    pub code: String,
    pub message: String,
}

impl RpcError {
    pub fn new(code: &str, message: impl Into<String>) -> RpcError {
        RpcError { code: code.to_string(), message: message.into() }
    }
}

fn bad_request(message: impl Into<String>) -> RpcError {
    RpcError::new("bad_request", message)
}

/// An admin operation, as carried by a request frame with an `op`
/// field. These drive the *server*, not a session: `Stats` reports the
/// serving state, `Shutdown` asks the operations loop to drain and
/// persist, `Republish` re-tunes (or re-loads) one model and swaps it
/// into the live service at `epoch + 1`.
#[derive(Clone, Debug, PartialEq)]
pub enum AdminRequest {
    Stats,
    Shutdown,
    Republish { model: String },
}

/// Any decoded request frame: a tenant session or an admin op.
#[derive(Clone, Debug)]
pub enum Request {
    Session(SessionRequest),
    Admin(AdminRequest),
}

/// Parse one request payload — session or admin. The `op` field
/// dispatches: absent (or `"session"`) means a session request, so
/// every pre-admin client payload keeps its exact meaning.
pub fn parse_any_request(line: &str, defaults: &RpcDefaults) -> Result<Request, RpcError> {
    let j = json::parse(line).map_err(|e| RpcError::new("bad_json", e.to_string()))?;
    let op = match j.get("op") {
        None => return Ok(Request::Session(session_from_json(&j, defaults)?)),
        Some(v) => v
            .as_str()
            .ok_or_else(|| bad_request("`op` must be a string"))?,
    };
    match op {
        "session" => Ok(Request::Session(session_from_json(&j, defaults)?)),
        "stats" => Ok(Request::Admin(AdminRequest::Stats)),
        "shutdown" => Ok(Request::Admin(AdminRequest::Shutdown)),
        "republish" => {
            let model = match j.get("model") {
                Some(Json::Str(s)) if !s.is_empty() => s.clone(),
                Some(_) => return Err(bad_request("`model` must be a non-empty string")),
                None => return Err(bad_request("republish needs `model`")),
            };
            Ok(Request::Admin(AdminRequest::Republish { model }))
        }
        other => Err(RpcError::new(
            "unknown_op",
            format!("unknown op `{other}` (session|stats|shutdown|republish)"),
        )),
    }
}

/// Parse one *session* request payload. Pure, so the TCP loop and the
/// `--requests` replay mode cannot drift (replay files carry sessions
/// only; admin ops exist on live connections).
pub fn parse_request(line: &str, defaults: &RpcDefaults) -> Result<SessionRequest, RpcError> {
    let j = json::parse(line).map_err(|e| RpcError::new("bad_json", e.to_string()))?;
    session_from_json(&j, defaults)
}

fn session_from_json(j: &Json, defaults: &RpcDefaults) -> Result<SessionRequest, RpcError> {
    let model = match j.get("model") {
        Some(Json::Str(s)) if !s.is_empty() => s.clone(),
        Some(_) => return Err(bad_request("`model` must be a non-empty string")),
        None => return Err(bad_request("missing `model`")),
    };
    let device = match j.get("device") {
        None | Some(Json::Null) => defaults.device.clone(),
        Some(v) => {
            let name = v.as_str().ok_or_else(|| bad_request("`device` must be a string"))?;
            DeviceProfile::by_name(name).ok_or_else(|| {
                RpcError::new("unknown_device", format!("unknown device `{name}` (server|edge)"))
            })?
        }
    };
    let budget_s = match j.get("budget_s") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let b = v
                .as_f64()
                .filter(|b| b.is_finite() && *b >= 0.0)
                .ok_or_else(|| bad_request("`budget_s` must be a finite number >= 0"))?;
            Some(b)
        }
    };
    let seed = match j.get("seed") {
        None | Some(Json::Null) => defaults.seed,
        Some(v) => v
            .as_f64()
            .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= 2f64.powi(53))
            .map(|x| x as u64)
            .ok_or_else(|| bad_request("`seed` must be a non-negative integer (< 2^53)"))?,
    };
    Ok(SessionRequest { model, device, budget_s, seed })
}

/// Encode a successful reply as the full response object.
pub fn response_json(reply: &SessionReply) -> Json {
    let choices = reply.choices.iter().map(|c| {
        Json::obj(vec![
            ("kernel", Json::num(c.kernel as f64)),
            ("class", Json::str(c.class_sig.as_str())),
            (
                "source_model",
                match &c.source_model {
                    Some(s) => Json::str(s.as_str()),
                    None => Json::Null,
                },
            ),
            (
                "source_input_shape",
                Json::arr(c.source_input_shape.iter().map(|&x| Json::num(x as f64))),
            ),
            ("standalone_s", Json::num(c.standalone_s)),
            ("schedule", serialize::to_json(&c.schedule)),
        ])
    });
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "reply",
            Json::obj(vec![
                ("target", Json::str(reply.target.as_str())),
                ("device", Json::str(reply.device)),
                ("seed", Json::num(reply.seed as f64)),
                ("epoch", Json::num(reply.epoch as f64)),
                ("sources", Json::arr(reply.sources.iter().map(|s| Json::str(s.as_str())))),
                ("untuned_model_s", Json::num(reply.untuned_model_s)),
                ("tuned_model_s", Json::num(reply.tuned_model_s)),
                ("predicted_speedup", Json::num(reply.predicted_speedup())),
                ("standalone_search_time_s", Json::num(reply.standalone_search_time_s)),
                ("charged_search_time_s", Json::num(reply.charged_search_time_s)),
                ("choices", Json::arr(choices)),
            ]),
        ),
    ])
}

/// Encode a structured error as the full response object.
pub fn error_json(err: &RpcError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::str(err.code.as_str())),
                ("message", Json::str(err.message.as_str())),
            ]),
        ),
    ])
}

/// A decoded response payload (client side).
#[derive(Debug)]
pub enum RpcResponse {
    /// The `reply` object of an `{"ok":true}` response.
    Reply(Json),
    Error(RpcError),
}

/// Decode a response payload (the client half of the codec).
pub fn parse_response(line: &str) -> anyhow::Result<RpcResponse> {
    let j = json::parse(line)?;
    match j.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => Ok(RpcResponse::Reply(j.req("reply")?.clone())),
        Some(false) => {
            let e = j.req("error")?;
            Ok(RpcResponse::Error(RpcError {
                code: e.req("code")?.as_str().unwrap_or_default().to_string(),
                message: e.req("message")?.as_str().unwrap_or_default().to_string(),
            }))
        }
        None => anyhow::bail!("response missing boolean `ok`"),
    }
}

/// Encode the `{"ok":true,"stats":{..}}` response of an admin `stats`
/// op. The `zoo` half (build accounting + completion flag) exists only
/// when an operations loop is attached — a bare [`RpcServer`] reports
/// the serving state alone.
pub fn stats_json(service: &ScheduleService, zoo: Option<(&ZooBuildStats, bool)>) -> Json {
    let cache: CacheStats = service.cache_stats();
    let mut stats = vec![
        ("protocol", Json::num(WIRE_PROTOCOL_VERSION as f64)),
        ("epoch", Json::num(service.epoch() as f64)),
        ("sources", Json::arr(service.live_sources().into_iter().map(Json::Str))),
        ("store_records", Json::num(service.store_records() as f64)),
        (
            "cache",
            Json::obj(vec![
                ("entries", Json::num(service.cache_len() as f64)),
                ("hits", Json::num(cache.hits as f64)),
                ("dedup_hits", Json::num(cache.dedup_hits as f64)),
                ("misses", Json::num(cache.misses as f64)),
                ("inserts", Json::num(cache.inserts as f64)),
                ("evictions", Json::num(cache.evictions as f64)),
                ("hit_rate", Json::num(cache.hit_rate())),
            ]),
        ),
    ];
    if let Some((z, complete)) = zoo {
        stats.push((
            "zoo",
            Json::obj(vec![
                ("models_tuned", Json::num(z.models_tuned as f64)),
                ("models_from_artifacts", Json::num(z.models_from_artifacts as f64)),
                ("trials_run", Json::num(z.trials_run as f64)),
                ("tuning_seconds_charged", Json::num(z.tuning_seconds_charged)),
                ("complete", Json::Bool(complete)),
            ]),
        ));
    }
    Json::obj(vec![("ok", Json::Bool(true)), ("stats", Json::obj(stats))])
}

/// Encode the `{"ok":true,"admin":{"op":..,..}}` acknowledgement of a
/// state-changing admin op (`shutdown`, `republish`).
pub fn admin_ack_json(op: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut admin = vec![("op", Json::str(op))];
    admin.extend(fields);
    Json::obj(vec![("ok", Json::Bool(true)), ("admin", Json::obj(admin))])
}

/// The server's admin dispatcher: every [`AdminRequest`] a connection
/// decodes is answered by this hook. The serve loop installs one that
/// forwards `shutdown`/`republish` to its control thread; anything
/// running a bare [`RpcServer`] gets [`default_admin`].
pub type AdminHook = Arc<dyn Fn(&AdminRequest, &ScheduleService) -> Json + Send + Sync>;

/// The hook used when no operations loop is attached: `stats` is a pure
/// function of the service and always answers; `shutdown`/`republish`
/// need an owner for the process and artifact store, so they are
/// refused with `admin_unavailable` rather than half-done.
pub fn default_admin() -> AdminHook {
    Arc::new(|req, service| match req {
        AdminRequest::Stats => stats_json(service, None),
        AdminRequest::Shutdown | AdminRequest::Republish { .. } => error_json(&RpcError::new(
            "admin_unavailable",
            "this server has no operations loop attached (stats only)",
        )),
    })
}

/// Serve one request payload end to end: parse, dispatch (session or
/// admin), encode. Never fails — every failure becomes a structured
/// error response.
pub fn handle_request_with(
    service: &ScheduleService,
    defaults: &RpcDefaults,
    admin: &AdminHook,
    line: &str,
) -> Json {
    match parse_any_request(line, defaults) {
        Err(e) => error_json(&e),
        Ok(Request::Admin(req)) => admin(&req, service),
        Ok(Request::Session(req)) => match service.open_session(&req) {
            Ok(reply) => response_json(&reply),
            Err(e) => {
                // Classify by re-probing the service, not by sniffing
                // the anyhow message (whose wording is not a contract).
                let code =
                    if service.can_resolve(&req.model) { "internal" } else { "unknown_model" };
                error_json(&RpcError::new(code, e.to_string()))
            }
        },
    }
}

/// [`handle_request_with`] under [`default_admin`] — the oracle the
/// wire tests compare against, and the `--requests` replay's sibling.
pub fn handle_request(service: &ScheduleService, defaults: &RpcDefaults, line: &str) -> Json {
    handle_request_with(service, defaults, &default_admin(), line)
}

/// Live-connection registry: connection id -> duplicated handle, used
/// to unblock readers on shutdown. Entries are removed when their
/// connection completes, so a long-lived server does not leak one fd
/// per connection served.
type ConnMap = Arc<Mutex<std::collections::HashMap<u64, TcpStream>>>;

/// Accepted-but-unserved connections, waiting for a pool worker.
struct ConnQueue {
    queue: Mutex<VecDeque<(u64, TcpStream)>>,
    ready: Condvar,
}

/// The multi-threaded TCP server: one accept thread feeding a bounded
/// worker pool (sized by the global `--jobs`/`TT_JOBS` knob via
/// [`effective_jobs`](crate::coordinator::effective_jobs)), all workers
/// sharing one [`ScheduleService`] handle (sessions contend only on
/// the sharded measurement cache). Connections beyond the pool size
/// queue at the acceptor — served in arrival order, never dropped.
/// [`RpcServer::shutdown`] stops accepting, drains in-flight replies,
/// unblocks every connection's reader, and joins all threads.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: ConnMap,
    pending: Arc<ConnQueue>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl RpcServer {
    /// Bind `bind` (e.g. `"127.0.0.1:7461"`, port 0 for ephemeral) and
    /// start serving `service` in background threads, with
    /// [`default_admin`] answering admin ops.
    pub fn start(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
    ) -> anyhow::Result<RpcServer> {
        Self::start_with_admin(bind, service, defaults, default_admin())
    }

    /// [`RpcServer::start`] with an explicit idle-read timeout in place
    /// of [`READ_STALL_TIMEOUT`] — lets tests exercise the hung-client
    /// path in milliseconds instead of seconds.
    pub fn start_with_timeouts(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
        read_timeout: Duration,
    ) -> anyhow::Result<RpcServer> {
        Self::start_inner(bind, service, defaults, default_admin(), read_timeout)
    }

    /// [`RpcServer::start`] with an explicit [`AdminHook`] — how the
    /// serve loop wires `shutdown` and `republish` to its control
    /// thread.
    pub fn start_with_admin(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
        admin: AdminHook,
    ) -> anyhow::Result<RpcServer> {
        Self::start_inner(bind, service, defaults, admin, READ_STALL_TIMEOUT)
    }

    fn start_inner(
        bind: &str,
        service: ScheduleService,
        defaults: RpcDefaults,
        admin: AdminHook,
        read_timeout: Duration,
    ) -> anyhow::Result<RpcServer> {
        let listener = TcpListener::bind(bind)
            .map_err(|e| anyhow::anyhow!("binding RPC listener on {bind}: {e}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: ConnMap = Arc::new(Mutex::new(std::collections::HashMap::new()));
        let pending = Arc::new(ConnQueue {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
        });
        let n_workers = crate::coordinator::effective_jobs(0);
        let mut workers = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let w_service = service.clone();
            let w_defaults = defaults.clone();
            let w_admin = admin.clone();
            let w_stop = stop.clone();
            let w_conns = conns.clone();
            let w_pending = pending.clone();
            let spawned = std::thread::Builder::new().name(format!("tt-rpc-{wi}")).spawn(
                move || {
                    worker_loop(&w_pending, &w_service, &w_defaults, &w_admin, &w_stop, &w_conns)
                },
            );
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    // Unwind the workers already parked on the condvar;
                    // returning the error with them still waiting would
                    // leak one thread (plus a service handle) each.
                    stop.store(true, Ordering::SeqCst);
                    drop(pending.queue.lock().expect("conn queue"));
                    pending.ready.notify_all();
                    for worker in workers {
                        let _ = worker.join();
                    }
                    return Err(anyhow::anyhow!("spawning RPC worker {wi}: {e}"));
                }
            }
        }
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let pending = pending.clone();
            std::thread::spawn(move || accept_loop(listener, stop, conns, pending, read_timeout))
        };
        Ok(RpcServer { addr, stop, conns, pending, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Graceful shutdown: stop accepting, drain, join all threads.
    /// Only the *read* half of each live connection is shut down, so a
    /// reply already being computed or written still reaches its client
    /// (the drain); a worker stuck writing toward a client that stopped
    /// reading is bounded by [`WRITE_STALL_TIMEOUT`], so the joins
    /// always terminate. Queued-but-unserved connections are closed
    /// unanswered — accepting no new work is what shutdown means.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake idle pool workers so they observe the stop flag. The
        // empty critical section orders the store with each worker's
        // check-then-wait: a worker that read stop == false while
        // holding the queue lock is guaranteed to reach `wait` (and
        // release the lock) before this notify fires — without it the
        // notification could land in that window and be lost, leaving
        // the worker parked forever and the joins below hung.
        drop(self.pending.queue.lock().expect("conn queue"));
        self.pending.ready.notify_all();
        // Unblock the accept loop with a throwaway connection (the flag
        // is already visible when it wakes). Wildcard binds (0.0.0.0)
        // may not be dialable as-is; fall back to loopback.
        if TcpStream::connect(self.addr).is_err() {
            let _ =
                TcpStream::connect((std::net::Ipv4Addr::LOCALHOST, self.addr.port()));
        }
        for conn in self.conns.lock().expect("conns lock").values() {
            let _ = conn.shutdown(Shutdown::Read);
        }
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
        // Close (by drop) connections that were accepted but never
        // reached a worker; their registry entries go with them.
        self.pending.queue.lock().expect("conn queue").clear();
        self.conns.lock().expect("conns lock").clear();
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_and_join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    conns: ConnMap,
    pending: Arc<ConnQueue>,
    read_timeout: Duration,
) {
    let mut next_id: u64 = 0;
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => {
                // Transient accept failure (e.g. fd pressure): back off
                // instead of spinning the accept thread hot.
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        // Bound every reply write so a drain can always terminate, and
        // every idle read so a silent client cannot pin a pool worker.
        let _ = stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT));
        let _ = stream.set_read_timeout(Some(read_timeout));
        let id = next_id;
        next_id += 1;
        // Register the handle BEFORE queueing: every connection must be
        // unblockable at shutdown, whether a worker holds it yet or
        // not. If the handle cannot be duplicated (fd pressure), refuse
        // the connection rather than queue one shutdown() cannot wake.
        let Ok(handle) = stream.try_clone() else { continue };
        conns.lock().expect("conns lock").insert(id, handle);
        pending.queue.lock().expect("conn queue").push_back((id, stream));
        pending.ready.notify_one();
    }
}

/// One pool worker: serve queued connections to completion, one at a
/// time, until shutdown. The queue is never abandoned mid-connection —
/// a worker finishes (or is unblocked out of) its current session loop
/// before it re-checks the stop flag.
fn worker_loop(
    pending: &ConnQueue,
    service: &ScheduleService,
    defaults: &RpcDefaults,
    admin: &AdminHook,
    stop: &AtomicBool,
    conns: &ConnMap,
) {
    loop {
        let (id, stream) = {
            let mut queue = pending.queue.lock().expect("conn queue");
            loop {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(next) = queue.pop_front() {
                    break next;
                }
                queue = pending.ready.wait(queue).expect("conn queue");
            }
        };
        connection_loop(stream, service, defaults, admin, stop);
        // Drop this connection's registry entry so a long-lived
        // server's fd usage tracks *live* connections only.
        conns.lock().expect("conns lock").remove(&id);
    }
}

/// One connection's session loop: answer frames in order until the
/// client closes, the framing breaks, or the server shuts down.
fn connection_loop(
    stream: TcpStream,
    service: &ScheduleService,
    defaults: &RpcDefaults,
    admin: &AdminHook,
    stop: &AtomicBool,
) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = stream;
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match read_frame(&mut reader) {
            Ok(line) => {
                let response = handle_request_with(service, defaults, admin, &line).to_compact();
                match encode_frame(&response) {
                    Ok(buf) => {
                        if writer.write_all(&buf).is_err() {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
            // Io covers the idle-read timeout (WouldBlock/TimedOut from
            // a client that connected and went silent): both are a
            // clean connection end, closed without an error frame.
            Err(FrameError::Closed) | Err(FrameError::Io(_)) => break,
            Err(e) => {
                // Framing violation: best-effort structured error, then
                // close (the stream cannot be resynchronized).
                if !stop.load(Ordering::SeqCst) {
                    let code = match e {
                        FrameError::Oversized(_) => "oversized_frame",
                        _ => "bad_frame",
                    };
                    let response = error_json(&RpcError::new(code, e.to_string())).to_compact();
                    if let Ok(buf) = encode_frame(&response) {
                        let _ = writer.write_all(&buf);
                    }
                }
                break;
            }
        }
    }
    let _ = writer.shutdown(Shutdown::Both);
}
