//! A hashed timer wheel for connection deadlines.
//!
//! The reactor (see [`crate::service::reactor`]) tracks one deadline per
//! connection — idle reap, read-stall, or write-stall — and needs two
//! operations to be cheap: (re)arming a deadline every time a connection
//! makes progress, and harvesting the set of expired connections once per
//! event-loop tick. A binary heap makes the second cheap but the first
//! O(log n) with tombstones; a hashed wheel makes both O(1) amortised at
//! the cost of bounded timer resolution, which is exactly the right trade
//! for coarse 30-second network deadlines.
//!
//! Design points:
//!
//! - Time is ticks, not instants. The caller converts `Instant`s to a
//!   monotonically nondecreasing millisecond counter and the wheel divides
//!   by [`TICK_MS`]. Scheduling rounds the due time *up* to the next tick
//!   and harvesting rounds the current time *down*, so a deadline never
//!   fires early — late by at most one tick granularity is fine for
//!   deadlines measured in seconds, early would break e.g. the write-stall
//!   test's timing assumptions.
//! - Cancellation is lazy. Re-arming a token does not remove the old slot
//!   entry; each entry carries the `due_tick` it was scheduled for, and
//!   harvest yields a token only if the entry is not stale. The caller
//!   additionally re-checks its own authoritative per-connection deadline
//!   before acting, so even a token harvested from a stale-but-matching
//!   tick is at worst a spurious wakeup, never a wrong close.
//! - Slot count is a power of two so the slot index is a mask, and the
//!   wheel handles due times further than one rotation away by re-queueing
//!   (an entry found before its due tick is pushed back into its slot and
//!   revisited a rotation later).

/// Milliseconds per wheel tick. Deadlines fire at most this much late.
pub const TICK_MS: u64 = 20;

/// Number of slots; one rotation covers `SLOTS * TICK_MS` ≈ 10.2 s.
const SLOTS: usize = 512;

#[derive(Debug, Clone, Copy)]
struct Entry {
    token: u64,
    due_tick: u64,
}

/// Hashed timer wheel over opaque `u64` tokens.
#[derive(Debug)]
pub struct TimerWheel {
    slots: Vec<Vec<Entry>>,
    /// The next tick `advance` will harvest; everything strictly below it
    /// has already been harvested.
    cursor: u64,
}

impl TimerWheel {
    pub fn new() -> Self {
        TimerWheel {
            slots: (0..SLOTS).map(|_| Vec::new()).collect(),
            cursor: 0,
        }
    }

    /// Arm (or re-arm) `token` to fire at `due_ms` (absolute, same clock
    /// as `advance`). Earlier entries for the same token become stale and
    /// are skipped at harvest time.
    pub fn schedule(&mut self, token: u64, due_ms: u64) {
        // Round up: never fire before the requested time.
        let mut due_tick = due_ms.div_ceil(TICK_MS);
        // A due time in the harvested past would land in a slot the cursor
        // has moved beyond and sleep a whole rotation; clamp it forward.
        if due_tick < self.cursor {
            due_tick = self.cursor;
        }
        let slot = (due_tick as usize) & (SLOTS - 1);
        self.slots[slot].push(Entry { token, due_tick });
    }

    /// Harvest every entry due at or before `now_ms`, appending its token
    /// to `out`. Tokens may repeat and may be stale (re-armed later);
    /// callers must re-check their own authoritative deadline.
    pub fn advance(&mut self, now_ms: u64, out: &mut Vec<u64>) {
        // Round down: a tick only counts as reached once fully elapsed.
        let now_tick = now_ms / TICK_MS;
        if now_tick < self.cursor {
            return;
        }
        // Sweep at most one full rotation; slots repeat beyond that and a
        // second pass over the same slot would find only re-queued future
        // entries again.
        let span = (now_tick - self.cursor + 1).min(SLOTS as u64);
        for step in 0..span {
            let tick = self.cursor + step;
            let slot = (tick as usize) & (SLOTS - 1);
            let mut i = 0;
            while i < self.slots[slot].len() {
                let e = self.slots[slot][i];
                if e.due_tick <= now_tick {
                    out.push(e.token);
                    self.slots[slot].swap_remove(i);
                } else {
                    // Future rotation: leave in place, revisit later.
                    i += 1;
                }
            }
        }
        self.cursor = now_tick + 1;
    }

    /// Total live entries (including stale ones awaiting lazy removal).
    /// Test/diagnostic aid.
    pub fn len(&self) -> usize {
        self.slots.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for TimerWheel {
    fn default() -> Self {
        Self::new()
    }
}
