//! Sharded measurement cache + concurrent sweep executor.
//!
//! The single-tenant engine owns a `&mut MeasureCache`; a serving
//! deployment has many tenants sweeping against one shared cache. One
//! global lock would serialize them, so the cache is split into N
//! shards (selected by cache-key hash), each behind its own mutex —
//! lookups and inserts take one short per-key lock, and tenants whose
//! working sets land on different shards never contend.
//!
//! Correctness under concurrency comes from the same property that
//! makes the flat cache transparent: a pair's measurement is a pure
//! function of (content, seed, device) — noise is content-derived, not
//! order-derived — so when two tenants race on the same missing pair,
//! both measure the *same* value and the double insert is idempotent.
//! Results are therefore bit-identical to a single-threaded run; only
//! the per-tenant *charged* ledgers (who paid for a shared miss) can
//! vary with interleaving, which is why reported numbers always use the
//! order-independent cold ledger (see `transfer::engine`).

use crate::coordinator::cache::{CacheStats, MeasureCache, Resolution};
use crate::coordinator::pool::{measure_pairs_cached_generic, CacheOps, CachedBatch};
use crate::coordinator::Ledger;
use crate::device::DeviceProfile;
use crate::ir::Kernel;
use crate::sched::{ApplyError, Schedule};
use std::sync::Mutex;

/// A [`MeasureCache`] split across `n` independently locked shards.
/// Shards are unbounded (serving caches persist via the artifact store
/// rather than evict).
#[derive(Debug)]
pub struct ShardedMeasureCache {
    shards: Vec<Mutex<MeasureCache>>,
}

impl ShardedMeasureCache {
    pub fn new(n_shards: usize) -> Self {
        let n = n_shards.max(1);
        ShardedMeasureCache {
            shards: (0..n).map(|_| Mutex::new(MeasureCache::new())).collect(),
        }
    }

    /// Distribute a flat snapshot (e.g. a zoo's cache, or one loaded
    /// from the artifact store) across shards.
    pub fn from_cache(cache: &MeasureCache, n_shards: usize) -> Self {
        let sharded = Self::new(n_shards);
        for (key, runtime) in cache.entries_lru() {
            sharded.shard(key).lock().unwrap().insert(key, runtime);
        }
        sharded.reset_stats(); // seeding must not look like activity
        sharded
    }

    fn shard(&self, key: u64) -> &Mutex<MeasureCache> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn insert(&self, key: u64, runtime: Option<f64>) {
        self.shard(key).lock().unwrap().insert(key, runtime);
    }

    pub fn peek(&self, key: u64) -> Option<Option<f64>> {
        self.shard(key).lock().unwrap().peek(key)
    }

    /// Merged counters across shards.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.shards {
            total.merge(&s.lock().unwrap().stats);
        }
        total
    }

    pub fn reset_stats(&self) {
        for s in &self.shards {
            s.lock().unwrap().reset_stats();
        }
    }

    /// Flatten into one [`MeasureCache`] (for artifact persistence).
    /// Counters are reset on the snapshot — contents, not activity.
    pub fn to_cache(&self) -> MeasureCache {
        let mut flat = MeasureCache::new();
        for s in &self.shards {
            for (key, runtime) in s.lock().unwrap().entries_lru() {
                flat.insert(key, runtime);
            }
        }
        flat.reset_stats();
        flat
    }
}

/// [`CacheOps`] over a shared sharded cache: every operation takes one
/// short per-key shard lock, so concurrent tenants interleave freely
/// while running the exact same pipeline body as the flat executor.
/// Implemented on `&ShardedMeasureCache` because the pipeline wants
/// `&mut C` but shard locks make interior mutation safe behind `&`.
impl CacheOps for &ShardedMeasureCache {
    fn record_dedup_hit(&mut self, key: u64) {
        self.shard(key).lock().unwrap().stats.dedup_hits += 1;
    }

    fn resolve(
        &mut self,
        key: u64,
        validate: impl FnOnce() -> Result<(), ApplyError>,
    ) -> Resolution<ApplyError> {
        // One short per-key critical section; measurement happens
        // outside every lock.
        self.shard(key).lock().unwrap().resolve_with(key, validate)
    }

    fn insert_outcome(&mut self, key: u64, runtime: Option<f64>) {
        self.shard(key).lock().unwrap().insert(key, runtime);
    }
}

/// The sharded counterpart of
/// [`measure_pairs_cached_precomputed`](crate::coordinator::measure_pairs_cached_precomputed):
/// the same generic pipeline body and the same transparency invariant,
/// but each resolution locks only the key's shard, so concurrent
/// tenants interleave freely. The ledger charges this caller's unique
/// misses (sequential device semantics per tenant); racing tenants may
/// both pay for the same pair once — an honest account of what each
/// tenant's device ran.
pub fn measure_pairs_sharded(
    jobs: &[(&Kernel, &Schedule)],
    contents: &[u64],
    profile: &DeviceProfile,
    seed: u64,
    cache: &ShardedMeasureCache,
    ledger: &mut Ledger,
) -> CachedBatch {
    let mut cache = cache;
    measure_pairs_cached_generic(jobs, contents, profile, seed, &mut cache, ledger)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{content_key, measure_pairs};
    use crate::ir::KernelBuilder;

    fn jobs_and_contents<'a>(
        pairs: &'a [(&'a Kernel, &'a Schedule)],
    ) -> (Vec<(&'a Kernel, &'a Schedule)>, Vec<u64>) {
        let contents = pairs.iter().map(|&(k, s)| content_key(k, s)).collect();
        (pairs.to_vec(), contents)
    }

    #[test]
    fn sharded_matches_unsharded_and_warm_is_free() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k1 = KernelBuilder::dense(256, 256, 256, &[]);
        let k2 = KernelBuilder::dense(512, 512, 512, &[]);
        let s1 = Schedule::untuned_default(&k1);
        let s2 = Schedule::untuned_default(&k2);
        let pairs: Vec<(&Kernel, &Schedule)> = vec![(&k1, &s1), (&k2, &s2), (&k1, &s1)];
        let (jobs, contents) = jobs_and_contents(&pairs);

        let plain = measure_pairs(&jobs, &prof, 7);
        let cache = ShardedMeasureCache::new(4);
        let mut ledger = Ledger::new();
        let cold = measure_pairs_sharded(&jobs, &contents, &prof, 7, &cache, &mut ledger);
        for (a, b) in plain.iter().zip(&cold.outcomes) {
            assert_eq!(a.runtime(), b.runtime(), "sharding must be transparent");
        }
        assert_eq!(ledger.measurements, 2, "duplicate pair measured once");
        assert_eq!(cache.stats().dedup_hits, 1);

        let mut warm_ledger = Ledger::new();
        let warm = measure_pairs_sharded(&jobs, &contents, &prof, 7, &cache, &mut warm_ledger);
        assert_eq!(warm_ledger.seconds, 0.0);
        for (a, b) in plain.iter().zip(&warm.outcomes) {
            assert_eq!(a.runtime(), b.runtime());
        }
    }

    #[test]
    fn from_cache_seeds_shards_and_to_cache_flattens_back() {
        let mut flat = MeasureCache::new();
        for key in 0..64u64 {
            flat.insert(key, if key % 5 == 0 { None } else { Some(key as f64 * 1e-4) });
        }
        let sharded = ShardedMeasureCache::from_cache(&flat, 8);
        assert_eq!(sharded.n_shards(), 8);
        assert_eq!(sharded.len(), 64);
        assert_eq!(sharded.stats(), CacheStats::default(), "seeding is not activity");
        for key in 0..64u64 {
            assert_eq!(sharded.peek(key), flat.peek(key));
        }
        let back = sharded.to_cache();
        assert_eq!(back.len(), 64);
        for key in 0..64u64 {
            assert_eq!(back.peek(key), flat.peek(key));
        }
    }

    #[test]
    fn flat_and_sharded_pipelines_agree_pairwise() {
        // Both entry points are thin wrappers over the same generic
        // body; this pins the API-level contract directly — outcome,
        // key, ledger, and stats parity on identical inputs, cold and
        // warm, including an invalid pair and a duplicate.
        use crate::coordinator::measure_pairs_cached_precomputed;
        let prof = DeviceProfile::xeon_e5_2620();
        let k1 = KernelBuilder::dense(256, 256, 256, &[]);
        let k2 = KernelBuilder::dense(8, 8, 8, &[]);
        let s1 = Schedule::untuned_default(&k1);
        let mut bad = Schedule::untuned_default(&k1);
        bad.spatial[1] = crate::sched::AxisTiling::of(&[64]); // 64 > 8 on k2
        let pairs: Vec<(&Kernel, &Schedule)> =
            vec![(&k1, &s1), (&k2, &bad), (&k1, &s1), (&k2, &bad)];
        let (jobs, contents) = jobs_and_contents(&pairs);

        let mut flat = MeasureCache::new();
        let sharded = ShardedMeasureCache::new(4);
        for round in 0..2 {
            let mut flat_ledger = Ledger::new();
            let mut shard_ledger = Ledger::new();
            let a = measure_pairs_cached_precomputed(
                &jobs,
                &contents,
                &prof,
                7,
                &mut flat,
                &mut flat_ledger,
            );
            let b =
                measure_pairs_sharded(&jobs, &contents, &prof, 7, &sharded, &mut shard_ledger);
            assert_eq!(a.keys, b.keys, "round {round}: key streams diverge");
            for (i, (x, y)) in a.outcomes.iter().zip(&b.outcomes).enumerate() {
                assert_eq!(x.runtime(), y.runtime(), "round {round}, job {i}");
            }
            assert_eq!(flat_ledger.seconds.to_bits(), shard_ledger.seconds.to_bits());
            assert_eq!(flat_ledger.measurements, shard_ledger.measurements);
            assert_eq!(flat_ledger.compile_failures, shard_ledger.compile_failures);
            assert_eq!(flat.stats, sharded.stats(), "round {round}: stats diverge");
        }
    }

    #[test]
    fn single_shard_degenerates_to_global_lock() {
        let cache = ShardedMeasureCache::new(0); // clamped to 1
        assert_eq!(cache.n_shards(), 1);
        cache.insert(9, Some(0.5));
        assert_eq!(cache.peek(9), Some(Some(0.5)));
    }
}
