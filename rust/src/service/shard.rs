//! Sharded measurement cache + concurrent sweep executor.
//!
//! The single-tenant engine owns a `&mut MeasureCache`; a serving
//! deployment has many tenants sweeping against one shared cache. One
//! global lock would serialize them, so the cache is split into N
//! shards (selected by cache-key hash), each behind its own mutex —
//! lookups and inserts take one short per-key lock, and tenants whose
//! working sets land on different shards never contend.
//!
//! Correctness under concurrency comes from the same property that
//! makes the flat cache transparent: a pair's measurement is a pure
//! function of (content, seed, device) — noise is content-derived, not
//! order-derived — so when two tenants race on the same missing pair,
//! both measure the *same* value and the double insert is idempotent.
//! Results are therefore bit-identical to a single-threaded run; only
//! the per-tenant *charged* ledgers (who paid for a shared miss) can
//! vary with interleaving, which is why reported numbers always use the
//! order-independent cold ledger (see `transfer::engine`).

use crate::coordinator::cache::{sweep_key, CacheStats, MeasureCache, Resolution};
use crate::coordinator::pool::{measure_with_noise, noise_seed, CachedBatch, PairOutcome};
use crate::coordinator::Ledger;
use crate::device::DeviceProfile;
use crate::ir::Kernel;
use crate::sched::{apply, ApplyError, Schedule};
use std::collections::HashMap;
use std::sync::Mutex;

/// A [`MeasureCache`] split across `n` independently locked shards.
/// Shards are unbounded (serving caches persist via the artifact store
/// rather than evict).
#[derive(Debug)]
pub struct ShardedMeasureCache {
    shards: Vec<Mutex<MeasureCache>>,
}

impl ShardedMeasureCache {
    pub fn new(n_shards: usize) -> Self {
        let n = n_shards.max(1);
        ShardedMeasureCache {
            shards: (0..n).map(|_| Mutex::new(MeasureCache::new())).collect(),
        }
    }

    /// Distribute a flat snapshot (e.g. a zoo's cache, or one loaded
    /// from the artifact store) across shards.
    pub fn from_cache(cache: &MeasureCache, n_shards: usize) -> Self {
        let sharded = Self::new(n_shards);
        for (key, runtime) in cache.entries_lru() {
            sharded.shard(key).lock().unwrap().insert(key, runtime);
        }
        sharded.reset_stats(); // seeding must not look like activity
        sharded
    }

    fn shard(&self, key: u64) -> &Mutex<MeasureCache> {
        &self.shards[(key % self.shards.len() as u64) as usize]
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn insert(&self, key: u64, runtime: Option<f64>) {
        self.shard(key).lock().unwrap().insert(key, runtime);
    }

    pub fn peek(&self, key: u64) -> Option<Option<f64>> {
        self.shard(key).lock().unwrap().peek(key)
    }

    /// Merged counters across shards.
    pub fn stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for s in &self.shards {
            total.merge(&s.lock().unwrap().stats);
        }
        total
    }

    pub fn reset_stats(&self) {
        for s in &self.shards {
            s.lock().unwrap().reset_stats();
        }
    }

    /// Flatten into one [`MeasureCache`] (for artifact persistence).
    /// Counters are reset on the snapshot — contents, not activity.
    pub fn to_cache(&self) -> MeasureCache {
        let mut flat = MeasureCache::new();
        for s in &self.shards {
            for (key, runtime) in s.lock().unwrap().entries_lru() {
                flat.insert(key, runtime);
            }
        }
        flat.reset_stats();
        flat
    }
}

/// The sharded counterpart of
/// [`measure_pairs_cached_precomputed`](crate::coordinator::measure_pairs_cached_precomputed):
/// same dedup-then-resolve-then-measure pipeline and the same
/// transparency invariant, but each resolution locks only the key's
/// shard, so concurrent tenants interleave freely. The ledger charges
/// this caller's unique misses (sequential device semantics per
/// tenant); racing tenants may both pay for the same pair once — an
/// honest account of what each tenant's device ran.
pub fn measure_pairs_sharded(
    jobs: &[(&Kernel, &Schedule)],
    contents: &[u64],
    profile: &DeviceProfile,
    seed: u64,
    cache: &ShardedMeasureCache,
    ledger: &mut Ledger,
) -> CachedBatch {
    // KEEP IN SYNC with `pool::measure_pairs_cached_precomputed`: same
    // dedup/resolve/measure/charge pipeline, differing only in cache
    // acquisition (per-key shard lock vs `&mut`). Both copies are held
    // to the transparency invariant by `sharded_matches_unsharded...`
    // below and the property tests; a semantic change to either
    // pipeline must land in both.
    assert_eq!(jobs.len(), contents.len());

    /// Where job `i`'s outcome comes from (mirrors the flat executor).
    #[derive(Clone)]
    enum Slot {
        Hit(f64),
        HitInvalid(ApplyError),
        Miss(usize),
    }

    let keys: Vec<u64> = contents.iter().map(|&c| sweep_key(c, seed, profile)).collect();

    let mut slot_of_key: HashMap<u64, usize> = HashMap::new();
    let mut unique_jobs: Vec<(&Kernel, &Schedule)> = Vec::new();
    let mut unique_keys: Vec<u64> = Vec::new();
    let mut unique_noise: Vec<u64> = Vec::new();
    let mut slots: Vec<Slot> = Vec::with_capacity(jobs.len());
    for (ji, &key) in keys.iter().enumerate() {
        if let Some(&si) = slot_of_key.get(&key) {
            cache.shard(key).lock().unwrap().stats.dedup_hits += 1;
            let dup = slots[si].clone();
            slots.push(dup);
            continue;
        }
        let (kernel, sched) = jobs[ji];
        let resolution = {
            // One short per-key critical section; measurement happens
            // outside every lock.
            let mut shard = cache.shard(key).lock().unwrap();
            shard.resolve_with(key, || apply(sched, kernel).map(|_| ()))
        };
        let slot = match resolution {
            Resolution::Hit(t) => Slot::Hit(t),
            Resolution::HitInvalid(e) => Slot::HitInvalid(e),
            Resolution::Corrupt | Resolution::Miss => {
                let u = unique_jobs.len();
                unique_jobs.push(jobs[ji]);
                unique_keys.push(key);
                unique_noise.push(noise_seed(seed, contents[ji]));
                Slot::Miss(u)
            }
        };
        slot_of_key.insert(key, slots.len());
        slots.push(slot);
    }

    let measured = measure_with_noise(&unique_jobs, profile, &unique_noise);
    for (key, outcome) in unique_keys.iter().zip(&measured) {
        match outcome.runtime() {
            Some(t) => ledger.charge_measure(profile, t),
            None => ledger.charge_compile_fail(profile),
        }
        cache.shard(*key).lock().unwrap().insert(*key, outcome.runtime());
    }

    let outcomes: Vec<PairOutcome> = slots
        .into_iter()
        .map(|slot| match slot {
            Slot::Miss(u) => measured[u].clone(),
            Slot::Hit(t) => PairOutcome::Measured(t),
            Slot::HitInvalid(e) => PairOutcome::Invalid(e),
        })
        .collect();
    CachedBatch { outcomes, keys }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{content_key, measure_pairs};
    use crate::ir::KernelBuilder;

    fn jobs_and_contents<'a>(
        pairs: &'a [(&'a Kernel, &'a Schedule)],
    ) -> (Vec<(&'a Kernel, &'a Schedule)>, Vec<u64>) {
        let contents = pairs.iter().map(|&(k, s)| content_key(k, s)).collect();
        (pairs.to_vec(), contents)
    }

    #[test]
    fn sharded_matches_unsharded_and_warm_is_free() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k1 = KernelBuilder::dense(256, 256, 256, &[]);
        let k2 = KernelBuilder::dense(512, 512, 512, &[]);
        let s1 = Schedule::untuned_default(&k1);
        let s2 = Schedule::untuned_default(&k2);
        let pairs: Vec<(&Kernel, &Schedule)> = vec![(&k1, &s1), (&k2, &s2), (&k1, &s1)];
        let (jobs, contents) = jobs_and_contents(&pairs);

        let plain = measure_pairs(&jobs, &prof, 7);
        let cache = ShardedMeasureCache::new(4);
        let mut ledger = Ledger::new();
        let cold = measure_pairs_sharded(&jobs, &contents, &prof, 7, &cache, &mut ledger);
        for (a, b) in plain.iter().zip(&cold.outcomes) {
            assert_eq!(a.runtime(), b.runtime(), "sharding must be transparent");
        }
        assert_eq!(ledger.measurements, 2, "duplicate pair measured once");
        assert_eq!(cache.stats().dedup_hits, 1);

        let mut warm_ledger = Ledger::new();
        let warm = measure_pairs_sharded(&jobs, &contents, &prof, 7, &cache, &mut warm_ledger);
        assert_eq!(warm_ledger.seconds, 0.0);
        for (a, b) in plain.iter().zip(&warm.outcomes) {
            assert_eq!(a.runtime(), b.runtime());
        }
    }

    #[test]
    fn from_cache_seeds_shards_and_to_cache_flattens_back() {
        let mut flat = MeasureCache::new();
        for key in 0..64u64 {
            flat.insert(key, if key % 5 == 0 { None } else { Some(key as f64 * 1e-4) });
        }
        let sharded = ShardedMeasureCache::from_cache(&flat, 8);
        assert_eq!(sharded.n_shards(), 8);
        assert_eq!(sharded.len(), 64);
        assert_eq!(sharded.stats(), CacheStats::default(), "seeding is not activity");
        for key in 0..64u64 {
            assert_eq!(sharded.peek(key), flat.peek(key));
        }
        let back = sharded.to_cache();
        assert_eq!(back.len(), 64);
        for key in 0..64u64 {
            assert_eq!(back.peek(key), flat.peek(key));
        }
    }

    #[test]
    fn single_shard_degenerates_to_global_lock() {
        let cache = ShardedMeasureCache::new(0); // clamped to 1
        assert_eq!(cache.n_shards(), 1);
        cache.insert(9, Some(0.5));
        assert_eq!(cache.peek(9), Some(Some(0.5)));
    }
}
