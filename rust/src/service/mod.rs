//! Multi-tenant schedule serving.
//!
//! The ROADMAP's north star is serving tuned state to many concurrent
//! clients, not re-deriving it per process. A [`ScheduleService`] owns
//! the serving state — an epoch-versioned snapshot of the tuned zoo
//! plus a sharded measurement cache ([`ShardedMeasureCache`]) — and
//! answers *sessions*: a tenant names a target model, a device, and an
//! optional device-seconds budget, and receives the best transferable
//! schedules, the predicted speedup, and full per-kernel provenance.
//!
//! **Zero-copy sessions.** The snapshot precomputes one `Arc`'d
//! sub-store per tuning model; a session composes them into borrowed
//! [`StoreView`]s, so the serving hot path clones **zero**
//! [`StoreRecord`](crate::transfer::StoreRecord)s (counter-guarded by
//! `benches/hotpath.rs` — PR 2 cloned a store slice per session).
//!
//! **Streaming builds.** [`ScheduleService::publish_model`] swaps in a
//! new snapshot with `epoch + 1` the moment one model's tuning lands
//! (see [`ZooProducer`](crate::report::ZooProducer)), so a service can
//! answer sessions while the rest of the zoo is still tuning — the
//! operating point Ansor-style systems aim for. In-flight sessions
//! keep the snapshot `Arc` they started with; they are never torn.
//!
//! **Pre-indexed snapshots.** Everything `open_session` needs that is a
//! pure function of the published snapshot is computed at publish time,
//! not per request: the Eq. 1 class-count tables
//! ([`SourceClassIndex`](crate::transfer::SourceClassIndex)) make
//! ranking a lookup + target-side fold, and every record carries its
//! canonical schedule hash from construction
//! (`StoreRecord::schedule_hash`), so planning a sweep serializes no
//! schedules. Replies are bit-identical to the scanning paths — this
//! moves work, never changes it.
//!
//! Session semantics are deterministic in (request, epoch): the Eq. 1
//! heuristic ranks the snapshot's tuning models, the session sweeps
//! them best-first, and the budget bounds how many sources are swept
//! using the order-independent *standalone* cost (never the charged
//! cost, which depends on what other tenants already warmed). Two
//! tenants issuing the same request against the same epoch therefore
//! always receive bit-identical replies, regardless of interleaving —
//! and a reply at epoch *e* of a streaming build is bit-identical to
//! the reply of a service built statically over the same *e* sources.
//! Proofs live in `rust/tests/service_stress.rs` and
//! `rust/tests/streaming_service.rs`.
//!
//! **Graceful degradation.** Under overload the server sheds rather
//! than stalls: `--max-queue` bounds the decoded-request queue, and a
//! request landing on the full queue is answered immediately with the
//! typed v5 `overloaded` error (carrying a `retry_after_ms` hint) while
//! the connection stays healthy — see [`rpc::overloaded_json`] and the
//! reactor's `ShedHook`. After a crash, `serve` restarted on the same
//! `--cache-dir` reloads every committed artifact (torn temp files are
//! quarantined, never loaded — see `crate::artifact`) and the streaming
//! build resumes tuning only the models the store does not already
//! cover: recovered models are republished at 0 trials.

pub mod fleet;
pub mod reactor;
pub mod rpc;
pub mod shard;
pub mod timer;

pub use shard::{measure_pairs_sharded, ShardedMeasureCache};

use crate::autosched::{CostModel, TuningResult};
use crate::coordinator::{estimator_seed, speculative_seed, CacheStats, Ledger, MeasureCache};
use crate::device::{model_time, DeviceProfile};
use crate::ir::{Kernel, ModelGraph};
use crate::report::Zoo;
use crate::sched::Schedule;
use crate::transfer::engine::{assemble_transfer_result, speculative_sweep};
use crate::transfer::{
    rank_tuning_models_indexed, ScheduleStore, SourceClassIndex, StoreView, SweepPlan,
    TransferOptions, TransferResult,
};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// One tenant's request.
#[derive(Clone, Debug)]
pub struct SessionRequest {
    /// Target model name (any name `models::by_name` accepts).
    pub model: String,
    pub device: DeviceProfile,
    /// Standalone device-seconds the tenant will spend on transfer
    /// sweeps. `None` = unbounded: sweep the full mixed pool (§5.5).
    /// `Some(b)` = sweep ranked tuning models best-first, stopping
    /// before the sweep that would start beyond `b` (the first source
    /// is always swept, so every session returns usable schedules).
    pub budget_s: Option<f64>,
    /// Measurement seed (part of every cache key).
    pub seed: u64,
}

/// Per-kernel outcome + provenance in a [`SessionReply`].
#[derive(Clone, Debug)]
pub struct KernelChoice {
    /// Unique-kernel index in the target graph.
    pub kernel: usize,
    pub class_sig: String,
    /// Tuning model the winning schedule came from (`None` = no
    /// compatible schedule beat the untuned default).
    pub source_model: Option<String>,
    /// Source kernel's shapes (provenance, Fig 4-style labels).
    pub source_input_shape: Vec<u64>,
    /// Standalone time of the selected schedule, seconds.
    pub standalone_s: f64,
    /// The schedule to compile with (untuned default when
    /// `source_model` is `None`).
    pub schedule: Schedule,
}

#[derive(Clone, Debug)]
pub struct SessionReply {
    pub target: String,
    pub device: &'static str,
    pub seed: u64,
    /// Store epoch this session was answered from: the number of
    /// snapshot publishes (streaming builds bump it per landed model).
    /// Replies are a pure function of (target, device, budget, seed,
    /// epoch) — provenance for clients of a still-tuning zoo.
    pub epoch: u64,
    /// Tuning models swept, in heuristic rank order ("mixed" pool =
    /// every ranked source).
    pub sources: Vec<String>,
    pub choices: Vec<KernelChoice>,
    pub untuned_model_s: f64,
    pub tuned_model_s: f64,
    /// Order-independent standalone cost of everything this session
    /// swept (what the session would have cost on a cold cache).
    pub standalone_search_time_s: f64,
    /// Device-seconds this session actually charged (0 when fully
    /// served from the shared cache).
    pub charged_search_time_s: f64,
}

impl SessionReply {
    /// Predicted end-to-end speedup over the untuned target.
    pub fn predicted_speedup(&self) -> f64 {
        self.untuned_model_s / self.tuned_model_s
    }
}

/// One immutable, epoch-versioned view of the tuned zoo. Sessions grab
/// the current snapshot's `Arc` once and serve entirely from it, so a
/// concurrent publish can never tear a reply.
struct Snapshot {
    /// Publish count (0 = empty service; static constructors set it to
    /// the number of distinct sources, which equals what a streaming
    /// build would have reached after publishing the same set).
    epoch: u64,
    /// Graphs of published models (targets resolve here first, then
    /// fall back to the built-in zoo).
    models: Vec<ModelGraph>,
    /// Precomputed per-source sub-stores. Sessions sweep borrowed
    /// [`StoreView`]s over these `Arc`s — the records are cloned once
    /// here, at publish/construction time, and never again.
    sources: BTreeMap<String, Arc<ScheduleStore>>,
    /// The merged store (source-name-major order, identical to a
    /// [`ScheduleStore::add_tuning`] build over the same models) — what
    /// persistence consumes.
    merged: Arc<ScheduleStore>,
    /// Eq. 1's source-side tables, precomputed at publish time so
    /// `open_session` ranks tuning models with lookups + a target-side
    /// fold instead of rescanning every record per request. Bit-identical
    /// ranking to scanning the merged store (`rank_tuning_models`
    /// delegates to the same fold).
    class_index: SourceClassIndex,
}

impl Snapshot {
    fn empty() -> Snapshot {
        Snapshot {
            epoch: 0,
            models: Vec::new(),
            sources: BTreeMap::new(),
            merged: Arc::new(ScheduleStore::new()),
            class_index: SourceClassIndex::default(),
        }
    }

    /// Snapshot a fully-built store (static constructors). Records are
    /// cloned exactly once (into the merged store); the per-source
    /// sub-stores take the input records by move.
    fn from_store(store: ScheduleStore, models: Vec<ModelGraph>) -> Snapshot {
        // Stable partition by source, preserving within-source order.
        let mut groups: BTreeMap<String, ScheduleStore> = BTreeMap::new();
        for r in store.records {
            groups.entry(r.source_model.clone()).or_default().records.push(r);
        }
        // Derive the merged store FROM the partition (source-name-major
        // order) instead of trusting the input to be globally sorted:
        // `view_of` concatenation order and merged order then agree by
        // construction, even for stores assembled with
        // [`ScheduleStore::merge`] (which appends without re-sorting).
        // For an [`ScheduleStore::add_tuning`]-built store this is
        // byte-identical to the input order — and it is exactly how
        // [`ScheduleService::publish_model`] derives its merged store,
        // so static and streaming builds cannot diverge.
        let mut merged = ScheduleStore::new();
        for s in groups.values() {
            merged.records.extend(s.records.iter().cloned());
        }
        let class_index =
            SourceClassIndex::of_sources(groups.iter().map(|(n, s)| (n.as_str(), s)));
        let sources: BTreeMap<String, Arc<ScheduleStore>> =
            groups.into_iter().map(|(name, s)| (name, Arc::new(s))).collect();
        Snapshot {
            epoch: sources.len() as u64,
            models,
            sources,
            merged: Arc::new(merged),
            class_index,
        }
    }

    /// View over the records of `names`, in merged-store order (the
    /// `BTreeMap` iterates sources by name — the leading sort key of
    /// the merged store). Zero records are cloned.
    fn view_of<'a>(&'a self, names: &[String]) -> StoreView<'a> {
        StoreView::concat(
            self.sources
                .iter()
                .filter(|(name, _)| names.iter().any(|n| n == *name))
                .map(|(_, s)| s.as_ref()),
        )
    }
}

struct Inner {
    snapshot: RwLock<Arc<Snapshot>>,
    cache: ShardedMeasureCache,
    /// Learned cost prior for session sweeps' draft stage (untrained by
    /// default = the legacy per-sweep draft model). Server-level
    /// configuration, not wire protocol; a trained prior's content hash
    /// keys speculative sweeps into their own cache space (see
    /// [`crate::coordinator::cache::estimator_seed`]) and is inert at
    /// keep = 1.0. `Arc`-swapped so a live republish can refresh it
    /// without tearing in-flight sessions.
    cost_prior: RwLock<Arc<CostModel>>,
}

/// Construction-time configuration for a [`ScheduleService`]: the PR 10
/// redesign that replaced the post-hoc
/// `with_speculative_keep`/`with_cost_model` chain. Both knobs are
/// consumed in one place, so `serve` and `fleet` build their service in
/// a single expression:
///
/// ```ignore
/// let service = ServiceOptions { speculative_keep: 0.5, cost_model: Some(prior) }
///     .service_from_zoo(zoo, shards);
/// ```
#[derive(Clone, Debug, Default)]
pub struct ServiceOptions {
    /// Draft-then-verify keep fraction for every sweep run through
    /// handles built from these options. `None` (and any value ≥ 1.0)
    /// selects the exact path. Not part of the wire protocol; replies
    /// stay a pure function of (target, device, budget, seed, epoch)
    /// under the configured keep, and pruned sweeps live in their own
    /// cache key space (see
    /// [`crate::coordinator::cache::speculative_seed`]).
    pub speculative_keep: Option<f64>,
    /// Learned cost prior installed at construction (`None` keeps the
    /// untrained default — except in
    /// [`ServiceOptions::service_from_zoo`], where the zoo's own prior
    /// applies).
    pub cost_model: Option<CostModel>,
}

impl ServiceOptions {
    fn keep(&self) -> f64 {
        match self.speculative_keep {
            Some(k) if k < 1.0 => k,
            _ => 1.0,
        }
    }

    fn build(self, snapshot: Snapshot, cache: ShardedMeasureCache) -> ScheduleService {
        let keep = self.keep();
        let prior = self.cost_model.unwrap_or_default();
        ScheduleService {
            inner: Arc::new(Inner {
                snapshot: RwLock::new(Arc::new(snapshot)),
                cache,
                cost_prior: RwLock::new(Arc::new(prior)),
            }),
            speculative_keep: keep,
        }
    }

    /// Build a service from a schedule store + the model graphs it can
    /// serve, with a fresh cache split into `shards`.
    pub fn service(
        self,
        store: ScheduleStore,
        models: Vec<ModelGraph>,
        shards: usize,
    ) -> ScheduleService {
        self.build(Snapshot::from_store(store, models), ShardedMeasureCache::new(shards))
    }

    /// An empty service (epoch 0, no sources): the starting point of a
    /// streaming build — [`ScheduleService::publish_model`] feeds it.
    pub fn empty_service(self, shards: usize) -> ScheduleService {
        self.service_with_cache(&MeasureCache::new(), shards)
    }

    /// [`ServiceOptions::empty_service`], but with the sharded cache
    /// seeded from a flat snapshot (e.g. the measurement cache persisted
    /// under the zoo's artifact key) — a warm `--cache-dir` keeps paying
    /// off across streaming-serve restarts.
    pub fn service_with_cache(self, cache: &MeasureCache, shards: usize) -> ScheduleService {
        self.build(Snapshot::empty(), ShardedMeasureCache::from_cache(cache, shards))
    }

    /// Promote a built zoo into a service: the zoo's store and models
    /// move in, its (possibly artifact-warmed) measurement cache is
    /// redistributed across `shards`, and its learned cost prior (if
    /// any — untrained for `Static` zoos) comes along unless
    /// [`ServiceOptions::cost_model`] overrides it.
    pub fn service_from_zoo(mut self, zoo: Zoo, shards: usize) -> ScheduleService {
        let cache = ShardedMeasureCache::from_cache(&zoo.cache.borrow(), shards);
        let prior = self.cost_model.take().unwrap_or_else(|| zoo.cost_model.into_inner());
        self.cost_model = Some(prior);
        self.build(Snapshot::from_store(zoo.store, zoo.models), cache)
    }
}

/// A shareable handle to the serving state (cheap to clone; all clones
/// serve the same snapshot and sharded cache — the keep fraction alone
/// is per-handle, fixed at construction).
#[derive(Clone)]
pub struct ScheduleService {
    inner: Arc<Inner>,
    /// Draft-then-verify keep fraction for sweeps run through this
    /// handle (1.0 = exact path). A plain field since PR 10 — set by
    /// [`ServiceOptions`] at construction, never mutated.
    speculative_keep: f64,
}

impl ScheduleService {
    /// Build a service from a schedule store + the model graphs it can
    /// serve, with a fresh cache split into `shards`. Shorthand for
    /// [`ServiceOptions::service`] with default options.
    pub fn new(store: ScheduleStore, models: Vec<ModelGraph>, shards: usize) -> ScheduleService {
        ServiceOptions::default().service(store, models, shards)
    }

    /// An empty service (epoch 0, no sources): the starting point of a
    /// streaming build — [`ScheduleService::publish_model`] feeds it.
    pub fn empty(shards: usize) -> ScheduleService {
        ServiceOptions::default().empty_service(shards)
    }

    /// [`ScheduleService::empty`] with a warm cache. Shorthand for
    /// [`ServiceOptions::service_with_cache`] with default options.
    pub fn empty_with_cache(cache: &MeasureCache, shards: usize) -> ScheduleService {
        ServiceOptions::default().service_with_cache(cache, shards)
    }

    /// Promote a built zoo into a service. Shorthand for
    /// [`ServiceOptions::service_from_zoo`] with default options.
    pub fn from_zoo(zoo: Zoo, shards: usize) -> ScheduleService {
        ServiceOptions::default().service_from_zoo(zoo, shards)
    }

    /// Configure the draft-then-verify keep fraction for sweeps run
    /// through the returned handle. Values ≥ 1.0 select the exact path.
    #[deprecated(note = "pass ServiceOptions { speculative_keep, .. } at construction")]
    pub fn with_speculative_keep(mut self, keep: f64) -> ScheduleService {
        self.speculative_keep = if keep < 1.0 { keep } else { 1.0 };
        self
    }

    fn speculative_keep(&self) -> f64 {
        self.speculative_keep
    }

    /// Install a learned cost prior for session sweeps (builder form).
    #[deprecated(note = "pass ServiceOptions { cost_model, .. } at construction")]
    pub fn with_cost_model(self, model: CostModel) -> ScheduleService {
        self.set_cost_model(model);
        self
    }

    /// Swap the learned cost prior on a live service (the republish
    /// path: a re-fit model takes effect for sessions opened from now
    /// on; in-flight sessions keep the `Arc` they already read).
    pub fn set_cost_model(&self, model: CostModel) {
        *self.inner.cost_prior.write().expect("cost prior lock poisoned") = Arc::new(model);
    }

    /// The current learned prior (untrained unless one was installed).
    pub fn cost_model(&self) -> Arc<CostModel> {
        self.inner.cost_prior.read().expect("cost prior lock poisoned").clone()
    }

    fn snapshot(&self) -> Arc<Snapshot> {
        self.inner.snapshot.read().expect("snapshot lock poisoned").clone()
    }

    /// Publish one model's tuning into the serving state and return the
    /// new epoch. This is the streaming-build write path: the model's
    /// sub-store is built once, a fresh snapshot (epoch + 1) is swapped
    /// in, and every session opened from now on sees the new source.
    /// Sessions already in flight keep their snapshot — replies are
    /// never torn across epochs.
    pub fn publish_model(&self, graph: &ModelGraph, tuning: &TuningResult) -> u64 {
        let mut sub = ScheduleStore::new();
        sub.add_tuning(graph, tuning);
        let mut guard = self.inner.snapshot.write().expect("snapshot lock poisoned");
        let old = guard.as_ref();
        let mut sources = old.sources.clone(); // Arc clones, not record clones
        sources.insert(graph.name.clone(), Arc::new(sub));
        // Re-merge in source-name order: byte-identical to a
        // `ScheduleStore::add_tuning` build over the same models
        // (source_model is the leading key of its total sort).
        let mut merged = ScheduleStore::new();
        for s in sources.values() {
            merged.records.extend(s.records.iter().cloned());
        }
        // Re-derive the Eq. 1 tables here, at publish time — sessions
        // opened against this snapshot rank with lookups only.
        let class_index =
            SourceClassIndex::of_sources(sources.iter().map(|(n, s)| (n.as_str(), s.as_ref())));
        let mut models = old.models.clone();
        if !models.iter().any(|m| m.name == graph.name) {
            models.push(graph.clone());
        }
        let epoch = old.epoch + 1;
        *guard = Arc::new(Snapshot {
            epoch,
            models,
            sources,
            merged: Arc::new(merged),
            class_index,
        });
        epoch
    }

    /// The current store epoch (publish count).
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// The current merged-store snapshot (for ranking inspection and
    /// artifact persistence). Cheap: clones an `Arc`, not the store.
    pub fn store(&self) -> Arc<ScheduleStore> {
        self.snapshot().merged.clone()
    }

    /// Names of the sources live in the current snapshot.
    pub fn live_sources(&self) -> Vec<String> {
        self.snapshot().sources.keys().cloned().collect()
    }

    /// Record count of the current merged-store snapshot (admin stats).
    pub fn store_records(&self) -> usize {
        self.snapshot().merged.records.len()
    }

    /// Per-source record counts of the current snapshot, sorted by
    /// source name (admin stats). Cheap: reads the pre-split per-model
    /// sub-stores, no merging.
    pub fn source_record_counts(&self) -> Vec<(String, usize)> {
        self.snapshot()
            .sources
            .iter()
            .map(|(name, store)| (name.clone(), store.records.len()))
            .collect()
    }

    /// Entries resident in the sharded measurement cache (admin stats).
    pub fn cache_len(&self) -> usize {
        self.inner.cache.len()
    }

    /// Whether `name` currently resolves to a servable target (a
    /// published graph or a built-in zoo model) — the same lookup
    /// [`ScheduleService::open_session`] performs, exposed so the RPC
    /// layer can classify `unknown_model` without sniffing error text.
    pub fn can_resolve(&self, name: &str) -> bool {
        Self::target_graph(&self.snapshot(), name).is_ok()
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.inner.cache.stats()
    }

    /// Flat snapshot of the shared cache (for artifact persistence).
    pub fn snapshot_cache(&self) -> MeasureCache {
        self.inner.cache.to_cache()
    }

    fn target_graph(snapshot: &Snapshot, name: &str) -> anyhow::Result<ModelGraph> {
        if let Some(m) = snapshot.models.iter().find(|m| m.name == name) {
            return Ok(m.clone());
        }
        crate::models::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model `{name}`"))
    }

    /// One standalone sweep of `view` onto `target` through the shared
    /// sharded cache.
    fn sweep(
        &self,
        target: &ModelGraph,
        view: &StoreView<'_>,
        label: &str,
        device: &DeviceProfile,
        seed: u64,
    ) -> TransferResult {
        let mut ledger = Ledger::new();
        let keep = self.speculative_keep();
        // Pruned sweeps key their measurements into a keep-specific
        // space: a speculative run misses a warm exact cache rather
        // than colliding with it. A trained prior re-ranks the draft
        // stage, so its content hash gets its own fold — but only when
        // the draft stage runs; at keep = 1.0 the prior is inert and
        // every legacy key survives.
        let prior = self.cost_model();
        let model_hash = if keep < 1.0 { prior.content_hash() } else { 0 };
        let seed = estimator_seed(speculative_seed(seed, keep), model_hash);
        let plan = SweepPlan::build_view(target, view, &TransferOptions::default());
        let (plan, candidates) = if keep >= 1.0 {
            let (candidate_jobs, candidate_contents) = plan.candidate_jobs(target);
            let candidates = measure_pairs_sharded(
                &candidate_jobs,
                &candidate_contents,
                device,
                seed,
                &self.inner.cache,
                &mut ledger,
            );
            (plan, candidates)
        } else {
            let cache = &self.inner.cache;
            let ledger = &mut ledger;
            let mut exec = |jobs: &[(&Kernel, &Schedule)], contents: &[u64]| {
                measure_pairs_sharded(jobs, contents, device, seed, cache, ledger)
            };
            speculative_sweep(target, &plan, device, keep, &prior, &mut exec)
        };
        let (default_jobs, default_contents) = plan.default_jobs(target);
        let defaults = measure_pairs_sharded(
            &default_jobs,
            &default_contents,
            device,
            seed,
            &self.inner.cache,
            &mut ledger,
        );
        assemble_transfer_result(target, &plan, candidates, defaults, ledger, device, label)
    }

    /// Serve one session. See [`SessionRequest`] for the budget
    /// semantics; the reply is a pure function of (request, epoch). The
    /// whole session runs against one snapshot `Arc` — publishes that
    /// land mid-session do not affect it — and sweeps borrowed
    /// [`StoreView`]s, never cloning a store record.
    pub fn open_session(&self, req: &SessionRequest) -> anyhow::Result<SessionReply> {
        let snapshot = self.snapshot();
        let target = Self::target_graph(&snapshot, &req.model)?;
        let ranked = rank_tuning_models_indexed(&target, &snapshot.class_index, &req.device);
        let ranked_names: Vec<String> = ranked.into_iter().map(|(name, _)| name).collect();

        // Which sources to sweep, and the per-sweep results.
        let mut swept: Vec<String> = Vec::new();
        let mut results: Vec<(TransferResult, StoreView<'_>)> = Vec::new();
        match req.budget_s {
            None => {
                // Unbounded: one mixed-pool sweep over every source.
                let view = snapshot.view_of(&ranked_names);
                let res = self.sweep(&target, &view, "mixed", &req.device, req.seed);
                swept = ranked_names;
                results.push((res, view));
            }
            Some(budget) => {
                let mut spent = 0.0f64;
                for name in &ranked_names {
                    if !swept.is_empty() && spent >= budget {
                        break;
                    }
                    let view = snapshot.view_of(std::slice::from_ref(name));
                    let res = self.sweep(&target, &view, name, &req.device, req.seed);
                    spent += res.standalone_search_time_s();
                    swept.push(name.clone());
                    results.push((res, view));
                }
            }
        }

        // Merge per-kernel winners across the swept sources (best
        // standalone time; earlier-ranked source wins exact ties).
        let mut choices: Vec<KernelChoice> = Vec::with_capacity(target.kernels.len());
        for (ki, kernel) in target.kernels.iter().enumerate() {
            let untuned_s = results
                .first()
                .map(|(r, _)| r.sweeps[ki].untuned_s)
                .unwrap_or_else(|| {
                    // Empty store (no sources at all): measure nothing,
                    // report the deterministic untuned time.
                    crate::device::untuned_kernel_times(&target, &req.device)[ki]
                });
            let mut choice = KernelChoice {
                kernel: ki,
                class_sig: kernel.class_signature(),
                source_model: None,
                source_input_shape: kernel.input_shape.clone(),
                standalone_s: untuned_s,
                schedule: Schedule::untuned_default(kernel),
            };
            for (res, view) in &results {
                let sweep = &res.sweeps[ki];
                if let (Some(ri), Some(sched)) = (sweep.chosen, &sweep.chosen_schedule) {
                    if sweep.chosen_s < choice.standalone_s {
                        let rec = view.records[ri];
                        choice.source_model = Some(rec.source_model.clone());
                        choice.source_input_shape = rec.source_input_shape.clone();
                        choice.standalone_s = sweep.chosen_s;
                        choice.schedule = sched.clone();
                    }
                }
            }
            choices.push(choice);
        }

        let tuned_model_s = if results.len() == 1 {
            // Single sweep: identical to the engine's own compile.
            results[0].0.tuned_model_s
        } else {
            model_time(&target, &req.device, |k| choices[k].schedule.clone())
        };
        let untuned_model_s = results
            .first()
            .map(|(r, _)| r.untuned_model_s)
            .unwrap_or_else(|| crate::device::untuned_model_time(&target, &req.device));

        Ok(SessionReply {
            target: target.name.clone(),
            device: req.device.name,
            seed: req.seed,
            epoch: snapshot.epoch,
            sources: swept,
            choices,
            untuned_model_s,
            tuned_model_s,
            standalone_search_time_s: results
                .iter()
                .map(|(r, _)| r.standalone_search_time_s())
                .sum(),
            charged_search_time_s: results.iter().map(|(r, _)| r.search_time_s()).sum(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::KernelBuilder;

    fn dense_service() -> ScheduleService {
        let prof = DeviceProfile::xeon_e5_2620();
        let opts = TuneOptions {
            trials: 96,
            batch_size: 16,
            population: 32,
            generations: 2,
            ..Default::default()
        };
        let mut store = ScheduleStore::new();
        let mut models = Vec::new();
        for (name, n) in [("SrcA", 512u64), ("SrcB", 1024u64)] {
            let mut g = ModelGraph::new(name);
            g.push(KernelBuilder::dense(n, n, n, &[]));
            let res = tune_model(&g, &prof, &opts);
            store.add_tuning(&g, &res);
            models.push(g);
        }
        let mut target = ModelGraph::new("TargetDense");
        target.push(KernelBuilder::dense(768, 768, 768, &[]));
        models.push(target);
        ScheduleService::new(store, models, 4)
    }

    fn request(budget: Option<f64>) -> SessionRequest {
        SessionRequest {
            model: "TargetDense".into(),
            device: DeviceProfile::xeon_e5_2620(),
            budget_s: budget,
            seed: 9,
        }
    }

    #[test]
    fn session_returns_schedules_with_provenance() {
        let svc = dense_service();
        let reply = svc.open_session(&request(None)).unwrap();
        assert_eq!(reply.target, "TargetDense");
        assert_eq!(reply.sources.len(), 2, "mixed pool sweeps every source");
        assert_eq!(reply.choices.len(), 1);
        let c = &reply.choices[0];
        assert!(c.source_model.is_some(), "dense schedules must transfer");
        assert!(reply.predicted_speedup() > 1.0);
        assert!(reply.standalone_search_time_s > 0.0);
    }

    #[test]
    #[allow(deprecated)] // wrapper coverage: the with_* chain must keep working
    fn speculative_sessions_are_deterministic_and_key_separated() {
        let svc = dense_service();
        let exact = svc.open_session(&request(None)).unwrap();
        assert!(exact.charged_search_time_s > 0.0, "cold exact session must charge");
        // The keep is per-handle (the snapshot and cache stay shared),
        // so this clone alone runs speculative sweeps, keyed into the
        // keep-specific cache space.
        let spec = svc.clone().with_speculative_keep(0.5);
        let a = spec.open_session(&request(None)).unwrap();
        assert!(
            a.charged_search_time_s > 0.0,
            "pruned sweeps must miss the exact run's cache entries, not collide"
        );
        let b = spec.open_session(&request(None)).unwrap();
        assert_eq!(b.charged_search_time_s, 0.0, "same-keep rerun is fully warm");
        assert_eq!(a.tuned_model_s.to_bits(), b.tuned_model_s.to_bits());
        assert_eq!(a.standalone_search_time_s.to_bits(), b.standalone_search_time_s.to_bits());
        assert!(a.tuned_model_s <= a.untuned_model_s);
    }

    /// Any trained model will do: key separation depends only on the
    /// content hash being nonzero.
    fn test_prior() -> CostModel {
        use crate::autosched::{GbdtParams, NUM_FEATURES};
        let mut rng = crate::util::rng::Rng::new(5);
        let xs: Vec<[f64; NUM_FEATURES]> = (0..64)
            .map(|_| {
                let mut x = [0.0; NUM_FEATURES];
                for v in x.iter_mut() {
                    *v = rng.f64() * 4.0;
                }
                x
            })
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| x[1] - x[4]).collect();
        let m = CostModel::train(&xs, &ys, &GbdtParams::default());
        assert!(m.is_trained());
        m
    }

    #[test]
    #[allow(deprecated)] // wrapper coverage: the with_* chain must keep working
    fn trained_prior_rekeys_speculative_sessions_and_is_inert_when_exact() {
        // Exact path: installing a trained prior changes nothing — the
        // second session is served entirely from the first one's cache.
        let svc = dense_service();
        let before = svc.open_session(&request(None)).unwrap();
        let svc = svc.with_cost_model(test_prior());
        let after = svc.open_session(&request(None)).unwrap();
        assert_eq!(after.tuned_model_s.to_bits(), before.tuned_model_s.to_bits());
        assert_eq!(after.charged_search_time_s, 0.0, "prior must be inert at keep=1.0");

        // Speculative path: the trained prior folds into the cache key
        // space, so primed sweeps miss untrained-prior entries.
        let svc = dense_service().with_speculative_keep(0.5);
        let plain = svc.open_session(&request(None)).unwrap();
        assert!(plain.charged_search_time_s > 0.0);
        let svc = svc.with_cost_model(test_prior());
        let primed = svc.open_session(&request(None)).unwrap();
        assert!(
            primed.charged_search_time_s > 0.0,
            "primed sweeps must not be served from untrained-prior entries"
        );
        let again = svc.open_session(&request(None)).unwrap();
        assert_eq!(again.charged_search_time_s, 0.0, "same-prior rerun is fully warm");
        assert_eq!(again.tuned_model_s.to_bits(), primed.tuned_model_s.to_bits());
    }

    #[test]
    fn zero_budget_sweeps_exactly_the_first_choice() {
        let svc = dense_service();
        let reply = svc.open_session(&request(Some(0.0))).unwrap();
        assert_eq!(reply.sources.len(), 1, "always sweep the first source, never more");
        let unbounded = svc.open_session(&request(None)).unwrap();
        assert!(reply.standalone_search_time_s <= unbounded.standalone_search_time_s);
        // More budget can only improve (or tie) each kernel's
        // standalone pick (end-to-end comparisons would be confounded
        // by inter-kernel boundary effects).
        for (u, m) in unbounded.choices.iter().zip(&reply.choices) {
            assert!(u.standalone_s <= m.standalone_s + 1e-12);
        }
    }

    #[test]
    fn warm_cache_never_changes_a_reply() {
        let svc = dense_service();
        let first = svc.open_session(&request(None)).unwrap();
        let second = svc.open_session(&request(None)).unwrap();
        assert_eq!(first.tuned_model_s.to_bits(), second.tuned_model_s.to_bits());
        assert_eq!(
            first.standalone_search_time_s.to_bits(),
            second.standalone_search_time_s.to_bits(),
            "standalone cost is order-independent"
        );
        assert_eq!(second.charged_search_time_s, 0.0, "second tenant rides the cache");
        assert!(first.charged_search_time_s > 0.0);
    }

    #[test]
    fn unknown_model_is_an_error() {
        let svc = dense_service();
        let mut req = request(None);
        req.model = "NoSuchModel".into();
        assert!(svc.open_session(&req).is_err());
    }

    #[test]
    fn static_service_epoch_counts_sources() {
        let svc = dense_service();
        assert_eq!(svc.epoch(), 2, "one epoch per distinct source");
        let reply = svc.open_session(&request(None)).unwrap();
        assert_eq!(reply.epoch, 2);
        assert_eq!(svc.live_sources(), vec!["SrcA".to_string(), "SrcB".to_string()]);
    }

    #[test]
    fn publishing_streams_sources_in() {
        let prof = DeviceProfile::xeon_e5_2620();
        let opts = TuneOptions {
            trials: 96,
            batch_size: 16,
            population: 32,
            generations: 2,
            ..Default::default()
        };
        let svc = ScheduleService::empty(2);
        assert_eq!(svc.epoch(), 0);

        // Target published first: resolvable, but no foreign sources
        // yet — the session falls back to untuned defaults at epoch 1.
        let mut target = ModelGraph::new("StreamTarget");
        target.push(KernelBuilder::dense(768, 768, 768, &[]));
        let target_tuning = tune_model(&target, &prof, &opts);
        assert_eq!(svc.publish_model(&target, &target_tuning), 1);
        let req = SessionRequest {
            model: "StreamTarget".into(),
            device: prof.clone(),
            budget_s: None,
            seed: 9,
        };
        let bare = svc.open_session(&req).unwrap();
        assert_eq!(bare.epoch, 1);
        assert!(bare.sources.is_empty(), "no foreign sources at epoch 1");
        assert!(bare.choices[0].source_model.is_none());

        // One source lands: the same request now sweeps it.
        let mut src = ModelGraph::new("StreamSrc");
        src.push(KernelBuilder::dense(512, 512, 512, &[]));
        let src_tuning = tune_model(&src, &prof, &opts);
        assert_eq!(svc.publish_model(&src, &src_tuning), 2);
        let served = svc.open_session(&req).unwrap();
        assert_eq!(served.epoch, 2);
        assert_eq!(served.sources, vec!["StreamSrc".to_string()]);
        assert!(served.choices[0].source_model.is_some());

        // Streaming vs static at the same source set: bit-identical
        // replies with the same epoch.
        let mut store = ScheduleStore::new();
        store.add_tuning(&target, &target_tuning);
        store.add_tuning(&src, &src_tuning);
        let static_svc =
            ScheduleService::new(store, vec![target.clone(), src.clone()], 2);
        let static_reply = static_svc.open_session(&req).unwrap();
        assert_eq!(static_reply.epoch, served.epoch);
        assert_eq!(static_reply.sources, served.sources);
        assert_eq!(static_reply.tuned_model_s.to_bits(), served.tuned_model_s.to_bits());
        assert_eq!(
            static_reply.standalone_search_time_s.to_bits(),
            served.standalone_search_time_s.to_bits()
        );
        assert_eq!(static_reply.choices[0].schedule, served.choices[0].schedule);
    }
}
