//! Multi-tenant schedule serving.
//!
//! The ROADMAP's north star is serving tuned state to many concurrent
//! clients, not re-deriving it per process. A [`ScheduleService`] owns
//! one shared zoo of tuned schedules behind an `Arc` — the merged
//! [`ScheduleStore`] plus a sharded measurement cache
//! ([`ShardedMeasureCache`]) — and answers *sessions*: a tenant names a
//! target model, a device, and an optional device-seconds budget, and
//! receives the best transferable schedules, the predicted speedup, and
//! full per-kernel provenance.
//!
//! Session semantics are deterministic in the request alone: the Eq. 1
//! heuristic ranks tuning models, the session sweeps them best-first,
//! and the budget bounds how many sources are swept using the
//! order-independent *standalone* cost (never the charged cost, which
//! depends on what other tenants already warmed). Two tenants issuing
//! the same request therefore always receive bit-identical replies,
//! regardless of interleaving — the concurrency proof lives in
//! `rust/tests/service_stress.rs`.

pub mod shard;

pub use shard::{measure_pairs_sharded, ShardedMeasureCache};

use crate::coordinator::{CacheStats, Ledger, MeasureCache};
use crate::device::{model_time, DeviceProfile};
use crate::ir::ModelGraph;
use crate::report::Zoo;
use crate::sched::Schedule;
use crate::transfer::engine::assemble_transfer_result;
use crate::transfer::{
    rank_tuning_models, ScheduleStore, SweepPlan, TransferOptions, TransferResult,
};
use std::sync::Arc;

/// One tenant's request.
#[derive(Clone, Debug)]
pub struct SessionRequest {
    /// Target model name (any name `models::by_name` accepts).
    pub model: String,
    pub device: DeviceProfile,
    /// Standalone device-seconds the tenant will spend on transfer
    /// sweeps. `None` = unbounded: sweep the full mixed pool (§5.5).
    /// `Some(b)` = sweep ranked tuning models best-first, stopping
    /// before the sweep that would start beyond `b` (the first source
    /// is always swept, so every session returns usable schedules).
    pub budget_s: Option<f64>,
    /// Measurement seed (part of every cache key).
    pub seed: u64,
}

/// Per-kernel outcome + provenance in a [`SessionReply`].
#[derive(Clone, Debug)]
pub struct KernelChoice {
    /// Unique-kernel index in the target graph.
    pub kernel: usize,
    pub class_sig: String,
    /// Tuning model the winning schedule came from (`None` = no
    /// compatible schedule beat the untuned default).
    pub source_model: Option<String>,
    /// Source kernel's shapes (provenance, Fig 4-style labels).
    pub source_input_shape: Vec<u64>,
    /// Standalone time of the selected schedule, seconds.
    pub standalone_s: f64,
    /// The schedule to compile with (untuned default when
    /// `source_model` is `None`).
    pub schedule: Schedule,
}

#[derive(Clone, Debug)]
pub struct SessionReply {
    pub target: String,
    pub device: &'static str,
    pub seed: u64,
    /// Tuning models swept, in heuristic rank order ("mixed" pool =
    /// every ranked source).
    pub sources: Vec<String>,
    pub choices: Vec<KernelChoice>,
    pub untuned_model_s: f64,
    pub tuned_model_s: f64,
    /// Order-independent standalone cost of everything this session
    /// swept (what the session would have cost on a cold cache).
    pub standalone_search_time_s: f64,
    /// Device-seconds this session actually charged (0 when fully
    /// served from the shared cache).
    pub charged_search_time_s: f64,
}

impl SessionReply {
    /// Predicted end-to-end speedup over the untuned target.
    pub fn predicted_speedup(&self) -> f64 {
        self.untuned_model_s / self.tuned_model_s
    }
}

struct Inner {
    models: Vec<ModelGraph>,
    store: ScheduleStore,
    cache: ShardedMeasureCache,
}

/// A shareable handle to the serving state (cheap to clone; all clones
/// serve the same store and sharded cache).
#[derive(Clone)]
pub struct ScheduleService {
    inner: Arc<Inner>,
}

impl ScheduleService {
    /// Build a service from a schedule store + the model graphs it can
    /// serve, with a fresh cache split into `shards`.
    pub fn new(store: ScheduleStore, models: Vec<ModelGraph>, shards: usize) -> ScheduleService {
        ScheduleService {
            inner: Arc::new(Inner { models, store, cache: ShardedMeasureCache::new(shards) }),
        }
    }

    /// Promote a built zoo into a service: the zoo's store and models
    /// move in, and its (possibly artifact-warmed) measurement cache is
    /// redistributed across `shards`.
    pub fn from_zoo(zoo: Zoo, shards: usize) -> ScheduleService {
        let cache = ShardedMeasureCache::from_cache(&zoo.cache.borrow(), shards);
        ScheduleService {
            inner: Arc::new(Inner { models: zoo.models, store: zoo.store, cache }),
        }
    }

    pub fn store(&self) -> &ScheduleStore {
        &self.inner.store
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.inner.cache.stats()
    }

    /// Flat snapshot of the shared cache (for artifact persistence).
    pub fn snapshot_cache(&self) -> MeasureCache {
        self.inner.cache.to_cache()
    }

    fn target_graph(&self, name: &str) -> anyhow::Result<ModelGraph> {
        if let Some(m) = self.inner.models.iter().find(|m| m.name == name) {
            return Ok(m.clone());
        }
        crate::models::by_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model `{name}`"))
    }

    /// Store slice holding the records of `sources` (in store order —
    /// deterministic sweep plans).
    fn slice_of(&self, sources: &[String]) -> ScheduleStore {
        ScheduleStore {
            records: self
                .inner
                .store
                .records
                .iter()
                .filter(|r| sources.iter().any(|s| *s == r.source_model))
                .cloned()
                .collect(),
        }
    }

    /// One standalone sweep of `slice` onto `target` through the shared
    /// sharded cache.
    fn sweep(
        &self,
        target: &ModelGraph,
        slice: &ScheduleStore,
        label: &str,
        device: &DeviceProfile,
        seed: u64,
    ) -> TransferResult {
        let mut ledger = Ledger::new();
        let plan = SweepPlan::build(target, slice, &TransferOptions::default());
        let (candidate_jobs, candidate_contents) = plan.candidate_jobs(target);
        let candidates = measure_pairs_sharded(
            &candidate_jobs,
            &candidate_contents,
            device,
            seed,
            &self.inner.cache,
            &mut ledger,
        );
        let (default_jobs, default_contents) = plan.default_jobs(target);
        let defaults = measure_pairs_sharded(
            &default_jobs,
            &default_contents,
            device,
            seed,
            &self.inner.cache,
            &mut ledger,
        );
        assemble_transfer_result(target, &plan, candidates, defaults, ledger, device, label)
    }

    /// Serve one session. See [`SessionRequest`] for the budget
    /// semantics; the reply is a pure function of the request.
    pub fn open_session(&self, req: &SessionRequest) -> anyhow::Result<SessionReply> {
        let target = self.target_graph(&req.model)?;
        let ranked = rank_tuning_models(&target, &self.inner.store, &req.device);
        let ranked_names: Vec<String> = ranked.into_iter().map(|(name, _)| name).collect();

        // Which sources to sweep, and the per-sweep results.
        let mut swept: Vec<String> = Vec::new();
        let mut results: Vec<(TransferResult, ScheduleStore)> = Vec::new();
        match req.budget_s {
            None => {
                // Unbounded: one mixed-pool sweep over every source.
                let slice = self.slice_of(&ranked_names);
                let res = self.sweep(&target, &slice, "mixed", &req.device, req.seed);
                swept = ranked_names;
                results.push((res, slice));
            }
            Some(budget) => {
                let mut spent = 0.0f64;
                for name in &ranked_names {
                    if !swept.is_empty() && spent >= budget {
                        break;
                    }
                    let slice = self.slice_of(std::slice::from_ref(name));
                    let res = self.sweep(&target, &slice, name, &req.device, req.seed);
                    spent += res.standalone_search_time_s();
                    swept.push(name.clone());
                    results.push((res, slice));
                }
            }
        }

        // Merge per-kernel winners across the swept sources (best
        // standalone time; earlier-ranked source wins exact ties).
        let mut choices: Vec<KernelChoice> = Vec::with_capacity(target.kernels.len());
        for (ki, kernel) in target.kernels.iter().enumerate() {
            let untuned_s = results
                .first()
                .map(|(r, _)| r.sweeps[ki].untuned_s)
                .unwrap_or_else(|| {
                    // Empty store (no sources at all): measure nothing,
                    // report the deterministic untuned time.
                    crate::device::untuned_kernel_times(&target, &req.device)[ki]
                });
            let mut choice = KernelChoice {
                kernel: ki,
                class_sig: kernel.class_signature(),
                source_model: None,
                source_input_shape: kernel.input_shape.clone(),
                standalone_s: untuned_s,
                schedule: Schedule::untuned_default(kernel),
            };
            for (res, slice) in &results {
                let sweep = &res.sweeps[ki];
                if let (Some(ri), Some(sched)) = (sweep.chosen, &sweep.chosen_schedule) {
                    if sweep.chosen_s < choice.standalone_s {
                        let rec = &slice.records[ri];
                        choice.source_model = Some(rec.source_model.clone());
                        choice.source_input_shape = rec.source_input_shape.clone();
                        choice.standalone_s = sweep.chosen_s;
                        choice.schedule = sched.clone();
                    }
                }
            }
            choices.push(choice);
        }

        let tuned_model_s = if results.len() == 1 {
            // Single sweep: identical to the engine's own compile.
            results[0].0.tuned_model_s
        } else {
            model_time(&target, &req.device, |k| choices[k].schedule.clone())
        };
        let untuned_model_s = results
            .first()
            .map(|(r, _)| r.untuned_model_s)
            .unwrap_or_else(|| crate::device::untuned_model_time(&target, &req.device));

        Ok(SessionReply {
            target: target.name.clone(),
            device: req.device.name,
            seed: req.seed,
            sources: swept,
            choices,
            untuned_model_s,
            tuned_model_s,
            standalone_search_time_s: results
                .iter()
                .map(|(r, _)| r.standalone_search_time_s())
                .sum(),
            charged_search_time_s: results.iter().map(|(r, _)| r.search_time_s()).sum(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::KernelBuilder;

    fn dense_service() -> ScheduleService {
        let prof = DeviceProfile::xeon_e5_2620();
        let opts = TuneOptions {
            trials: 96,
            batch_size: 16,
            population: 32,
            generations: 2,
            ..Default::default()
        };
        let mut store = ScheduleStore::new();
        let mut models = Vec::new();
        for (name, n) in [("SrcA", 512u64), ("SrcB", 1024u64)] {
            let mut g = ModelGraph::new(name);
            g.push(KernelBuilder::dense(n, n, n, &[]));
            let res = tune_model(&g, &prof, &opts);
            store.add_tuning(&g, &res);
            models.push(g);
        }
        let mut target = ModelGraph::new("TargetDense");
        target.push(KernelBuilder::dense(768, 768, 768, &[]));
        models.push(target);
        ScheduleService::new(store, models, 4)
    }

    fn request(budget: Option<f64>) -> SessionRequest {
        SessionRequest {
            model: "TargetDense".into(),
            device: DeviceProfile::xeon_e5_2620(),
            budget_s: budget,
            seed: 9,
        }
    }

    #[test]
    fn session_returns_schedules_with_provenance() {
        let svc = dense_service();
        let reply = svc.open_session(&request(None)).unwrap();
        assert_eq!(reply.target, "TargetDense");
        assert_eq!(reply.sources.len(), 2, "mixed pool sweeps every source");
        assert_eq!(reply.choices.len(), 1);
        let c = &reply.choices[0];
        assert!(c.source_model.is_some(), "dense schedules must transfer");
        assert!(reply.predicted_speedup() > 1.0);
        assert!(reply.standalone_search_time_s > 0.0);
    }

    #[test]
    fn zero_budget_sweeps_exactly_the_first_choice() {
        let svc = dense_service();
        let reply = svc.open_session(&request(Some(0.0))).unwrap();
        assert_eq!(reply.sources.len(), 1, "always sweep the first source, never more");
        let unbounded = svc.open_session(&request(None)).unwrap();
        assert!(reply.standalone_search_time_s <= unbounded.standalone_search_time_s);
        // More budget can only improve (or tie) each kernel's
        // standalone pick (end-to-end comparisons would be confounded
        // by inter-kernel boundary effects).
        for (u, m) in unbounded.choices.iter().zip(&reply.choices) {
            assert!(u.standalone_s <= m.standalone_s + 1e-12);
        }
    }

    #[test]
    fn warm_cache_never_changes_a_reply() {
        let svc = dense_service();
        let first = svc.open_session(&request(None)).unwrap();
        let second = svc.open_session(&request(None)).unwrap();
        assert_eq!(first.tuned_model_s.to_bits(), second.tuned_model_s.to_bits());
        assert_eq!(
            first.standalone_search_time_s.to_bits(),
            second.standalone_search_time_s.to_bits(),
            "standalone cost is order-independent"
        );
        assert_eq!(second.charged_search_time_s, 0.0, "second tenant rides the cache");
        assert!(first.charged_search_time_s > 0.0);
    }

    #[test]
    fn unknown_model_is_an_error() {
        let svc = dense_service();
        let mut req = request(None);
        req.model = "NoSuchModel".into();
        assert!(svc.open_session(&req).is_err());
    }
}
