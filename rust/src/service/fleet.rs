//! Fleet serving: a consistent-hash router over N backend serve
//! instances (`repro fleet --listen ADDR --instance ADDR...`).
//!
//! The paper's value proposition is amortization — schedules tuned once
//! are reused across models (Transfer-Tuning §1) — so a production
//! deployment serves one shared zoo to many tenants. PR 7's reactor
//! made a *single* instance scale to thousands of connections; this
//! module is the layer above it: a router process that spreads the
//! session keyspace across a fleet of those instances and keeps the
//! end-to-end determinism invariant intact.
//!
//! **Placement.** Requests are routed by their `(model, device)` pair —
//! the two request fields that select which schedules a session sweeps.
//! The pair is hashed onto a [`HashRing`] of [`VNODES_PER_INSTANCE`]
//! FNV-derived virtual nodes per instance. The ring is built over the
//! *sorted, deduplicated* instance list, so placement is a pure
//! function of the instance *set*: reordering `--instance` flags (or
//! restarting the router) never moves a key.
//!
//! **Transparency.** The router is a v5+ proxy: a forwarded reply is
//! returned to the client byte-for-byte as the backend produced it
//! (both sides speak [`rpc::encode_frame`] framing). Combined with the
//! service determinism invariant — replies are pure in (target, device,
//! budget, seed, epoch) — a routed session is bit-identical to the same
//! request against a single instance over the union of the fleet's
//! sources at the same epoch. `rust/tests/fleet.rs` pins this.
//!
//! **Failure handling.** Two signals demote an instance, both
//! deterministic in what the client observes:
//!
//! * A typed `overloaded` reply is a *redirect*: the router tries the
//!   key's next ring successor. Only if every candidate is shedding
//!   does the client see the (last) `overloaded` reply — the backoff
//!   hint then reflects a genuinely saturated fleet.
//! * A connect/forward I/O failure marks the instance *down*: the
//!   request rehashes to the successor (deterministically — the ring
//!   order for a key is fixed), and the instance is probed again only
//!   after a seeded exponential backoff ([`PROBE_BASE_MS`], jitter
//!   derived from FNV of the address, no wall-clock randomness). When
//!   every candidate is down the client gets the v6 `fleet_unavailable`
//!   error.
//!
//! **Convergence.** The router moves bytes, never artifacts. Epoch
//! reconciliation across the fleet is driven out-of-band by
//! `repro fleet sync`, which pairwise [`ArtifactStore::merge_from`]s
//! the instances' cache dirs (see [`crate::artifact::sync_stores`]) and
//! then issues `republish --all` per instance — after which every
//! instance answers epoch-stamped-identical sessions.
//!
//! [`ArtifactStore::merge_from`]: crate::artifact::ArtifactStore::merge_from

use super::reactor::{self, Reactor, ReactorConfig, ServerGauges, ShedHook};
use super::rpc::{
    self, admin_ack_json, error_json, overloaded_json, RpcError, ServerStats, MAX_FRAME_LEN,
    WIRE_PROTOCOL_VERSION,
};
use crate::ir::workload::fnv1a;
use crate::util::json::{self, Json};
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Virtual nodes per instance on the ring. Enough that the keyspace
/// split stays near-uniform for small fleets (the deployment target is
/// 2–16 instances), small enough that ring construction and successor
/// walks are trivially cheap.
pub const VNODES_PER_INSTANCE: usize = 64;

/// First-retry delay after an instance is marked down. Doubles per
/// consecutive failure (capped at [`PROBE_MAX_MS`]), plus a
/// deterministic jitter seeded from the instance address — probes
/// de-synchronize across routers without any wall-clock randomness.
pub const PROBE_BASE_MS: u64 = 500;

/// Ceiling on the down-instance probe backoff.
pub const PROBE_MAX_MS: u64 = 8_000;

/// The routing key of a request payload: the `(model, device)` pair
/// that selects which schedules a session sweeps, joined on a unit
/// separator (neither field may contain control characters, so the
/// pairing is injective). A missing field keys as the empty string —
/// requests the backends will reject still route deterministically.
/// A payload that is not JSON keys as itself: any backend answers it
/// with the same `bad_json` error, so transparency holds regardless.
pub fn routing_key(payload: &str) -> String {
    match json::parse(payload) {
        Ok(j) => {
            let model = j.get("model").and_then(|v| v.as_str()).unwrap_or("");
            let device = j.get("device").and_then(|v| v.as_str()).unwrap_or("");
            format!("{model}\u{1f}{device}")
        }
        Err(_) => payload.to_string(),
    }
}

/// A consistent-hash ring over an instance set. Construction sorts and
/// dedups the addresses, so two rings over the same *set* of instances
/// are identical regardless of the order the `--instance` flags came
/// in — the placement stability the fleet determinism test pins.
#[derive(Clone, Debug)]
pub struct HashRing {
    instances: Vec<String>,
    /// `(point_hash, instance_index)`, sorted. The index tiebreak makes
    /// the walk order total even under (astronomically unlikely) hash
    /// collisions.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    pub fn new(instances: &[String]) -> HashRing {
        let mut instances: Vec<String> = instances.to_vec();
        instances.sort();
        instances.dedup();
        let mut points = Vec::with_capacity(instances.len() * VNODES_PER_INSTANCE);
        for (idx, inst) in instances.iter().enumerate() {
            for vnode in 0..VNODES_PER_INSTANCE {
                points.push((fnv1a(format!("{inst}#{vnode}").as_bytes()), idx));
            }
        }
        points.sort_unstable();
        HashRing { instances, points }
    }

    /// The sorted, deduplicated instance addresses (ring order).
    pub fn instances(&self) -> &[String] {
        &self.instances
    }

    pub fn len(&self) -> usize {
        self.instances.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instances.is_empty()
    }

    /// Total virtual-node points on the ring.
    pub fn points(&self) -> usize {
        self.points.len()
    }

    /// Every instance index that can serve `key`, in deterministic
    /// failover order: the clockwise successor walk from the key's hash,
    /// keeping the first occurrence of each instance. The first element
    /// is the key's primary; killing it promotes exactly the second —
    /// rehash is a pop, never a reshuffle.
    pub fn candidates(&self, key: &str) -> Vec<usize> {
        if self.points.is_empty() {
            return Vec::new();
        }
        let h = fnv1a(key.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = vec![false; self.instances.len()];
        let mut order = Vec::with_capacity(self.instances.len());
        for i in 0..self.points.len() {
            let (_, idx) = self.points[(start + i) % self.points.len()];
            if !seen[idx] {
                seen[idx] = true;
                order.push(idx);
                if order.len() == self.instances.len() {
                    break;
                }
            }
        }
        order
    }

    /// The key's primary instance index.
    pub fn primary(&self, key: &str) -> Option<usize> {
        self.candidates(key).first().copied()
    }
}

/// Forwarding-side knobs (the listening side reuses
/// [`rpc::ServerConfig`]).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Backend connect deadline; expiry (or refusal) marks the instance
    /// down.
    pub connect_timeout: Duration,
    /// Per-forward read/write deadline on the backend socket.
    pub forward_timeout: Duration,
    /// Listening-side knobs, identical semantics to a backend server's.
    pub server: rpc::ServerConfig,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            connect_timeout: Duration::from_millis(1_000),
            forward_timeout: Duration::from_secs(60),
            server: rpc::ServerConfig::default(),
        }
    }
}

/// Per-instance routing/health state (one [`Mutex`]'d vector, indexed
/// like [`HashRing::instances`]).
#[derive(Clone, Debug)]
struct Health {
    up: bool,
    /// Consecutive forward failures (resets on success; drives the
    /// probe backoff exponent).
    consecutive_failures: u32,
    /// When a down instance may next be probed (None while up).
    next_probe_at: Option<Instant>,
    /// Cumulative replies forwarded from this instance.
    routed: u64,
    /// Cumulative `overloaded` redirects away from this instance.
    redirects: u64,
    /// Cumulative down transitions + failed probes.
    down_marks: u64,
}

impl Health {
    fn new() -> Health {
        Health {
            up: true,
            consecutive_failures: 0,
            next_probe_at: None,
            routed: 0,
            redirects: 0,
            down_marks: 0,
        }
    }
}

/// A point-in-time copy of one instance's gauges, for the `fleet`
/// stats block (pure data so [`fleet_stats_json`] stays testable).
#[derive(Clone, Debug)]
pub struct InstanceStats {
    pub addr: String,
    pub up: bool,
    pub routed: u64,
    pub redirects: u64,
    pub down_marks: u64,
}

/// Encode the router's `stats` reply (wire v6): the fleet's ring shape
/// and per-instance routing/health gauges, plus the router's own
/// reactor gauges in the usual `server` block. A router serves no
/// sessions itself, so the backend blocks (`epoch`, `sources`, `cache`,
/// ...) are absent — `fleet` is the discriminator.
pub fn fleet_stats_json(
    instances: &[InstanceStats],
    ring_points: usize,
    unavailable_total: u64,
    server: ServerStats,
) -> Json {
    let rows = instances.iter().map(|i| {
        Json::obj(vec![
            ("addr", Json::str(i.addr.as_str())),
            ("up", Json::Bool(i.up)),
            ("routed", Json::num(i.routed as f64)),
            ("redirects", Json::num(i.redirects as f64)),
            ("down_marks", Json::num(i.down_marks as f64)),
        ])
    });
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        (
            "stats",
            Json::obj(vec![
                ("protocol", Json::num(WIRE_PROTOCOL_VERSION as f64)),
                (
                    "fleet",
                    Json::obj(vec![
                        ("instances", Json::arr(rows)),
                        ("ring_points", Json::num(ring_points as f64)),
                        ("unavailable_total", Json::num(unavailable_total as f64)),
                    ]),
                ),
                (
                    "server",
                    Json::obj(vec![
                        ("connections", Json::num(server.connections as f64)),
                        ("queue_depth", Json::num(server.queue_depth as f64)),
                        ("evicted_idle", Json::num(server.evicted_idle as f64)),
                        ("evicted_read_stall", Json::num(server.evicted_read_stall as f64)),
                        ("evicted_write_stall", Json::num(server.evicted_write_stall as f64)),
                        ("shed_total", Json::num(server.shed_total as f64)),
                        ("quarantined", Json::num(server.quarantined as f64)),
                    ]),
                ),
            ]),
        ),
    ])
}

struct FleetState {
    ring: HashRing,
    health: Mutex<Vec<Health>>,
    config: FleetConfig,
    stop: AtomicBool,
    /// Requests answered with `fleet_unavailable` (every candidate
    /// down).
    unavailable_total: AtomicUsize,
}

impl FleetState {
    /// Whether a forward to instance `idx` may be attempted now: always
    /// while up; while down, only once the probe deadline has passed
    /// (the attempt *is* the probe).
    fn attempt_allowed(&self, idx: usize, now: Instant) -> bool {
        let health = self.health.lock().expect("fleet health");
        let h = &health[idx];
        h.up || h.next_probe_at.map_or(true, |at| at <= now)
    }

    fn note_success(&self, idx: usize) {
        let mut health = self.health.lock().expect("fleet health");
        let h = &mut health[idx];
        h.up = true;
        h.consecutive_failures = 0;
        h.next_probe_at = None;
        h.routed += 1;
    }

    fn note_redirect(&self, idx: usize) {
        self.health.lock().expect("fleet health")[idx].redirects += 1;
    }

    fn note_failure(&self, idx: usize, now: Instant) {
        let mut health = self.health.lock().expect("fleet health");
        let h = &mut health[idx];
        h.up = false;
        h.consecutive_failures = h.consecutive_failures.saturating_add(1);
        h.down_marks += 1;
        let backoff =
            (PROBE_BASE_MS << (h.consecutive_failures - 1).min(4)).min(PROBE_MAX_MS);
        // Deterministic de-synchronization: seeded from the address and
        // the failure count, never from the wall clock.
        let seed = fnv1a(self.ring.instances()[idx].as_bytes())
            ^ u64::from(h.consecutive_failures);
        let jitter = seed % (backoff / 4 + 1);
        h.next_probe_at = Some(now + Duration::from_millis(backoff + jitter));
    }

    fn instance_stats(&self) -> Vec<InstanceStats> {
        let health = self.health.lock().expect("fleet health");
        self.ring
            .instances()
            .iter()
            .zip(health.iter())
            .map(|(addr, h)| InstanceStats {
                addr: addr.clone(),
                up: h.up,
                routed: h.routed,
                redirects: h.redirects,
                down_marks: h.down_marks,
            })
            .collect()
    }
}

/// One frame round-trip to a backend: connect, send, read the reply
/// payload. Any failure is an `io::Error` — the caller's signal to mark
/// the instance down and rehash. The `rpc.write`/`rpc.read` fault sites
/// fire on the *client* half here (the backend's reactor has its own),
/// so a fleet smoke test can rehearse a flaky backend link
/// deterministically with `--fault-plan`.
fn forward(addr: &str, payload: &str, config: &FleetConfig) -> std::io::Result<String> {
    let sock: SocketAddr = addr
        .parse()
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, format!("{e}")))?;
    let mut stream = TcpStream::connect_timeout(&sock, config.connect_timeout)?;
    stream.set_read_timeout(Some(config.forward_timeout))?;
    stream.set_write_timeout(Some(config.forward_timeout))?;
    let _ = stream.set_nodelay(true);
    if crate::faults::should_fail("rpc.write") {
        return Err(crate::faults::io_error("rpc.write"));
    }
    crate::faults::sleep_site("rpc.write");
    let frame = rpc::encode_frame(payload)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
    stream.write_all(&frame)?;
    if crate::faults::should_fail("rpc.read") {
        return Err(crate::faults::io_error("rpc.read"));
    }
    crate::faults::sleep_site("rpc.read");
    match rpc::read_frame(&mut stream) {
        Ok(reply) => Ok(reply),
        Err(rpc::FrameError::Io(e)) => Err(e),
        Err(e) => Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())),
    }
}

/// Whether a backend reply is the typed `overloaded` error (the
/// redirect signal — never forwarded while another replica can answer).
fn is_overloaded(reply: &str) -> bool {
    let Ok(j) = json::parse(reply) else {
        return false;
    };
    let code = j.get("error").and_then(|e| e.get("code")).and_then(|c| c.as_str());
    code == Some("overloaded")
}

/// Route one request payload: admin intercept, then the candidate walk.
/// Returns the reply payload (forwarded verbatim, or a router-local
/// frame for `stats`/`shutdown`/terminal failures).
fn route(state: &Arc<FleetState>, payload: &str) -> String {
    crate::faults::sleep_site("rpc.handler");
    // Admin ops address the *router*: `stats` reports the fleet block,
    // `shutdown` drains this process. State-changing backend ops are
    // refused — artifact state must converge via `fleet sync`, not via
    // a republish that lands on whichever replica a key hashes to.
    if let Ok(j) = json::parse(payload) {
        if let Some(op) = j.get("op").and_then(|v| v.as_str()) {
            match op {
                "session" => {}
                "stats" => {
                    return fleet_stats_json(
                        &state.instance_stats(),
                        state.ring.points(),
                        state.unavailable_total.load(Ordering::Relaxed) as u64,
                        ServerStats::default(),
                    )
                    .to_compact();
                }
                "shutdown" => {
                    state.stop.store(true, Ordering::SeqCst);
                    return admin_ack_json("shutdown", vec![("fleet", Json::Bool(true))])
                        .to_compact();
                }
                other => {
                    return error_json(&RpcError::new(
                        "unknown_op",
                        format!(
                            "fleet router forwards sessions only; run `{other}` against a \
                             backend instance, or `repro fleet sync` to reconcile the fleet"
                        ),
                    ))
                    .to_compact();
                }
            }
        }
    }
    let key = routing_key(payload);
    let candidates = state.ring.candidates(&key);
    let mut last_overloaded: Option<String> = None;
    for idx in candidates {
        let now = Instant::now();
        if !state.attempt_allowed(idx, now) {
            continue;
        }
        match forward(&state.ring.instances()[idx], payload, &state.config) {
            Ok(reply) => {
                if is_overloaded(&reply) {
                    state.note_redirect(idx);
                    last_overloaded = Some(reply);
                    continue;
                }
                state.note_success(idx);
                // Byte-identity: the backend's payload, untouched.
                return reply;
            }
            Err(_) => {
                state.note_failure(idx, Instant::now());
                continue;
            }
        }
    }
    if let Some(reply) = last_overloaded {
        // Every live replica is shedding: surface the (adaptive) hint.
        return reply;
    }
    state.unavailable_total.fetch_add(1, Ordering::Relaxed);
    error_json(&RpcError::new(
        "fleet_unavailable",
        format!(
            "every replica for this routing key is down ({} instances)",
            state.ring.len()
        ),
    ))
    .to_compact()
}

/// The fleet router process: a [`Reactor`] whose handler forwards
/// frames to ring-selected backends. Construction mirrors
/// [`rpc::RpcServer`]; `repro fleet` drives one of these.
pub struct FleetRouter {
    inner: Reactor,
    state: Arc<FleetState>,
}

impl FleetRouter {
    /// Bind `listen` and start routing across `instances`.
    pub fn start(
        listen: &str,
        instances: &[String],
        config: FleetConfig,
    ) -> anyhow::Result<FleetRouter> {
        anyhow::ensure!(!instances.is_empty(), "fleet needs at least one --instance");
        let ring = HashRing::new(instances);
        let state = Arc::new(FleetState {
            health: Mutex::new(vec![Health::new(); ring.len()]),
            ring,
            config: config.clone(),
            stop: AtomicBool::new(false),
            unavailable_total: AtomicUsize::new(0),
        });
        let handler: reactor::Handler = Arc::new({
            let state = state.clone();
            move |line: &str| route(&state, line)
        });
        // The router's own shed path stays on the fixed cold-start hint:
        // its handler does network I/O, so its drain rate measures
        // backend latency, not local capacity.
        let shed: ShedHook = Arc::new(|depth: usize| overloaded_json(depth).to_compact());
        let rcfg = ReactorConfig {
            jobs: 0,
            max_conns: config.server.max_conns.max(1),
            idle_timeout: config.server.idle_timeout,
            read_stall: config.server.read_stall,
            write_stall: config.server.write_stall,
            max_frame_len: MAX_FRAME_LEN,
            max_queue: config.server.max_queue,
        };
        let gauges = Arc::new(ServerGauges::default());
        let inner = Reactor::start(listen, handler, rpc::violation_hook(), shed, rcfg, gauges)?;
        Ok(FleetRouter { inner, state })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.inner.local_addr()
    }

    pub fn gauges(&self) -> Arc<ServerGauges> {
        self.inner.gauges()
    }

    /// The ring this router placed its instances on.
    pub fn ring(&self) -> &HashRing {
        &self.state.ring
    }

    /// Whether a wire `shutdown` op has been received (the serve loop
    /// polls this next to its signal latch).
    pub fn stop_requested(&self) -> bool {
        self.state.stop.load(Ordering::SeqCst)
    }

    /// A point-in-time `stats` reply, as the wire would carry it — with
    /// the router's live reactor gauges in the `server` block (the
    /// in-band handler reports a default block instead: it runs *on* a
    /// worker, where a coherent snapshot of its own queue is a lie).
    pub fn stats(&self) -> Json {
        fleet_stats_json(
            &self.state.instance_stats(),
            self.state.ring.points(),
            self.state.unavailable_total.load(Ordering::Relaxed) as u64,
            ServerStats::snapshot(&self.gauges()),
        )
    }

    /// Drain connections and stop the reactor (graceful; idempotent at
    /// the process level).
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
    }

    #[test]
    fn ring_is_stable_under_reordering_and_dedup() {
        let mut shuffled = addrs(5);
        shuffled.reverse();
        shuffled.push("127.0.0.1:9002".to_string()); // duplicate
        let a = HashRing::new(&addrs(5));
        let b = HashRing::new(&shuffled);
        assert_eq!(a.instances(), b.instances());
        for key in ["ResNet-50\u{1f}server", "BERT-base\u{1f}edge", "x\u{1f}"] {
            assert_eq!(a.candidates(key), b.candidates(key));
        }
    }

    #[test]
    fn candidates_cover_every_instance_and_removal_promotes_successor() {
        let ring = HashRing::new(&addrs(4));
        let key = "MobileNetV2\u{1f}server";
        let order = ring.candidates(key);
        assert_eq!(order.len(), 4, "walk must reach every instance");
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        // Removing the primary from the instance *set* leaves the
        // surviving relative order intact: the successor is promoted,
        // nothing else moves (the consistent-hash property the
        // instance-kill rehash relies on).
        let survivors: Vec<String> = ring
            .instances()
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != order[0])
            .map(|(_, a)| a.clone())
            .collect();
        let reduced = HashRing::new(&survivors);
        let reduced_order: Vec<&str> =
            reduced.candidates(key).iter().map(|&i| reduced.instances()[i].as_str()).collect();
        let expected: Vec<&str> =
            order[1..].iter().map(|&i| ring.instances()[i].as_str()).collect();
        assert_eq!(reduced_order, expected);
    }

    #[test]
    fn keyspace_split_is_roughly_uniform() {
        let ring = HashRing::new(&addrs(4));
        let mut counts = [0usize; 4];
        for i in 0..1000 {
            counts[ring.primary(&format!("model-{i}\u{1f}server")).unwrap()] += 1;
        }
        for &c in &counts {
            assert!(c > 100, "4-way split of 1000 keys left a near-empty shard: {counts:?}");
        }
    }

    #[test]
    fn routing_key_is_total_and_separates_fields() {
        assert_eq!(routing_key(r#"{"model":"a","device":"edge"}"#), "a\u{1f}edge");
        assert_eq!(routing_key(r#"{"model":"a"}"#), "a\u{1f}");
        assert_ne!(
            routing_key(r#"{"model":"ab","device":"c"}"#),
            routing_key(r#"{"model":"a","device":"bc"}"#)
        );
        assert_eq!(routing_key("not json"), "not json");
    }

    #[test]
    fn fleet_stats_shape_is_pinned() {
        let stats = fleet_stats_json(
            &[InstanceStats {
                addr: "127.0.0.1:9000".into(),
                up: true,
                routed: 3,
                redirects: 1,
                down_marks: 0,
            }],
            64,
            2,
            ServerStats::default(),
        );
        assert_eq!(
            stats.to_compact(),
            "{\"ok\":true,\"stats\":{\"protocol\":6,\"fleet\":{\"instances\":[\
             {\"addr\":\"127.0.0.1:9000\",\"up\":true,\"routed\":3,\"redirects\":1,\
             \"down_marks\":0}],\"ring_points\":64,\"unavailable_total\":2},\
             \"server\":{\"connections\":0,\"queue_depth\":0,\"evicted_idle\":0,\
             \"evicted_read_stall\":0,\"evicted_write_stall\":0,\"shed_total\":0,\
             \"quarantined\":0}}}"
        );
    }
}
