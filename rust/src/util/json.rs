//! Minimal JSON value, parser, and pretty/compact writers.
//!
//! The schedule store uses an Ansor-log-like JSON-lines format; this module
//! exists because the build environment is offline (no serde). It supports
//! exactly the JSON subset we emit: objects, arrays, strings (with escapes),
//! f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    // ---- accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// `get` that errors with the missing key name — store files are
    /// hand-editable, so diagnostics matter.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key `{key}`"))
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parser ---------------------------------------------------------------

pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected `{}` at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (may be multi-byte).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected , or ] (found {:?})", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected , or }} (found {:?})", other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = Json::obj(vec![
            ("name", Json::str("conv2d_bias_relu")),
            ("shapes", Json::arr([Json::num(1.0), Json::num(64.0), Json::num(56.0)])),
            ("valid", Json::Bool(true)),
            ("cost", Json::Num(1.25e-3)),
            ("none", Json::Null),
        ]);
        let s = v.to_compact();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let s = r#" { "a" : [ 1 , -2.5e3 , "x\n\"y\"" ] , "b" : null } "#;
        let v = parse(s).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(-2500.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_str(), Some("x\n\"y\""));
        assert_eq!(v.get("b"), Some(&Json::Null));
    }

    #[test]
    fn integer_formatting_is_clean() {
        assert_eq!(Json::num(64.0).to_compact(), "64");
        assert_eq!(Json::num(0.5).to_compact(), "0.5");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::str("τ-tuning ✓");
        let back = parse(&v.to_compact()).unwrap();
        assert_eq!(back.as_str(), Some("τ-tuning ✓"));
    }

    #[test]
    fn deep_nesting() {
        let mut v = Json::num(1.0);
        for _ in 0..50 {
            v = Json::arr([v]);
        }
        let s = v.to_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }
}
