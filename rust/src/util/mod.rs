//! Dependency-free utilities (the build environment is offline): JSON,
//! seeded PRNG, statistics, and table/CSV rendering.

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
