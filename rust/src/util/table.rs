//! Plain-text aligned table renderer + CSV writer for the report harness.
//!
//! Every paper table/figure is emitted both as an aligned console table
//! (the "same rows the paper reports") and as CSV under `results/` so the
//! series can be re-plotted.

use std::fmt::Write as _;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                let _ = write!(line, " {}{} ", cell, " ".repeat(pad));
                if i + 1 < ncols {
                    line.push('|');
                }
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV under `dir/<slug>.csv`, creating the directory.
    pub fn write_csv(&self, dir: &Path, slug: &str) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{slug}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

/// Format seconds human-readably (matches the paper's "minutes" framing).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1.0 {
        format!("{:.0} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.1} s")
    } else if secs < 2.0 * 3600.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.2} h", secs / 3600.0)
    }
}

/// Format a speedup like the paper ("1.16x", "59x").
pub fn fmt_speedup(x: f64) -> String {
    if x >= 10.0 {
        format!("{x:.1}x")
    } else {
        format!("{x:.2}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["model", "speedup"]);
        t.row(vec!["ResNet18".into(), "1.20x".into()]);
        t.row(vec!["BERT".into(), "59.0x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("ResNet18"));
        // Header and rows aligned: all lines after the separator have equal
        // display width.
        let lines: Vec<&str> = s.lines().skip(1).collect();
        let w = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w || l.is_empty()));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(0.5), "500 ms");
        assert_eq!(fmt_duration(72.0), "72.0 s");
        assert_eq!(fmt_duration(432.0), "7.2 min");
        assert_eq!(fmt_duration(10_000.0), "2.78 h");
    }

    #[test]
    fn speedups() {
        assert_eq!(fmt_speedup(1.158), "1.16x");
        assert_eq!(fmt_speedup(59.4), "59.4x");
    }
}
