//! Seeded, dependency-free PRNG (xoshiro256**) plus the distributions the
//! simulator and the evolutionary search need.
//!
//! Determinism is a hard requirement: the paper's search is stochastic
//! (genetic mutation + noisy measurements), and every experiment in
//! EXPERIMENTS.md must be regenerable bit-for-bit from a `--seed`.

/// xoshiro256** by Blackman & Vigna — small, fast, high quality.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64, used to expand a single u64 seed into the xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-kernel / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine here; modulo
        // bias is negligible for the small `n` we use.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.usize(hi - lo + 1)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Pick a reference to a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }

    /// Standard normal via Box–Muller (one value per call; we do not
    /// bother caching the second — clarity over the last nanosecond).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal multiplicative noise centred on 1.0: exp(N(0, sigma)).
    /// This is how the simulator models run-to-run measurement jitter.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportionally to non-negative weights. Returns 0 if
    /// all weights are zero.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return 0;
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_noise_centred() {
        let mut r = Rng::new(13);
        let n = 50_000;
        // Median of exp(N(0, s)) is 1.0.
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal_noise(0.02)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = xs[n / 2];
        assert!((med - 1.0).abs() < 0.005, "median {med}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(17);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            let v = r.range(3, 7);
            assert!((3..=7).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
