//! Small statistics helpers used by the measurement harness and reports.

/// Median of a slice (copies; slices are tiny — repeat counts).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "mean of empty slice");
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Pearson correlation; used to sanity-check the learned cost model.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx.sqrt() * dy.sqrt())
    }
}

/// Spearman rank correlation — what actually matters for a tuner's cost
/// model is ranking candidates, not absolute error.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    let rx = ranks(xs);
    let ry = ranks(ys);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut out = vec![0.0; xs.len()];
    for (rank, &i) in idx.iter().enumerate() {
        out[i] = rank as f64;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn geomean_of_powers() {
        let g = geomean(&[1.0, 100.0]);
        assert!((g - 10.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [1.0, 8.0, 27.0, 64.0, 125.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_anticorrelated() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [9.0, 5.0, 1.0];
        assert!((spearman(&xs, &ys) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }
}
