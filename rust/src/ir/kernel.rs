//! Kernel construction: fused op sequences → canonical loop nests.
//!
//! These builders mirror the fusion conventions the paper inherits from
//! TVM's Relay partitioner (§4.2): anchor op + fused epilogue
//! (bias/activation/residual-add), pooling kernels, dense kernels, and the
//! transformer kernels BERT/MobileBERT need.

use super::loopnest::{AffineDim, Axis, AxisKind, BufferAccess, LoopNest};
use super::ops::{AnchorKind, OpKind};
use super::workload;

pub const F32: u64 = 4;

/// A fused kernel: the unit of auto-scheduling and transfer-tuning.
#[derive(Clone, Debug)]
pub struct Kernel {
    /// Fused op sequence, anchor first.
    pub ops: Vec<OpKind>,
    pub anchor: AnchorKind,
    pub nest: LoopNest,
    /// Display shapes for Table-1-style inventories.
    pub input_shape: Vec<u64>,
    pub weight_shape: Vec<u64>,
    /// Hash of (class signature, axis extents): identical kernels share
    /// auto-schedules for free, exactly like Ansor workload ids (§2).
    pub workload_id: u64,
}

impl Kernel {
    /// `conv2d_bias_relu`-style signature string (paper "TVM Ops" column).
    pub fn class_signature(&self) -> String {
        workload::class_signature(&self.ops)
    }

    pub fn flops(&self) -> f64 {
        self.nest.flops()
    }
}

fn finish(
    ops: Vec<OpKind>,
    nest: LoopNest,
    input_shape: Vec<u64>,
    weight_shape: Vec<u64>,
) -> Kernel {
    let anchor = AnchorKind::from_op(ops[0]);
    // Hash loop extents AND raw input/weight shapes: two convs with the
    // same output extents but different strides (56x56/2 vs 28x28/1) are
    // different computations and must not share a workload id.
    let mut key: Vec<u64> = nest.axes.iter().map(|a| a.extent).collect();
    key.extend_from_slice(&input_shape);
    key.extend_from_slice(&weight_shape);
    let workload_id = workload::workload_id(&workload::class_signature(&ops), &key);
    let epilogue: f64 = ops.iter().skip(1).map(|o| o.pointwise_cost()).sum();
    let nest = LoopNest { epilogue_ops: epilogue, ..nest };
    Kernel { ops, anchor, nest, input_shape, weight_shape, workload_id }
}

/// Builder for every kernel shape the model zoo uses.
pub struct KernelBuilder;

impl KernelBuilder {
    /// 2D convolution, NCHW. `fused` is the epilogue (BiasAdd/Relu/Add...).
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d(
        n: u64,
        ic: u64,
        h: u64,
        w: u64,
        oc: u64,
        kh: u64,
        kw: u64,
        stride: u64,
        pad: u64,
        fused: &[OpKind],
    ) -> Kernel {
        let oh = (h + 2 * pad - kh) / stride + 1;
        let ow = (w + 2 * pad - kw) / stride + 1;
        // axes: 0:n 1:oc 2:oh 3:ow | 4:ic 5:kh 6:kw
        let axes = vec![
            Axis { name: "n", extent: n, kind: AxisKind::Spatial },
            Axis { name: "oc", extent: oc, kind: AxisKind::Spatial },
            Axis { name: "oh", extent: oh, kind: AxisKind::Spatial },
            Axis { name: "ow", extent: ow, kind: AxisKind::Spatial },
            Axis { name: "ic", extent: ic, kind: AxisKind::Reduction },
            Axis { name: "kh", extent: kh, kind: AxisKind::Reduction },
            Axis { name: "kw", extent: kw, kind: AxisKind::Reduction },
        ];
        let buffers = vec![
            BufferAccess {
                name: "X",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(0),
                    AffineDim::axis(4),
                    AffineDim::window(2, stride, 5),
                    AffineDim::window(3, stride, 6),
                ],
                is_output: false,
            },
            BufferAccess {
                name: "W",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(1),
                    AffineDim::axis(4),
                    AffineDim::axis(5),
                    AffineDim::axis(6),
                ],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(0),
                    AffineDim::axis(1),
                    AffineDim::axis(2),
                    AffineDim::axis(3),
                ],
                is_output: true,
            },
        ];
        let mut ops = vec![OpKind::Conv2d];
        ops.extend_from_slice(fused);
        finish(
            ops,
            LoopNest { axes, buffers, flops_per_point: 2.0, epilogue_ops: 0.0 },
            vec![n, ic, h, w],
            vec![oc, ic, kh, kw],
        )
    }

    /// Depthwise 2D convolution (per-channel filter), NCHW.
    #[allow(clippy::too_many_arguments)]
    pub fn depthwise_conv2d(
        n: u64,
        c: u64,
        h: u64,
        w: u64,
        kh: u64,
        kw: u64,
        stride: u64,
        pad: u64,
        fused: &[OpKind],
    ) -> Kernel {
        let oh = (h + 2 * pad - kh) / stride + 1;
        let ow = (w + 2 * pad - kw) / stride + 1;
        // axes: 0:n 1:c 2:oh 3:ow | 4:kh 5:kw
        let axes = vec![
            Axis { name: "n", extent: n, kind: AxisKind::Spatial },
            Axis { name: "c", extent: c, kind: AxisKind::Spatial },
            Axis { name: "oh", extent: oh, kind: AxisKind::Spatial },
            Axis { name: "ow", extent: ow, kind: AxisKind::Spatial },
            Axis { name: "kh", extent: kh, kind: AxisKind::Reduction },
            Axis { name: "kw", extent: kw, kind: AxisKind::Reduction },
        ];
        let buffers = vec![
            BufferAccess {
                name: "X",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(0),
                    AffineDim::axis(1),
                    AffineDim::window(2, stride, 4),
                    AffineDim::window(3, stride, 5),
                ],
                is_output: false,
            },
            BufferAccess {
                name: "W",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(1), AffineDim::axis(4), AffineDim::axis(5)],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(0),
                    AffineDim::axis(1),
                    AffineDim::axis(2),
                    AffineDim::axis(3),
                ],
                is_output: true,
            },
        ];
        let mut ops = vec![OpKind::DepthwiseConv2d];
        ops.extend_from_slice(fused);
        finish(
            ops,
            LoopNest { axes, buffers, flops_per_point: 2.0, epilogue_ops: 0.0 },
            vec![n, c, h, w],
            vec![c, 1, kh, kw],
        )
    }

    /// Fully-connected layer: `Y[m,n] = X[m,k] * W[n,k]`.
    pub fn dense(m: u64, k: u64, n: u64, fused: &[OpKind]) -> Kernel {
        // axes: 0:m 1:n | 2:k
        let axes = vec![
            Axis { name: "m", extent: m, kind: AxisKind::Spatial },
            Axis { name: "n", extent: n, kind: AxisKind::Spatial },
            Axis { name: "k", extent: k, kind: AxisKind::Reduction },
        ];
        let buffers = vec![
            BufferAccess {
                name: "X",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(2)],
                is_output: false,
            },
            BufferAccess {
                name: "W",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(1), AffineDim::axis(2)],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(1)],
                is_output: true,
            },
        ];
        let mut ops = vec![OpKind::Dense];
        ops.extend_from_slice(fused);
        finish(
            ops,
            LoopNest { axes, buffers, flops_per_point: 2.0, epilogue_ops: 0.0 },
            vec![m, k],
            vec![n, k],
        )
    }

    /// Batched matmul (attention): `Y[b,m,n] = sum_k A[b,m,k] B[b,k,n]`.
    pub fn batch_matmul(b: u64, m: u64, k: u64, n: u64, fused: &[OpKind]) -> Kernel {
        // axes: 0:b 1:m 2:n | 3:k
        let axes = vec![
            Axis { name: "b", extent: b, kind: AxisKind::Spatial },
            Axis { name: "m", extent: m, kind: AxisKind::Spatial },
            Axis { name: "n", extent: n, kind: AxisKind::Spatial },
            Axis { name: "k", extent: k, kind: AxisKind::Reduction },
        ];
        let buffers = vec![
            BufferAccess {
                name: "A",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(1), AffineDim::axis(3)],
                is_output: false,
            },
            BufferAccess {
                name: "B",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(3), AffineDim::axis(2)],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(1), AffineDim::axis(2)],
                is_output: true,
            },
        ];
        let mut ops = vec![OpKind::BatchMatMul];
        ops.extend_from_slice(fused);
        finish(
            ops,
            LoopNest { axes, buffers, flops_per_point: 2.0, epilogue_ops: 0.0 },
            vec![b, m, k],
            vec![b, k, n],
        )
    }

    /// Max/avg pooling with window `(ph, pw)` and equal stride.
    pub fn pool2d(op: OpKind, n: u64, c: u64, h: u64, w: u64, ph: u64, pw: u64, stride: u64) -> Kernel {
        assert!(matches!(op, OpKind::MaxPool2d | OpKind::AvgPool2d));
        let oh = (h - ph) / stride + 1;
        let ow = (w - pw) / stride + 1;
        // axes: 0:n 1:c 2:oh 3:ow | 4:ph 5:pw
        let axes = vec![
            Axis { name: "n", extent: n, kind: AxisKind::Spatial },
            Axis { name: "c", extent: c, kind: AxisKind::Spatial },
            Axis { name: "oh", extent: oh, kind: AxisKind::Spatial },
            Axis { name: "ow", extent: ow, kind: AxisKind::Spatial },
            Axis { name: "ph", extent: ph, kind: AxisKind::Reduction },
            Axis { name: "pw", extent: pw, kind: AxisKind::Reduction },
        ];
        let buffers = vec![
            BufferAccess {
                name: "X",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(0),
                    AffineDim::axis(1),
                    AffineDim::window(2, stride, 4),
                    AffineDim::window(3, stride, 5),
                ],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(0),
                    AffineDim::axis(1),
                    AffineDim::axis(2),
                    AffineDim::axis(3),
                ],
                is_output: true,
            },
        ];
        finish(
            vec![op],
            LoopNest { axes, buffers, flops_per_point: 1.0, epilogue_ops: 0.0 },
            vec![n, c, h, w],
            vec![ph, pw],
        )
    }

    /// Global average pool: NCHW → NC.
    pub fn global_avg_pool(n: u64, c: u64, h: u64, w: u64) -> Kernel {
        // axes: 0:n 1:c | 2:h 3:w
        let axes = vec![
            Axis { name: "n", extent: n, kind: AxisKind::Spatial },
            Axis { name: "c", extent: c, kind: AxisKind::Spatial },
            Axis { name: "h", extent: h, kind: AxisKind::Reduction },
            Axis { name: "w", extent: w, kind: AxisKind::Reduction },
        ];
        let buffers = vec![
            BufferAccess {
                name: "X",
                elem_bytes: F32,
                dims: vec![
                    AffineDim::axis(0),
                    AffineDim::axis(1),
                    AffineDim::axis(2),
                    AffineDim::axis(3),
                ],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(1)],
                is_output: true,
            },
        ];
        finish(
            vec![OpKind::GlobalAvgPool2d],
            LoopNest { axes, buffers, flops_per_point: 1.0, epilogue_ops: 0.0 },
            vec![n, c, h, w],
            vec![h, w],
        )
    }

    /// Row-wise reduction kernels (softmax / layer-norm) over `[rows, cols]`.
    pub fn row_reduce(op: OpKind, rows: u64, cols: u64, fused: &[OpKind]) -> Kernel {
        assert!(matches!(op, OpKind::Softmax | OpKind::LayerNorm));
        // axes: 0:rows | 1:cols
        let axes = vec![
            Axis { name: "rows", extent: rows, kind: AxisKind::Spatial },
            Axis { name: "cols", extent: cols, kind: AxisKind::Reduction },
        ];
        let buffers = vec![
            BufferAccess {
                name: "X",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(1)],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0), AffineDim::axis(1)],
                is_output: true,
            },
        ];
        let mut ops = vec![op];
        ops.extend_from_slice(fused);
        // Softmax/LN do several passes: exp + sum + div ≈ 8 ops/point.
        finish(
            ops,
            LoopNest { axes, buffers, flops_per_point: 8.0, epilogue_ops: 0.0 },
            vec![rows, cols],
            vec![],
        )
    }

    /// Pure element-wise kernel over `points` elements (residual adds that
    /// could not fuse, embedding lookups, transposes...).
    pub fn eltwise(ops_seq: &[OpKind], points: u64) -> Kernel {
        let axes = vec![Axis { name: "i", extent: points, kind: AxisKind::Spatial }];
        let buffers = vec![
            BufferAccess {
                name: "X",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0)],
                is_output: false,
            },
            BufferAccess {
                name: "Y",
                elem_bytes: F32,
                dims: vec![AffineDim::axis(0)],
                is_output: true,
            },
        ];
        let cost: f64 = ops_seq.iter().map(|o| o.pointwise_cost().max(1.0)).sum();
        finish(
            ops_seq.to_vec(),
            LoopNest { axes, buffers, flops_per_point: cost, epilogue_ops: 0.0 },
            vec![points],
            vec![],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_shape_and_flops() {
        // ResNet18 first layer: 224x224x3 -> 64 filters 7x7 stride 2 pad 3.
        let k = KernelBuilder::conv2d(1, 3, 224, 224, 64, 7, 7, 2, 3, &[OpKind::BiasAdd, OpKind::Relu]);
        let oh = k.nest.axes[2].extent;
        assert_eq!(oh, 112);
        assert_eq!(k.class_signature(), "conv2d_bias_relu");
        // 2 * N*OC*OH*OW*IC*KH*KW MACs (+ epilogue)
        let macs = 2.0 * (64 * 112 * 112 * 3 * 7 * 7) as f64;
        assert!(k.flops() >= macs && k.flops() < macs * 1.01);
    }

    #[test]
    fn identical_kernels_share_workload_id() {
        let a = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]);
        let b = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]);
        assert_eq!(a.workload_id, b.workload_id);
    }

    #[test]
    fn different_shape_different_id_same_class() {
        let a = KernelBuilder::dense(256, 1024, 1024, &[]);
        let b = KernelBuilder::dense(128, 1024, 1024, &[]);
        assert_ne!(a.workload_id, b.workload_id);
        assert_eq!(a.class_signature(), b.class_signature());
    }

    #[test]
    fn different_fusion_different_class() {
        let a = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]);
        let b = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Add, OpKind::Relu]);
        assert_eq!(a.class_signature(), "conv2d_bias_relu");
        assert_eq!(b.class_signature(), "conv2d_bias_add_relu");
        assert_ne!(a.workload_id, b.workload_id);
    }

    #[test]
    fn dense_input_footprint() {
        let k = KernelBuilder::dense(256, 768, 3072, &[]);
        let x = &k.nest.buffers[0];
        assert_eq!(x.total_bytes(&k.nest.axes), 256 * 768 * 4);
    }

    #[test]
    fn pool_flops_small() {
        let k = KernelBuilder::pool2d(OpKind::MaxPool2d, 1, 64, 112, 112, 2, 2, 2);
        assert_eq!(k.nest.axes[2].extent, 56);
        assert_eq!(k.class_signature(), "max_pool2d");
    }

    #[test]
    fn depthwise_has_no_channel_reduction() {
        let k = KernelBuilder::depthwise_conv2d(1, 32, 112, 112, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu6]);
        assert_eq!(k.nest.reduction_axes().count(), 2);
        assert_eq!(k.class_signature(), "dwconv2d_bias_relu6");
    }
}
