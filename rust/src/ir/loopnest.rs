//! Canonical loop nests with affine buffer-access functions.
//!
//! A [`LoopNest`] is the analytic core of a kernel: an ordered list of
//! axes (spatial then reduction) plus, for every buffer the kernel
//! touches, an affine map from axes to buffer dimensions. The affine maps
//! are what let the cost simulator compute *tile footprints* exactly —
//! including convolution sliding windows, where the input footprint along
//! a spatial dim is `stride*(oh_tile-1) + kh_tile` elements.

/// Whether an axis is a data-parallel (spatial) or reduction axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AxisKind {
    Spatial,
    Reduction,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Axis {
    pub name: &'static str,
    pub extent: u64,
    pub kind: AxisKind,
}

/// One buffer dimension as an affine combination of loop axes:
/// `index = sum(coeff_i * axis_i) (+ const)`. The *range size* of the
/// dimension under a tile assigning `t_i` iterations to axis `i` is
/// `sum(coeff_i * (t_i - 1)) + 1`.
#[derive(Clone, Debug, PartialEq)]
pub struct AffineDim {
    /// (axis index, stride coefficient) terms.
    pub terms: Vec<(usize, u64)>,
}

impl AffineDim {
    pub fn axis(a: usize) -> Self {
        AffineDim { terms: vec![(a, 1)] }
    }
    pub fn strided(a: usize, stride: u64) -> Self {
        AffineDim { terms: vec![(a, stride)] }
    }
    /// Conv-style window: `stride*oh + kh`.
    pub fn window(spatial: usize, stride: u64, kernel: usize) -> Self {
        AffineDim {
            terms: vec![(spatial, stride), (kernel, 1)],
        }
    }

    /// Number of distinct elements touched along this dim when axis `i`
    /// runs for `tile[i]` iterations.
    pub fn range_size(&self, tile: &[u64]) -> u64 {
        let mut span = 0u64;
        for &(axis, coeff) in &self.terms {
            span += coeff * tile[axis].saturating_sub(1);
        }
        span + 1
    }

    /// Does this dim depend on `axis` at all?
    pub fn uses_axis(&self, axis: usize) -> bool {
        self.terms.iter().any(|&(a, _)| a == axis)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct BufferAccess {
    pub name: &'static str,
    pub elem_bytes: u64,
    pub dims: Vec<AffineDim>,
    pub is_output: bool,
}

impl BufferAccess {
    /// Bytes touched by a tile (per-axis iteration counts in canonical
    /// axis order).
    pub fn footprint_bytes(&self, tile: &[u64]) -> u64 {
        self.dims
            .iter()
            .map(|d| d.range_size(tile))
            .product::<u64>()
            * self.elem_bytes
    }

    pub fn uses_axis(&self, axis: usize) -> bool {
        self.dims.iter().any(|d| d.uses_axis(axis))
    }

    /// Total bytes of the buffer region the whole kernel touches.
    pub fn total_bytes(&self, axes: &[Axis]) -> u64 {
        let full: Vec<u64> = axes.iter().map(|a| a.extent).collect();
        self.footprint_bytes(&full)
    }
}

/// Canonical loop nest: spatial axes first (outer→inner by convention),
/// then reduction axes. Schedules index axes by position in this list.
#[derive(Clone, Debug, PartialEq)]
pub struct LoopNest {
    pub axes: Vec<Axis>,
    pub buffers: Vec<BufferAccess>,
    /// FLOPs executed per innermost iteration point of the *full* domain
    /// (2.0 for multiply-accumulate kernels, 1.0 for pooling, ...).
    pub flops_per_point: f64,
    /// Extra scalar ops applied per *output* point (fused epilogue:
    /// bias/relu/swish...), used for body-cost and unroll/icache modeling.
    pub epilogue_ops: f64,
}

impl LoopNest {
    pub fn total_points(&self) -> f64 {
        self.axes.iter().map(|a| a.extent as f64).product()
    }

    pub fn output_points(&self) -> f64 {
        self.axes
            .iter()
            .filter(|a| a.kind == AxisKind::Spatial)
            .map(|a| a.extent as f64)
            .product()
    }

    pub fn flops(&self) -> f64 {
        self.total_points() * self.flops_per_point + self.output_points() * self.epilogue_ops
    }

    pub fn spatial_axes(&self) -> impl Iterator<Item = (usize, &Axis)> {
        self.axes
            .iter()
            .enumerate()
            .filter(|(_, a)| a.kind == AxisKind::Spatial)
    }

    pub fn reduction_axes(&self) -> impl Iterator<Item = (usize, &Axis)> {
        self.axes
            .iter()
            .enumerate()
            .filter(|(_, a)| a.kind == AxisKind::Reduction)
    }

    pub fn output_buffer(&self) -> &BufferAccess {
        self.buffers
            .iter()
            .find(|b| b.is_output)
            .expect("loop nest has no output buffer")
    }

    /// Bytes of every buffer the kernel touches once (compulsory traffic).
    pub fn total_data_bytes(&self) -> u64 {
        self.buffers.iter().map(|b| b.total_bytes(&self.axes)).sum()
    }

    /// Structural fingerprint: (axis kinds, buffer arity) — two nests with
    /// different structure can never exchange schedules even if the class
    /// signature collided.
    pub fn skeleton(&self) -> Vec<AxisKind> {
        self.axes.iter().map(|a| a.kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm(n: u64, m: u64, k: u64) -> LoopNest {
        LoopNest {
            axes: vec![
                Axis { name: "n", extent: n, kind: AxisKind::Spatial },
                Axis { name: "m", extent: m, kind: AxisKind::Spatial },
                Axis { name: "k", extent: k, kind: AxisKind::Reduction },
            ],
            buffers: vec![
                BufferAccess {
                    name: "A",
                    elem_bytes: 4,
                    dims: vec![AffineDim::axis(0), AffineDim::axis(2)],
                    is_output: false,
                },
                BufferAccess {
                    name: "B",
                    elem_bytes: 4,
                    dims: vec![AffineDim::axis(2), AffineDim::axis(1)],
                    is_output: false,
                },
                BufferAccess {
                    name: "C",
                    elem_bytes: 4,
                    dims: vec![AffineDim::axis(0), AffineDim::axis(1)],
                    is_output: true,
                },
            ],
            flops_per_point: 2.0,
            epilogue_ops: 0.0,
        }
    }

    #[test]
    fn gemm_flops() {
        let nest = gemm(512, 512, 512);
        assert_eq!(nest.flops(), 2.0 * 512.0 * 512.0 * 512.0);
    }

    #[test]
    fn tile_footprints() {
        let nest = gemm(512, 512, 512);
        // Tile: 8x8 output tile over full K.
        let tile = [8, 8, 512];
        let a = &nest.buffers[0];
        let b = &nest.buffers[1];
        let c = &nest.buffers[2];
        assert_eq!(a.footprint_bytes(&tile), 8 * 512 * 4);
        assert_eq!(b.footprint_bytes(&tile), 512 * 8 * 4);
        assert_eq!(c.footprint_bytes(&tile), 8 * 8 * 4);
    }

    #[test]
    fn window_range_size() {
        // conv input dim: stride 2, oh tile 4, kh tile 3 -> 2*(4-1)+1*(3-1)+1 = 9
        let d = AffineDim::window(0, 2, 1);
        assert_eq!(d.range_size(&[4, 3]), 9);
        // degenerate tile of 1x1 touches exactly 1 element
        assert_eq!(d.range_size(&[1, 1]), 1);
    }

    #[test]
    fn uses_axis() {
        let d = AffineDim::window(0, 2, 1);
        assert!(d.uses_axis(0));
        assert!(d.uses_axis(1));
        assert!(!d.uses_axis(2));
    }

    #[test]
    fn total_data_bytes_gemm() {
        let nest = gemm(64, 64, 64);
        // 3 buffers of 64*64 f32
        assert_eq!(nest.total_data_bytes(), 3 * 64 * 64 * 4);
    }
}
