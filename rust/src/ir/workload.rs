//! Workload identity: class signatures and workload ids.
//!
//! Ansor gives every kernel a workload id — "the hash of its key
//! parameters (e.g., operation type, input data sizes)" (paper §2) — so
//! identical kernels reuse schedules for free. Transfer-tuning relaxes the
//! identity to the *class signature* (op sequence only, shapes ignored),
//! which is the paper's central idea (§4.2).

use super::ops::OpKind;

/// FNV-1a, 64-bit. Stable across runs/platforms; used for workload ids.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// `conv2d_bias_relu`-style signature for a fused op sequence.
pub fn class_signature(ops: &[OpKind]) -> String {
    ops.iter().map(|o| o.token()).collect::<Vec<_>>().join("_")
}

/// Workload id = hash(class signature, key extents). Kernel builders
/// pass every loop-axis extent *plus* the raw input/weight shapes (see
/// `finish` in `ir::kernel`), so same-output kernels with different
/// strides get distinct ids — and the measurement cache inherits that
/// exactness.
pub fn workload_id(class_sig: &str, extents: &[u64]) -> u64 {
    let mut bytes = Vec::with_capacity(class_sig.len() + extents.len() * 8);
    bytes.extend_from_slice(class_sig.as_bytes());
    for e in extents {
        bytes.extend_from_slice(&e.to_le_bytes());
    }
    fnv1a(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signature_joins_tokens() {
        let sig = class_signature(&[OpKind::Conv2d, OpKind::BiasAdd, OpKind::Add, OpKind::Relu]);
        assert_eq!(sig, "conv2d_bias_add_relu");
    }

    #[test]
    fn workload_id_sensitive_to_extents() {
        assert_ne!(workload_id("dense", &[256, 768, 768]), workload_id("dense", &[128, 768, 768]));
        assert_eq!(workload_id("dense", &[256, 768, 768]), workload_id("dense", &[256, 768, 768]));
    }

    #[test]
    fn workload_id_sensitive_to_class() {
        assert_ne!(workload_id("dense", &[64]), workload_id("conv2d", &[64]));
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("") = offset basis.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
    }
}
