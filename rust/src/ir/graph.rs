//! Model graphs: sequences of kernel instances with use-counts.
//!
//! The paper's Table 1 shows ResNet18 as 18 *unique* kernels, some used
//! more than once ("Use Count"). We keep the deduplicated kernel list plus
//! the full instance sequence (needed for the inter-kernel cache effects
//! of §5.5 / Fig 8, where producer→consumer adjacency matters).

use super::kernel::Kernel;
use std::collections::HashMap;

/// One occurrence of a kernel in the model's execution order.
#[derive(Clone, Debug)]
pub struct KernelInstance {
    /// Index into [`ModelGraph::kernels`].
    pub kernel: usize,
    /// Index (into `instances`) of the producer whose output this instance
    /// consumes; `None` for the first kernel. The zoo builds models as
    /// execution-ordered chains, which is what the boundary cost model
    /// needs (it only looks at adjacent pairs).
    pub producer: Option<usize>,
}

#[derive(Clone, Debug)]
pub struct ModelGraph {
    pub name: String,
    /// Unique kernels (deduplicated by workload id).
    pub kernels: Vec<Kernel>,
    /// Execution order over unique-kernel indices.
    pub instances: Vec<KernelInstance>,
}

impl ModelGraph {
    pub fn new(name: &str) -> Self {
        ModelGraph { name: name.to_string(), kernels: Vec::new(), instances: Vec::new() }
    }

    /// Append a kernel occurrence; dedupes by workload id like Ansor
    /// ("repeated kernels are only tuned once", §4.2).
    pub fn push(&mut self, kernel: Kernel) -> usize {
        let idx = match self
            .kernels
            .iter()
            .position(|k| k.workload_id == kernel.workload_id)
        {
            Some(i) => i,
            None => {
                self.kernels.push(kernel);
                self.kernels.len() - 1
            }
        };
        let producer = if self.instances.is_empty() { None } else { Some(self.instances.len() - 1) };
        self.instances.push(KernelInstance { kernel: idx, producer });
        idx
    }

    /// How many times unique kernel `k` appears (Table 1 "Use Count").
    pub fn use_count(&self, k: usize) -> usize {
        self.instances.iter().filter(|i| i.kernel == k).count()
    }

    /// Unique class signatures in deterministic (first-appearance) order.
    pub fn class_signatures(&self) -> Vec<String> {
        let mut seen = HashMap::new();
        let mut out = Vec::new();
        for k in &self.kernels {
            let sig = k.class_signature();
            if seen.insert(sig.clone(), ()).is_none() {
                out.push(sig);
            }
        }
        out
    }

    /// Unique kernel indices belonging to a class.
    pub fn kernels_of_class(&self, sig: &str) -> Vec<usize> {
        self.kernels
            .iter()
            .enumerate()
            .filter(|(_, k)| k.class_signature() == sig)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn total_flops(&self) -> f64 {
        self.instances.iter().map(|i| self.kernels[i.kernel].flops()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::kernel::KernelBuilder;
    use crate::ir::ops::OpKind;

    fn tiny_model() -> ModelGraph {
        let mut g = ModelGraph::new("tiny");
        let conv = KernelBuilder::conv2d(1, 16, 32, 32, 16, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]);
        g.push(conv.clone());
        g.push(conv); // repeated -> same unique kernel
        g.push(KernelBuilder::pool2d(OpKind::MaxPool2d, 1, 16, 32, 32, 2, 2, 2));
        g.push(KernelBuilder::dense(1, 16 * 16 * 16, 10, &[OpKind::Add]));
        g
    }

    #[test]
    fn dedupes_repeated_kernels() {
        let g = tiny_model();
        assert_eq!(g.kernels.len(), 3);
        assert_eq!(g.instances.len(), 4);
        assert_eq!(g.use_count(0), 2);
        assert_eq!(g.use_count(1), 1);
    }

    #[test]
    fn producers_form_chain() {
        let g = tiny_model();
        assert_eq!(g.instances[0].producer, None);
        assert_eq!(g.instances[3].producer, Some(2));
    }

    #[test]
    fn class_listing() {
        let g = tiny_model();
        let sigs = g.class_signatures();
        assert_eq!(sigs, vec!["conv2d_bias_relu", "max_pool2d", "dense_add"]);
        assert_eq!(g.kernels_of_class("conv2d_bias_relu"), vec![0]);
    }
}
