//! Tensor-program intermediate representation.
//!
//! This is the substrate the paper assumes from TVM: a tensor program
//! (e.g. a DNN) is partitioned into *kernels* — fused loop nests such as
//! `conv2d_bias_relu` — which are optimized independently (paper §2).
//!
//! The IR is deliberately analytic rather than executable: a kernel
//! carries its canonical loop-nest structure (axes, buffer access
//! functions, per-point cost), which is what the schedule primitives
//! transform and what the device cost simulator consumes. *Executable*
//! kernels live in the Python/Pallas layer and are exercised through the
//! PJRT runtime (`crate::runtime`).

pub mod graph;
pub mod kernel;
pub mod loopnest;
pub mod ops;
pub mod workload;

pub use graph::{KernelInstance, ModelGraph};
pub use kernel::{Kernel, KernelBuilder};
pub use loopnest::{AffineDim, Axis, AxisKind, BufferAccess, LoopNest};
pub use ops::{AnchorKind, OpKind};
pub use workload::{class_signature, workload_id};
