//! Operation vocabulary.
//!
//! A kernel is a fused sequence of [`OpKind`]s; the sequence is the kernel's
//! *class signature* (paper §4.2: "a kernel class is a set of kernels that
//! share the same sequence of operations, regardless of their data sizes").
//! The first "heavy" op in the sequence is the *anchor*: it determines the
//! canonical loop-nest skeleton, and therefore which schedules can be
//! structurally applied at all.

/// All operations our model zoo needs (superset of the paper's Table 1/2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    // Anchors (define the loop nest).
    Conv2d,
    DepthwiseConv2d,
    Dense,
    BatchMatMul,
    MaxPool2d,
    AvgPool2d,
    GlobalAvgPool2d,
    Softmax,
    LayerNorm,
    // Fused element-wise / epilogue ops.
    Add,     // residual / skip-connection addition
    BiasAdd, // per-channel bias
    Relu,
    Relu6,
    Swish,
    Sigmoid,
    Gelu,
    Tanh,
    Mul, // squeeze-and-excite channel scale
    Flatten,
    Embedding,
    Transpose,
}

impl OpKind {
    /// Lower-case token used in class signatures; matches the paper's
    /// "TVM Ops" column (e.g. `conv2d_bias_relu`).
    pub fn token(self) -> &'static str {
        match self {
            OpKind::Conv2d => "conv2d",
            OpKind::DepthwiseConv2d => "dwconv2d",
            OpKind::Dense => "dense",
            OpKind::BatchMatMul => "batch_matmul",
            OpKind::MaxPool2d => "max_pool2d",
            OpKind::AvgPool2d => "avg_pool2d",
            OpKind::GlobalAvgPool2d => "global_avg_pool2d",
            OpKind::Softmax => "softmax",
            OpKind::LayerNorm => "layer_norm",
            OpKind::Add => "add",
            OpKind::BiasAdd => "bias",
            OpKind::Relu => "relu",
            OpKind::Relu6 => "relu6",
            OpKind::Swish => "swish",
            OpKind::Sigmoid => "sigmoid",
            OpKind::Gelu => "gelu",
            OpKind::Tanh => "tanh",
            OpKind::Mul => "mul",
            OpKind::Flatten => "flatten",
            OpKind::Embedding => "embedding",
            OpKind::Transpose => "transpose",
        }
    }

    /// Approximate scalar-op cost of applying this op once to one output
    /// point (used for the fused-epilogue part of the body cost).
    pub fn pointwise_cost(self) -> f64 {
        match self {
            OpKind::Add | OpKind::BiasAdd | OpKind::Relu | OpKind::Relu6 | OpKind::Mul => 1.0,
            OpKind::Sigmoid | OpKind::Tanh => 8.0,
            OpKind::Swish | OpKind::Gelu => 10.0,
            _ => 0.0,
        }
    }

    pub fn is_anchor(self) -> bool {
        matches!(
            self,
            OpKind::Conv2d
                | OpKind::DepthwiseConv2d
                | OpKind::Dense
                | OpKind::BatchMatMul
                | OpKind::MaxPool2d
                | OpKind::AvgPool2d
                | OpKind::GlobalAvgPool2d
                | OpKind::Softmax
                | OpKind::LayerNorm
        )
    }
}

/// Loop-nest skeleton family. Two kernels can only share a schedule if
/// their class signatures match, which implies equal anchors; the anchor is
/// also what the sketch generator keys on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AnchorKind {
    Conv2d,     // axes: n, oc, oh, ow | red: ic, kh, kw
    Depthwise,  // axes: n, c, oh, ow  | red: kh, kw
    Dense,      // axes: m, n          | red: k
    BatchMatMul, // axes: b, m, n      | red: k
    Pool2d,     // axes: n, c, oh, ow  | red: kh, kw
    GlobalPool, // axes: n, c          | red: h, w
    Eltwise,    // axes: flattened points | no reduction
    RowReduce,  // axes: rows          | red: cols (softmax / layernorm)
}

impl AnchorKind {
    pub fn from_op(op: OpKind) -> AnchorKind {
        match op {
            OpKind::Conv2d => AnchorKind::Conv2d,
            OpKind::DepthwiseConv2d => AnchorKind::Depthwise,
            OpKind::Dense => AnchorKind::Dense,
            OpKind::BatchMatMul => AnchorKind::BatchMatMul,
            OpKind::MaxPool2d | OpKind::AvgPool2d => AnchorKind::Pool2d,
            OpKind::GlobalAvgPool2d => AnchorKind::GlobalPool,
            OpKind::Softmax | OpKind::LayerNorm => AnchorKind::RowReduce,
            _ => AnchorKind::Eltwise,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_are_stable() {
        // Class signatures are persisted in schedule stores; tokens must
        // not change silently.
        assert_eq!(OpKind::Conv2d.token(), "conv2d");
        assert_eq!(OpKind::BiasAdd.token(), "bias");
        assert_eq!(OpKind::GlobalAvgPool2d.token(), "global_avg_pool2d");
    }

    #[test]
    fn anchors_map() {
        assert_eq!(AnchorKind::from_op(OpKind::Conv2d), AnchorKind::Conv2d);
        assert_eq!(AnchorKind::from_op(OpKind::MaxPool2d), AnchorKind::Pool2d);
        assert_eq!(AnchorKind::from_op(OpKind::Softmax), AnchorKind::RowReduce);
        assert_eq!(AnchorKind::from_op(OpKind::Relu), AnchorKind::Eltwise);
    }

    #[test]
    fn anchor_ops_flagged() {
        assert!(OpKind::Dense.is_anchor());
        assert!(!OpKind::Relu.is_anchor());
    }
}
