//! PJRT runtime: execute the AOT-compiled JAX/Pallas artifacts from Rust.
//!
//! This is the *real* (non-simulated) execution path of the three-layer
//! architecture. Python lowers the L2 model / L1 Pallas kernels to HLO
//! **text** once (`make artifacts`); this module loads the text, compiles
//! it on the PJRT CPU client, and executes it with concrete buffers —
//! Python is never on the request path.
//!
//! Interchange is HLO text rather than serialized `HloModuleProto`
//! because jax >= 0.5 emits 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! ## Feature gating
//!
//! Real execution needs the external `xla` bindings crate, which the
//! offline build environment does not carry. The implementation is
//! therefore gated behind the non-default `pjrt` cargo feature; the
//! default build gets an API-identical stub whose constructors return an
//! error, so every caller (`repro serve`, the e2e tests, the PJRT bench)
//! compiles and degrades to a clean "runtime unavailable" path. To run
//! for real: add the `xla` dependency to Cargo.toml and build with
//! `--features pjrt`.

use anyhow::Result;
use std::path::Path;

/// Whether real PJRT execution is compiled in. The default (offline)
/// build gets the stub, whose constructors always error — artifact-
/// gated tests and benches must check this too, or they panic instead
/// of skipping when artifacts happen to exist.
pub const AVAILABLE: bool = cfg!(feature = "pjrt");

/// Locate the artifacts directory (env override, then repo default).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("TT_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{Context, Result};
    use std::path::Path;
    use std::time::Instant;

    /// A compiled artifact ready to execute.
    pub struct LoadedKernel {
        pub name: String,
        exe: xla::PjRtLoadedExecutable,
    }

    /// PJRT CPU client wrapper owning every loaded executable.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Ok(Runtime { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load one `*.hlo.txt` artifact and compile it.
        pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedKernel> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("kernel")
                .trim_end_matches(".hlo")
                .to_string();
            Ok(LoadedKernel { name, exe })
        }
    }

    impl LoadedKernel {
        /// Execute with f32 inputs of the given shapes; returns the first
        /// output (artifacts are lowered with `return_tuple=True`, so the
        /// result is unwrapped from a 1-tuple).
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| {
                    xla::Literal::vec1(data)
                        .reshape(shape)
                        .context("reshaping input literal")
                })
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Time `iters` executions (after `warmup` ones); returns per-call
        /// seconds (median-of-means over 3 chunks).
        pub fn bench_f32(
            &self,
            inputs: &[(&[f32], &[i64])],
            warmup: usize,
            iters: usize,
        ) -> Result<f64> {
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, shape)| Ok(xla::Literal::vec1(data).reshape(shape)?))
                .collect::<Result<_>>()?;
            for _ in 0..warmup {
                let bufs = self.exe.execute::<xla::Literal>(&lits)?;
                let _ = bufs[0][0].to_literal_sync()?;
            }
            let chunks = 3usize;
            let per_chunk = iters.div_ceil(chunks).max(1);
            let mut means = Vec::with_capacity(chunks);
            for _ in 0..chunks {
                let t0 = Instant::now();
                for _ in 0..per_chunk {
                    let bufs = self.exe.execute::<xla::Literal>(&lits)?;
                    let _ = bufs[0][0].to_literal_sync()?;
                }
                means.push(t0.elapsed().as_secs_f64() / per_chunk as f64);
            }
            means.sort_by(|a, b| a.partial_cmp(b).unwrap());
            Ok(means[chunks / 2])
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        // Runtime tests that need artifacts live in rust/tests/runtime_e2e.rs
        // (they are skipped when `make artifacts` has not run). Here we only
        // check client creation, which needs no artifacts.
        #[test]
        fn cpu_client_comes_up() {
            let rt = Runtime::cpu().expect("PJRT CPU client");
            assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{LoadedKernel, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::*;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: build with `--features pjrt` (requires the `xla` crate)";

    /// Stub of the compiled-artifact handle; never constructed.
    pub struct LoadedKernel {
        pub name: String,
    }

    /// Stub PJRT client: constructors fail with a clear message so the
    /// CLI/bench/test callers degrade gracefully in offline builds.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo_text(&self, _path: &Path) -> Result<LoadedKernel> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }
    }

    impl LoadedKernel {
        pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }

        pub fn bench_f32(
            &self,
            _inputs: &[(&[f32], &[i64])],
            _warmup: usize,
            _iters: usize,
        ) -> Result<f64> {
            Err(anyhow::anyhow!(UNAVAILABLE))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedKernel, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_is_never_empty() {
        // Note: test processes share env; use a unique var read.
        let d = artifacts_dir();
        assert!(!d.as_os_str().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = Runtime::cpu().unwrap_err().to_string();
        assert!(err.contains("pjrt"), "unexpected error: {err}");
    }
}
