//! Report harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §4 for the experiment index). Each emitter
//! returns a [`crate::util::table::Table`], which the CLI prints and
//! also writes as CSV under `results/`.

pub mod experiments;
pub mod figures;
pub mod tables;

pub use experiments::{republish_model, ExperimentConfig, Zoo, ZooBuildStats, ZooProducer};
