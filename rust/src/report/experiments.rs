//! Shared experiment pipeline: tune the zoo once, reuse everywhere.
//!
//! Almost every figure consumes the same expensive artifacts — the
//! Ansor tuning trajectory of each model and the schedule store built
//! from all of them — so they are computed once per (device, trials,
//! seed) and shared. All results are deterministic in the seed.

use crate::artifact::{self, ArtifactStore};
use crate::autosched::{
    features, fit_pairs, training_target, tune_model, CostModel, CostModelKind, TrainingPair,
    TuneOptions, TuningResult,
};
use crate::coordinator::jobs::{effective_jobs, par_map_indexed};
use crate::coordinator::{content_from_parts, speculative_seed, sweep_key, CacheStats, MeasureCache};
use crate::device::{untuned_model_time, DeviceProfile};
use crate::ir::ModelGraph;
use crate::models;
use crate::sched::apply;
use crate::transfer::{
    rank_tuning_models, transfer_tune_cached, ScheduleStore, TransferOptions, TransferResult,
};
use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::sync::mpsc;

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Ansor trials per model (paper/Fig 1: 20 000; CLI default is lower
    /// for interactive runs — pass `--trials 20000` for the full paper).
    pub trials: usize,
    pub seed: u64,
    pub device: DeviceProfile,
    /// Host worker threads for the build: up to `jobs` models tune
    /// concurrently, and every inner fan-out (sweep pool, tuner batch
    /// evaluation) resolves through the same knob. 0 = inherit the
    /// `--jobs`/`TT_JOBS` setting, else auto-detect. Purely a
    /// wall-clock control — results are bit-identical at any value
    /// (`rust/tests/property_parallel.rs`), which is why it is
    /// deliberately NOT part of any artifact key.
    pub jobs: usize,
    /// Draft-then-verify keep fraction (`--speculative-keep`): each
    /// tuning round's candidate batch is ranked by the cost model and
    /// only the top fraction reaches full simulation; transfer sweeps
    /// prune span-wise the same way. 1.0 (the default) is the exact
    /// path. Unlike `jobs`, this *does* change results, so it is part
    /// of every artifact and measurement-cache key (pruned runs miss an
    /// exact cache instead of colliding with it).
    pub speculative_keep: f64,
    /// Which cost estimator scores candidates (`--cost-model`).
    /// `Static` (the default) is the historical behavior: every tuning
    /// run and draft stage trains its own throwaway model. `Learned`
    /// fits a persistent GBDT prior from the zoo's measurement cache at
    /// deterministic size thresholds (see `crate::autosched::learned`);
    /// once trained, the prior's content hash joins `speculative_keep`
    /// in every artifact and cache key it influences. Until the prior
    /// trains (and always at the default keep for sweeps), a `Learned`
    /// run is byte-identical to `Static`.
    pub cost_model: CostModelKind,
}

impl ExperimentConfig {
    /// The keep fraction with the exact path normalized to exactly 1.0
    /// (values above 1.0 cannot prune, so they must share the exact
    /// path's keys bit-for-bit).
    pub fn effective_keep(&self) -> f64 {
        if self.speculative_keep < 1.0 {
            self.speculative_keep
        } else {
            1.0
        }
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            trials: 2000,
            seed: 0xA45,
            device: DeviceProfile::xeon_e5_2620(),
            jobs: 0,
            speculative_keep: 1.0,
            cost_model: CostModelKind::Static,
        }
    }
}

/// The tuned zoo: all 11 models, their Ansor trajectories, untuned
/// baselines, and the cross-model schedule store.
///
/// All transfer sweeps launched from one zoo share one content-addressed
/// [`MeasureCache`]: the pool-mode sweep of Fig 8 re-evaluates exactly
/// the pairs the one-to-one sweeps already measured (plus the rest of
/// the pool), so sharing the cache removes the duplicate device seconds
/// without changing any result (cache transparency — see
/// `crate::coordinator::cache`). Interior mutability keeps the public
/// `&self` API; report generation is single-threaded.
pub struct Zoo {
    pub config: ExperimentConfig,
    pub models: Vec<ModelGraph>,
    pub tunings: Vec<TuningResult>,
    pub untuned_s: Vec<f64>,
    pub store: ScheduleStore,
    pub cache: RefCell<MeasureCache>,
    /// The learned cost prior ([`ExperimentConfig::cost_model`]).
    /// Untrained for `Static` zoos and for `Learned` zoos whose cache
    /// has not yet crossed the first refit threshold; loaded from the
    /// artifact store on warm starts (zero re-training) and otherwise
    /// fit from the rehydrated cache at build time. Re-fit on demand via
    /// [`Zoo::refit_cost_model`] after sweeps warm the cache further.
    pub cost_model: RefCell<CostModel>,
    /// What this build cost (the warm-start proof inspects it).
    pub build_stats: ZooBuildStats,
}

/// Cost accounting of one [`Zoo::build_incremental`] run: how many
/// models were actually tuned vs served from the artifact store, and
/// what the tuned ones charged. A fully warm build has
/// `models_tuned == 0`, `trials_run == 0`, and
/// `tuning_seconds_charged == 0.0`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ZooBuildStats {
    pub models_tuned: usize,
    pub models_from_artifacts: usize,
    pub trials_run: usize,
    pub tuning_seconds_charged: f64,
}

/// Where one landed tuning came from (accounting + progress label).
enum TuneOrigin {
    Artifact,
    Tuned,
}

/// Worker-thread plumbing for the producer's model-level fan-out. Kept
/// in its own struct so [`ZooProducer::finish`] can destructure the
/// producer while this drop guard still joins any straggling workers
/// (their results land in `rx` — still alive during the join — or the
/// send errors harmlessly once the channel is gone).
struct Fanout {
    /// `None` once every model is scheduled: with no producer-held
    /// sender left, a worker that dies without sending surfaces as a
    /// clean `recv` error instead of a deadlock.
    tx: Option<mpsc::Sender<(usize, TuningResult)>>,
    rx: mpsc::Receiver<(usize, TuningResult)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Fanout {
    fn new() -> Fanout {
        let (tx, rx) = mpsc::channel();
        Fanout { tx: Some(tx), rx, handles: Vec::new() }
    }
}

impl Drop for Fanout {
    fn drop(&mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The streaming front half of a zoo build: tune-or-load one model at a
/// time, persisting each tuning artifact the moment it lands.
///
/// [`Zoo::build_incremental`] drains a producer to completion before
/// anything is served; a streaming deployment instead interleaves
/// [`ZooProducer::publish_next`] with live traffic — each landed model
/// is published into a [`ScheduleService`](crate::service::ScheduleService)
/// as a new store epoch, so sessions are answered with whatever sources
/// exist *now* instead of blocking on the full zoo (`repro serve
/// --listen` runs exactly this loop; `rust/tests/streaming_service.rs`
/// proves partial-zoo replies are bit-identical to a static service
/// over the same sources).
///
/// **Model-level fan-out.** Up to `jobs` models
/// ([`ExperimentConfig::jobs`]) tune concurrently on background worker
/// threads, but results *land* strictly in submission order: a model
/// that finishes early waits in `ready` until every earlier model has
/// been yielded. Stats accounting, artifact-write order, progress
/// lines, epoch numbering — everything downstream of [`ZooProducer::step`]
/// is therefore byte-identical to a serial build; the knob buys
/// wall-clock only.
///
/// **Resume after a crash.** Because every landed tuning is persisted
/// (crash-safely — see `crate::artifact`) *before* the next model
/// lands, a producer restarted over the same store is automatically a
/// resume: models whose artifacts committed load warm
/// (`models_from_artifacts`, 0 trials) and only the interrupted
/// remainder is tuned. No checkpoint file, no resume flag — the
/// artifact store *is* the checkpoint, and its open-time recovery pass
/// guarantees a kill mid-write can only cost the one uncommitted model.
pub struct ZooProducer<'a> {
    config: ExperimentConfig,
    models: Vec<ModelGraph>,
    next: usize,
    artifacts: Option<&'a mut ArtifactStore>,
    /// Learned prior handed to every tuning this producer launches (and
    /// folded into its tuning keys when trained). Zoo builds always run
    /// with the untrained default — the prior is fit *from* the build's
    /// own measurements, so feeding it back in would invalidate warm
    /// starts — but [`republish_model`] re-tunes single models under a
    /// zoo's fitted prior via [`ZooProducer::with_prior`].
    prior: CostModel,
    /// Cost accounting so far (exactly [`Zoo::build_stats`]'s semantics;
    /// a fully warm producer finishes with 0 trials / 0.0 charged).
    pub stats: ZooBuildStats,
    /// Models handed to a worker (or loaded from artifacts) so far.
    scheduled: usize,
    /// Tunings currently running on background workers.
    in_flight: usize,
    /// Completed-but-not-yet-landed results, keyed by model index.
    ready: HashMap<usize, (TuningResult, TuneOrigin)>,
    fanout: Fanout,
}

impl<'a> ZooProducer<'a> {
    /// Producer over the paper's full 11-model zoo.
    pub fn new(config: ExperimentConfig, artifacts: Option<&'a mut ArtifactStore>) -> Self {
        Self::for_models(models::all_models(), config, artifacts)
    }

    /// Producer over an explicit model list (tests; partial zoos).
    pub fn for_models(
        models: Vec<ModelGraph>,
        config: ExperimentConfig,
        artifacts: Option<&'a mut ArtifactStore>,
    ) -> Self {
        ZooProducer {
            config,
            models,
            next: 0,
            artifacts,
            prior: CostModel::default(),
            stats: ZooBuildStats::default(),
            scheduled: 0,
            in_flight: 0,
            ready: HashMap::new(),
            fanout: Fanout::new(),
        }
    }

    /// Tune under a learned prior: the model seeds every launched
    /// tuner's cost model, and (when trained) its content hash becomes
    /// part of each tuning key, so primed tunings never collide with
    /// from-scratch ones.
    pub fn with_prior(mut self, prior: CostModel) -> Self {
        self.prior = prior;
        self
    }

    /// Keep the model-level lookahead full: schedule models in index
    /// order until `jobs` tunings are in flight or everything is
    /// scheduled. Artifact-backed models load right here, on the
    /// consumer thread (deterministic load order, and they never occupy
    /// a worker slot); cold models tune on background workers. With
    /// several model workers the tuner's own candidate fan-out is
    /// pinned to one thread each — the model-level parallelism is the
    /// better use of the same cores — while a serial (`jobs = 1`) build
    /// keeps the whole knob for trial-level parallelism.
    fn pump(&mut self) {
        let slots = effective_jobs(self.config.jobs);
        // Pin the inner tuner to one thread only when model-level
        // parallelism can actually use the cores: a single-model
        // producer (`republish_model`, one-model zoos) keeps the whole
        // knob for trial-level parallelism instead of tuning
        // 1-threaded while every other core idles.
        let inner_jobs = if slots > 1 && self.models.len() > 1 { 1 } else { self.config.jobs };
        while self.scheduled < self.models.len() && self.in_flight < slots {
            let index = self.scheduled;
            self.scheduled += 1;
            let key = artifact::tuning_key(
                &self.models[index].name,
                &self.config.device,
                self.config.trials,
                self.config.seed,
                self.config.effective_keep(),
                self.prior.content_hash(),
            );
            if let Some(res) = self.artifacts.as_deref_mut().and_then(|a| a.load_tuning(key)) {
                self.ready.insert(index, (res, TuneOrigin::Artifact));
                continue;
            }
            let graph = self.models[index].clone();
            let device = self.config.device.clone();
            let opts = TuneOptions {
                trials: self.config.trials,
                seed: self.config.seed,
                jobs: inner_jobs,
                speculative_keep: self.config.effective_keep(),
                prior: self.prior.clone(),
                ..Default::default()
            };
            let tx = self
                .fanout
                .tx
                .as_ref()
                .expect("sender lives while models remain unscheduled")
                .clone();
            self.in_flight += 1;
            let handle = std::thread::Builder::new()
                .name(format!("tt-tune-{}", graph.name))
                .spawn(move || {
                    let res = tune_model(&graph, &device, &opts);
                    let _ = tx.send((index, res));
                })
                .expect("spawn tuning worker");
            self.fanout.handles.push(handle);
        }
        if self.scheduled >= self.models.len() {
            // Everything scheduled: drop our sender so only live
            // workers keep the channel open.
            self.fanout.tx = None;
        }
    }

    pub fn models(&self) -> &[ModelGraph] {
        &self.models
    }

    /// Models not yet produced.
    pub fn remaining(&self) -> usize {
        self.models.len() - self.next
    }

    /// Key under which this producer's zoo-level artifacts (merged
    /// store, measurement cache) live — same derivation as
    /// [`Zoo::artifact_key`]. Always the *base* (model-hash-0) key:
    /// builds run under the untrained prior, and the fitted cost model
    /// itself is stored under this key so a warm start can find it
    /// before any model exists in memory.
    pub fn zoo_key(&self) -> u64 {
        artifact::zoo_key(
            &self.models.iter().map(|m| m.name.clone()).collect::<Vec<_>>(),
            &self.config.device,
            self.config.trials,
            self.config.seed,
            self.config.effective_keep(),
            0,
        )
    }

    /// Tune-or-load the next model and persist its artifact. Returns
    /// the model's index, its tuning, and its untuned baseline time
    /// (computed once, here — the progress line and the consumer both
    /// need it); `None` once every model has landed.
    ///
    /// With `jobs > 1` later models may already be tuning (or finished)
    /// in the background, but this call lands results strictly in
    /// submission order — complete out of order, land in order — so
    /// accounting and persistence cannot depend on worker timing. The
    /// `[host ..s]` figure in the progress line is the wall-clock this
    /// landing *waited*, which is how the fan-out shows up: overlapped
    /// models land in near-zero host time.
    pub fn step(
        &mut self,
        progress: &mut impl FnMut(&str),
    ) -> Option<(usize, TuningResult, f64)> {
        if self.next >= self.models.len() {
            return None;
        }
        let index = self.next;
        self.next += 1;
        let t0 = std::time::Instant::now();
        self.pump();
        let (res, origin) = loop {
            if let Some(hit) = self.ready.remove(&index) {
                break hit;
            }
            let (done, res) = self
                .fanout
                .rx
                .recv()
                .expect("tuning worker died before its result landed");
            self.in_flight -= 1;
            self.ready.insert(done, (res, TuneOrigin::Tuned));
            self.pump(); // a worker slot freed: keep the lookahead full
        };
        let m = &self.models[index];
        let origin_label = match origin {
            TuneOrigin::Artifact => {
                self.stats.models_from_artifacts += 1;
                "artifact"
            }
            TuneOrigin::Tuned => {
                self.stats.models_tuned += 1;
                self.stats.trials_run += res.trials_used;
                self.stats.tuning_seconds_charged += res.search_time_s;
                let cfg = &self.config;
                let key = artifact::tuning_key(
                    &m.name,
                    &cfg.device,
                    cfg.trials,
                    cfg.seed,
                    cfg.effective_keep(),
                    self.prior.content_hash(),
                );
                if let Some(a) = self.artifacts.as_deref_mut() {
                    if let Err(e) = a.save_tuning(key, &res) {
                        progress(&format!("warn: could not persist tuning of {}: {e}", m.name));
                    }
                }
                "tuned"
            }
        };
        let untuned = untuned_model_time(m, &self.config.device);
        progress(&format!(
            "{origin_label:<8} {:<16} trials={} simulated-search={:>9.1}s best-model-time={:.3}ms (untuned {:.3}ms) [host {:.1}s]",
            m.name,
            res.trials_used,
            res.search_time_s,
            res.final_model_time(m, &self.config.device) * 1e3,
            untuned * 1e3,
            t0.elapsed().as_secs_f64(),
        ));
        Some((index, res, untuned))
    }

    /// [`ZooProducer::step`] + publish into a live service: the model's
    /// tuning becomes a new store epoch the moment it lands. Returns
    /// the epoch, or `None` when the zoo is complete.
    pub fn publish_next(
        &mut self,
        service: &crate::service::ScheduleService,
        progress: &mut impl FnMut(&str),
    ) -> Option<u64> {
        let (index, res, _untuned) = self.step(progress)?;
        Some(service.publish_model(&self.models[index], &res))
    }

    /// Tear down into (models, stats, artifact-store borrow) once all
    /// steps ran — what [`Zoo::build_incremental`] needs to finish.
    pub fn finish(self) -> (Vec<ModelGraph>, ZooBuildStats, Option<&'a mut ArtifactStore>) {
        (self.models, self.stats, self.artifacts)
    }
}

/// Re-tune (or re-load, when a matching artifact exists) one model and
/// swap it into a live service at `epoch + 1` — the `republish` admin
/// op. This *is* a one-model [`ZooProducer`] run, so tuning keys,
/// artifact persistence, and warm-start accounting cannot drift from
/// the build path; replies stay a pure function of (target, device,
/// budget, seed, epoch) because a republish is just one more epoch.
/// Returns the new epoch and what the republish cost (a warm republish
/// is `models_from_artifacts == 1`, zero trials).
///
/// `prior` is the learned cost model the re-tune runs under (pass the
/// serving zoo's fitted prior, or the untrained default for the legacy
/// from-scratch path); a trained prior re-keys the tuning artifact, so
/// primed re-tunes are cached separately from from-scratch ones.
pub fn republish_model(
    graph: ModelGraph,
    config: ExperimentConfig,
    prior: CostModel,
    artifacts: Option<&mut ArtifactStore>,
    service: &crate::service::ScheduleService,
    progress: &mut impl FnMut(&str),
) -> (u64, ZooBuildStats) {
    let mut producer = ZooProducer::for_models(vec![graph], config, artifacts).with_prior(prior);
    let epoch = producer
        .publish_next(service, progress)
        .expect("a one-model producer yields exactly one landing");
    (epoch, producer.stats.clone())
}

impl Zoo {
    /// Tune every model in the zoo from scratch (no artifact store).
    /// `progress` receives one line per model (the CLI prints it; tests
    /// pass a sink).
    pub fn build(config: ExperimentConfig, progress: impl FnMut(&str)) -> Zoo {
        Self::build_incremental(config, None, progress)
    }

    /// Build the zoo as an incremental pipeline over an artifact store:
    /// each model's tuning is loaded when a matching artifact exists
    /// (same model, device, trials, seed, format version — see
    /// [`artifact::tuning_key`]) and tuned-then-persisted otherwise; the
    /// zoo's shared measurement cache is likewise rehydrated. A warm run
    /// re-tunes nothing and re-measures nothing, yet every derived
    /// number is bit-identical to the cold run (the codec round-trips
    /// schedules and costs exactly). Call [`Zoo::persist`] after the
    /// experiments to write back the merged store + warmed cache.
    ///
    /// This is the blocking consumer of a [`ZooProducer`]: it drains
    /// every model before returning. A serving process that wants to
    /// answer sessions *while* the zoo tunes drives the producer
    /// directly (see [`ZooProducer::publish_next`]).
    pub fn build_incremental(
        config: ExperimentConfig,
        artifacts: Option<&mut ArtifactStore>,
        progress: impl FnMut(&str),
    ) -> Zoo {
        Self::build_for_models(models::all_models(), config, artifacts, progress)
    }

    /// [`Zoo::build_incremental`] over an explicit model list (tests,
    /// benches, partial zoos). Same producer pipeline, same stats and
    /// artifact semantics; with [`ExperimentConfig::jobs`] > 1, up to
    /// that many models tune concurrently while everything still lands
    /// — and persists — in submission order.
    pub fn build_for_models(
        models: Vec<ModelGraph>,
        config: ExperimentConfig,
        artifacts: Option<&mut ArtifactStore>,
        mut progress: impl FnMut(&str),
    ) -> Zoo {
        let mut producer = ZooProducer::for_models(models, config.clone(), artifacts);
        let mut tunings = Vec::with_capacity(producer.models().len());
        let mut untuned_s = Vec::with_capacity(producer.models().len());
        let mut store = ScheduleStore::new();
        while let Some((index, res, untuned)) = producer.step(&mut progress) {
            let m = &producer.models()[index];
            untuned_s.push(untuned);
            store.add_tuning(m, &res);
            tunings.push(res);
        }
        // Rehydrate the shared measurement cache so warm transfer
        // sweeps charge zero device seconds too.
        let zoo_key = producer.zoo_key();
        let (models, build_stats, mut artifacts) = producer.finish();
        let cache = artifacts
            .as_deref_mut()
            .and_then(|a| a.load_measure_cache(zoo_key))
            .unwrap_or_default();
        // Learned runs: prefer the persisted model (warm start, zero
        // re-training); otherwise fit from whatever the rehydrated
        // cache holds — a cold build has an empty cache and stays
        // untrained until sweeps feed it (see `refit_cost_model`).
        let cost_model = if config.cost_model == CostModelKind::Learned {
            artifacts
                .as_deref_mut()
                .and_then(|a| a.load_cost_model(zoo_key))
                .unwrap_or_default()
        } else {
            CostModel::default()
        };
        let zoo = Zoo {
            config,
            models,
            tunings,
            untuned_s,
            store,
            cache: RefCell::new(cache),
            cost_model: RefCell::new(cost_model),
            build_stats,
        };
        if zoo.config.cost_model == CostModelKind::Learned
            && !zoo.cost_model.borrow().is_trained()
        {
            zoo.refit_cost_model();
        }
        zoo
    }

    /// Export the measurement cache's (features, runtime) pairs as a
    /// training set for the learned prior: every same-class
    /// (kernel, store record) combination across the zoo's models whose
    /// measurement is resident in the cache, identified by content key.
    ///
    /// Pairs are read from the *base* estimator seed space — the one
    /// untrained-prior sweeps and every exact-path (keep = 1.0) sweep
    /// deposit into — so the corpus keeps growing as long as exact
    /// sweeps run, and two caches with the same entries yield the same
    /// corpus regardless of how (or in what order, or at what `--jobs`)
    /// they were warmed. The feature pass is pure and parallel;
    /// `fit_pairs` re-sorts by content key, so nothing here depends on
    /// enumeration order.
    pub fn training_pairs(&self) -> Vec<TrainingPair> {
        let fit_seed = speculative_seed(self.config.seed, self.config.effective_keep());
        let cache = self.cache.borrow();
        let mut seen: HashSet<u64> = HashSet::new();
        let mut found = Vec::new();
        for m in &self.models {
            for kernel in &m.kernels {
                let sig = kernel.class_signature();
                for r in &self.store.records {
                    if r.class_sig != sig {
                        continue;
                    }
                    let content = content_from_parts(kernel.workload_id, r.schedule_hash());
                    if !seen.insert(content) {
                        continue;
                    }
                    let key = sweep_key(content, fit_seed, &self.config.device);
                    if let Some(Some(t)) = cache.peek(key) {
                        found.push((kernel, &r.schedule, content, t));
                    }
                }
            }
        }
        let feats = par_map_indexed(&found, self.config.jobs, |_, job| {
            apply(job.1, job.0).ok().map(|nest| features(job.0, &nest, &self.config.device))
        });
        found
            .iter()
            .zip(feats)
            .filter_map(|(&(_, _, content, t), x)| {
                x.map(|x| TrainingPair { content, x, y: training_target(t) })
            })
            .collect()
    }

    /// Fit (or re-fit) the learned prior from the current cache
    /// contents. No-op for `Static` zoos, and never downgrades a
    /// trained model to untrained (the fit only replaces the prior once
    /// the corpus crosses a refit threshold — see
    /// `crate::autosched::learned::REFIT_THRESHOLDS`). Returns whether
    /// the prior's content hash changed — i.e. whether downstream keys
    /// move.
    pub fn refit_cost_model(&self) -> bool {
        if self.config.cost_model != CostModelKind::Learned {
            return false;
        }
        let fitted = fit_pairs(&self.training_pairs());
        if !fitted.is_trained() {
            return false;
        }
        let changed = fitted.content_hash() != self.cost_model.borrow().content_hash();
        *self.cost_model.borrow_mut() = fitted;
        changed
    }

    /// Key under which this zoo's merged store + measurement cache —
    /// and, for `Learned` runs, the fitted cost model — are persisted.
    /// Always the base (model-hash-0) key: the zoo build itself runs
    /// under the untrained prior (the model is fit *after* the build,
    /// from its measurements), so keying the zoo by its own output
    /// would chicken-and-egg every warm start.
    pub fn artifact_key(&self) -> u64 {
        artifact::zoo_key(
            &self.models.iter().map(|m| m.name.clone()).collect::<Vec<_>>(),
            &self.config.device,
            self.config.trials,
            self.config.seed,
            self.config.effective_keep(),
            0,
        )
    }

    /// Persist the zoo-level artifacts: the merged schedule store
    /// (shareable by the serving layer without the tunings) and the
    /// measurement cache as warmed by whatever experiments ran since
    /// the build. Per-model tunings were already persisted during
    /// [`Zoo::build_incremental`].
    pub fn persist(&self, artifacts: &mut ArtifactStore) -> anyhow::Result<()> {
        let key = self.artifact_key();
        artifacts.save_schedule_store(key, &self.store)?;
        artifacts.save_measure_cache(key, &self.cache.borrow())?;
        let model = self.cost_model.borrow();
        if self.config.cost_model == CostModelKind::Learned && model.is_trained() {
            artifacts.save_cost_model(key, &model)?;
        }
        Ok(())
    }

    pub fn model_index(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.name == name)
    }

    /// The heuristic's ranked tuning-model choices for a target.
    pub fn choices(&self, target: &ModelGraph) -> Vec<(String, f64)> {
        rank_tuning_models(target, &self.store, &self.config.device)
    }

    /// Run one-to-one transfer-tuning onto `target` using the
    /// heuristic's first choice (or a named source). Measurements go
    /// through the zoo's shared cache.
    pub fn transfer(&self, target: &ModelGraph, source: Option<&str>) -> Option<TransferResult> {
        let src = match source {
            Some(s) => s.to_string(),
            None => self.choices(target).first()?.0.clone(),
        };
        let slice = self.store.of_model(&src);
        Some(transfer_tune_cached(
            target,
            &slice,
            &self.config.device,
            &src,
            self.config.seed,
            &TransferOptions {
                speculative_keep: self.config.effective_keep(),
                cost_prior: self.cost_model.borrow().clone(),
                ..Default::default()
            },
            &mut self.cache.borrow_mut(),
        ))
    }

    /// Mixed-pool transfer (§5.5): all models' schedules except the
    /// target's own. Shares the cache with the one-to-one sweeps, so in
    /// a full Fig 8 run the pool mode only pays for pairs no one-to-one
    /// sweep already measured.
    pub fn transfer_pooled(&self, target: &ModelGraph) -> TransferResult {
        let pool = ScheduleStore {
            records: self
                .store
                .records
                .iter()
                .filter(|r| r.source_model != target.name)
                .cloned()
                .collect(),
        };
        transfer_tune_cached(
            target,
            &pool,
            &self.config.device,
            "mixed",
            self.config.seed,
            &TransferOptions {
                speculative_keep: self.config.effective_keep(),
                cost_prior: self.cost_model.borrow().clone(),
                ..Default::default()
            },
            &mut self.cache.borrow_mut(),
        )
    }

    /// Snapshot of the shared cache's counters (hit rate, evictions...).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.borrow().stats.clone()
    }

    /// Ansor speedup achievable within a given search-time budget
    /// (Fig 5a's second bar).
    pub fn ansor_speedup_at(&self, model_idx: usize, budget_s: f64) -> f64 {
        let t = self.tunings[model_idx].model_time_at_budget(budget_s, self.untuned_s[model_idx]);
        self.untuned_s[model_idx] / t
    }

    /// Search time Ansor needs to reach a target end-to-end time
    /// (Fig 5b's second bar); `None` = not reached within its budget.
    pub fn ansor_time_to_match(&self, model_idx: usize, target_time_s: f64) -> Option<f64> {
        self.tunings[model_idx].time_to_reach(target_time_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_zoo() -> Zoo {
        // Small-trial zoo: fast enough for unit tests, still end-to-end.
        Zoo::build(
            ExperimentConfig {
                trials: 120,
                seed: 11,
                device: DeviceProfile::xeon_e5_2620(),
                ..Default::default()
            },
            |_| {},
        )
    }

    #[test]
    fn zoo_builds_all_models_and_store() {
        let zoo = tiny_zoo();
        assert_eq!(zoo.models.len(), 11);
        assert_eq!(zoo.tunings.len(), 11);
        assert!(!zoo.store.records.is_empty());
        assert!(zoo.store.source_models().len() >= 10);
    }

    #[test]
    fn heuristic_choices_exclude_target() {
        let zoo = tiny_zoo();
        let target = &zoo.models[0]; // ResNet18
        let choices = zoo.choices(target);
        assert!(!choices.is_empty());
        assert!(choices.iter().all(|(m, _)| m != "ResNet18"));
    }

    #[test]
    fn transfer_runs_end_to_end() {
        let zoo = tiny_zoo();
        let target = zoo.models[zoo.model_index("ResNet18").unwrap()].clone();
        let res = zoo.transfer(&target, Some("ResNet50")).unwrap();
        assert_eq!(res.source, "ResNet50");
        assert!(res.pairs_evaluated() > 0);
        assert!(res.speedup() >= 0.95, "speedup {}", res.speedup());
    }

    #[test]
    fn pooled_transfer_evaluates_more_pairs() {
        let zoo = tiny_zoo();
        let target = zoo.models[zoo.model_index("ResNet18").unwrap()].clone();
        let one = zoo.transfer(&target, Some("ResNet50")).unwrap();
        let pooled = zoo.transfer_pooled(&target);
        assert!(pooled.pairs_evaluated() >= one.pairs_evaluated());
    }

    #[test]
    fn shared_cache_amortizes_repeated_sweeps_without_changing_results() {
        let zoo = tiny_zoo();
        let target = zoo.models[zoo.model_index("ResNet18").unwrap()].clone();

        let cold = zoo.transfer_pooled(&target);
        assert!(cold.search_time_s() > 0.0);

        // Identical sweep, warm cache: same answer, zero device seconds.
        let warm = zoo.transfer_pooled(&target);
        assert_eq!(warm.tuned_model_s, cold.tuned_model_s);
        assert_eq!(warm.search_time_s(), 0.0);

        // A different mode over overlapping pairs pays only the delta.
        let one = zoo.transfer(&target, Some("ResNet50")).unwrap();
        assert_eq!(one.search_time_s(), 0.0, "one-to-one pairs are a subset of the pool");

        let stats = zoo.cache_stats();
        assert!(stats.hits + stats.dedup_hits > 0);
        assert!(stats.hit_rate() > 0.5, "hit rate {}", stats.hit_rate());
    }

    #[test]
    fn learned_prior_fits_deterministically_and_is_inert_at_exact_keep() {
        let zoo = Zoo::build(
            ExperimentConfig {
                trials: 120,
                seed: 11,
                device: DeviceProfile::xeon_e5_2620(),
                cost_model: CostModelKind::Learned,
                ..Default::default()
            },
            |_| {},
        );
        // Cold build: empty cache, nothing to fit yet.
        assert!(!zoo.cost_model.borrow().is_trained());
        assert!(!zoo.refit_cost_model(), "no corpus, no fit");

        // Warm the cache with pooled sweeps; the full 11-model pool
        // crosses the first refit threshold comfortably.
        let first = zoo.transfer_pooled(&zoo.models[0]);
        for m in zoo.models.iter().skip(1).take(3) {
            zoo.transfer_pooled(m);
        }
        let pairs = zoo.training_pairs();
        assert!(pairs.len() >= 64, "corpus too small: {}", pairs.len());

        assert!(zoo.refit_cost_model(), "first fit must change the prior");
        let hash = zoo.cost_model.borrow().content_hash();
        assert_ne!(hash, 0);
        // Same cache, second fit: idempotent (threshold-bucketed).
        assert!(!zoo.refit_cost_model());
        assert_eq!(zoo.cost_model.borrow().content_hash(), hash);

        // At the default (exact) keep the trained prior is inert: the
        // re-sweep is served entirely from cache, bit-identical.
        let again = zoo.transfer_pooled(&zoo.models[0]);
        assert_eq!(again.tuned_model_s.to_bits(), first.tuned_model_s.to_bits());
        assert_eq!(again.search_time_s(), 0.0, "trained prior must not re-key exact sweeps");
    }
}
