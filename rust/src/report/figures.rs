//! Reproductions of the paper's Figures 1, 4, 5, 6, 7 and 8 as data
//! tables (same rows/series the paper plots; CSV output re-plots them).

use super::experiments::{ExperimentConfig, Zoo};
use crate::autosched::{tune_model, TuneOptions};
use crate::models::{self, letters::LetterBook};
use crate::transfer::{transfer_tune_one_to_one, ScheduleStore};
use crate::util::table::{fmt_duration, fmt_speedup, Table};

/// Fig 1: Ansor's maximum speedup and the search time it took, per model.
pub fn fig1(zoo: &Zoo) -> Table {
    let mut t = Table::new(
        &format!(
            "Fig 1: Ansor speedup & search time ({} trials, {})",
            zoo.config.trials, zoo.config.device.name
        ),
        &["Model", "Untuned", "Tuned", "Max speedup", "Search time"],
    );
    for (mi, m) in zoo.models.iter().enumerate() {
        let tuned = zoo.tunings[mi].final_model_time(m, &zoo.config.device);
        t.row(vec![
            m.name.clone(),
            fmt_duration(zoo.untuned_s[mi]),
            fmt_duration(tuned),
            fmt_speedup(zoo.untuned_s[mi] / tuned),
            fmt_duration(zoo.tunings[mi].search_time_s),
        ]);
    }
    t
}

/// Fig 4: inference time of every ResNet18 kernel under every compatible
/// ResNet50 schedule (long format; `-1` = invalid, matching the paper's
/// convention).
pub fn fig4(zoo: &Zoo) -> Table {
    let target = zoo.models[zoo.model_index("ResNet18").expect("zoo has ResNet18")].clone();
    let res = zoo
        .transfer(&target, Some("ResNet50"))
        .expect("ResNet50 must be in the store");
    let slice = zoo.store.of_model("ResNet50");

    let mut letters = LetterBook::new();
    let mut t = Table::new(
        "Fig 4: ResNet18 kernels x ResNet50 schedules (standalone times)",
        &["Kernel", "Class", "Schedule", "Time (ms)", "Chosen"],
    );
    for sweep in &res.sweeps {
        let k = &target.kernels[sweep.kernel];
        let letter = letters.letter(&k.class_signature());
        let kname = format!("{}", sweep.kernel + 1);
        t.row(vec![
            kname.clone(),
            letter.clone(),
            "untuned".into(),
            format!("{:.4}", sweep.untuned_s * 1e3),
            if sweep.chosen.is_none() { "*".into() } else { "".into() },
        ]);
        for (slot, (ri, outcome)) in sweep.outcomes.iter().enumerate() {
            let rec = &slice.records[*ri];
            let label = rec.label(&letter, slot + 1);
            t.row(vec![
                kname.clone(),
                letter.clone(),
                label,
                match outcome {
                    Some(ts) => format!("{:.4}", ts * 1e3),
                    None => "-1".into(), // invalid code, paper convention
                },
                if sweep.chosen == Some(*ri) { "*".into() } else { "".into() },
            ]);
        }
    }
    t
}

/// Fig 5 (server) / Fig 6 (edge): per model, transfer-tuning speedup vs
/// Ansor-at-equal-search-time, and TT search time vs the time Ansor
/// needs to match TT's speedup. The device comes from the zoo's config.
pub fn fig5(zoo: &Zoo) -> Table {
    let is_edge = zoo.config.device.name != "xeon-e5-2620";
    let title = if is_edge {
        format!("Fig 6: transfer-tuning vs Ansor on edge CPU ({})", zoo.config.device.name)
    } else {
        format!("Fig 5: transfer-tuning vs Ansor on server CPU ({})", zoo.config.device.name)
    };
    let mut t = Table::new(
        &title,
        &[
            "Model",
            "Source",
            "TT speedup",
            "Ansor speedup (same time)",
            "TT search",
            "Ansor to match",
            "Ratio",
        ],
    );
    let mut ratios = Vec::new();
    for (mi, m) in zoo.models.iter().enumerate() {
        let Some(tt) = zoo.transfer(m, None) else { continue };
        // Report standalone (cold-equivalent) search times: they are
        // deterministic in the seed no matter which earlier figures
        // warmed the zoo's shared measurement cache.
        let tt_search = tt.standalone_search_time_s();
        let ansor_same = zoo.ansor_speedup_at(mi, tt_search);
        let to_match = zoo.ansor_time_to_match(mi, tt.tuned_model_s);
        let (match_str, ratio_str) = match to_match {
            Some(s) => {
                let r = s / tt_search;
                ratios.push(r);
                (fmt_duration(s), format!("{r:.1}x"))
            }
            None => {
                let r = zoo.tunings[mi].search_time_s / tt_search;
                ratios.push(r);
                (format!("> {}", fmt_duration(zoo.tunings[mi].search_time_s)), format!("> {r:.1}x"))
            }
        };
        t.row(vec![
            m.name.clone(),
            tt.source.clone(),
            fmt_speedup(tt.speedup()),
            fmt_speedup(ansor_same),
            fmt_duration(tt_search),
            match_str,
            ratio_str,
        ]);
    }
    if !ratios.is_empty() {
        // Two summaries (the paper reports an average of 6.5x server /
        // 10.8x edge): geometric mean (ratios are multiplicative;
        // censored "> x" entries enter at their lower bound) and median
        // (robust to the censoring).
        for (label, value) in [
            ("Geo-mean", crate::util::stats::geomean(&ratios)),
            ("Median", crate::util::stats::median(&ratios)),
        ] {
            t.row(vec![
                label.into(),
                "".into(),
                "".into(),
                "".into(),
                "".into(),
                "".into(),
                format!("{value:.1}x"),
            ]);
        }
    }
    t
}

/// Fig 7: transfer-tuning across sequence lengths for BERT/MobileBERT
/// (128 <-> 256). Tunes the four variants, then transfers both ways.
pub fn fig7(config: &ExperimentConfig, mut progress: impl FnMut(&str)) -> Table {
    let variants = [
        models::bert::bert(128),
        models::bert::bert(256),
        models::bert::mobilebert(128),
        models::bert::mobilebert(256),
    ];
    let opts = TuneOptions {
        trials: config.trials,
        seed: config.seed,
        jobs: config.jobs,
        ..Default::default()
    };
    let mut store = ScheduleStore::new();
    for v in &variants {
        progress(&format!("tuning {} ...", v.name));
        let res = tune_model(v, &config.device, &opts);
        store.add_tuning(v, &res);
    }

    let mut t = Table::new(
        "Fig 7: transfer-tuning across sequence lengths (BERT family)",
        &["Target", "Source", "Speedup", "Search time"],
    );
    let pairs = [
        ("BERT-128", "BERT"),        // 256 -> 128
        ("BERT", "BERT-128"),        // 128 -> 256
        ("MobileBERT-128", "MobileBERT"),
        ("MobileBERT", "MobileBERT-128"),
    ];
    for (target_name, source_name) in pairs {
        let target = variants.iter().find(|v| v.name == target_name).unwrap();
        let res = transfer_tune_one_to_one(target, &store, source_name, &config.device, config.seed);
        t.row(vec![
            target_name.into(),
            source_name.into(),
            fmt_speedup(res.speedup()),
            fmt_duration(res.search_time_s()),
        ]);
    }
    t
}

/// Fig 8: one-to-one vs mixed-pool transfer-tuning (speedup + search
/// time per model). Search columns are standalone (cold-equivalent)
/// costs — the paper's quantity; "Mixed amortized" is what the pooled
/// sweep actually charged after the zoo's shared cache absorbed the
/// pairs the one-to-one sweep already measured.
pub fn fig8(zoo: &Zoo) -> Table {
    let mut t = Table::new(
        "Fig 8: one-to-one vs mixed schedule pool",
        &[
            "Model",
            "One-to-one speedup",
            "Mixed speedup",
            "One-to-one search",
            "Mixed search",
            "Mixed amortized",
            "Mixed regressed?",
        ],
    );
    let mut regressions = 0usize;
    let mut rows = 0usize;
    for m in &zoo.models {
        let Some(one) = zoo.transfer(m, None) else { continue };
        let pooled = zoo.transfer_pooled(m);
        let regressed = pooled.speedup() < one.speedup() - 1e-9;
        if regressed {
            regressions += 1;
        }
        rows += 1;
        t.row(vec![
            m.name.clone(),
            fmt_speedup(one.speedup()),
            fmt_speedup(pooled.speedup()),
            fmt_duration(one.standalone_search_time_s()),
            fmt_duration(pooled.standalone_search_time_s()),
            fmt_duration(pooled.search_time_s()),
            if regressed { "yes".into() } else { "no".into() },
        ]);
    }
    t.row(vec![
        "Summary".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        format!("{regressions}/{rows} regressed"),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceProfile;

    fn tiny_zoo() -> Zoo {
        Zoo::build(
            ExperimentConfig {
                trials: 120,
                seed: 11,
                device: DeviceProfile::xeon_e5_2620(),
                jobs: 0,
                speculative_keep: 1.0,
                ..Default::default()
            },
            |_| {},
        )
    }

    #[test]
    fn fig1_lists_all_models() {
        let zoo = tiny_zoo();
        let t = fig1(&zoo);
        assert_eq!(t.rows.len(), 11);
    }

    #[test]
    fn fig4_contains_untuned_rows_and_choices() {
        let zoo = tiny_zoo();
        let t = fig4(&zoo);
        // 18 kernels -> at least 18 untuned rows.
        let untuned_rows = t.rows.iter().filter(|r| r[2] == "untuned").count();
        assert_eq!(untuned_rows, 18);
        // At least one schedule chosen somewhere.
        assert!(t.rows.iter().any(|r| r[4] == "*"));
    }

    #[test]
    fn fig5_has_mean_row() {
        let zoo = tiny_zoo();
        let t = fig5(&zoo);
        assert_eq!(t.rows.last().unwrap()[0], "Median");
        assert_eq!(t.rows.len(), 13);
    }

    #[test]
    fn fig8_counts_regressions() {
        let zoo = tiny_zoo();
        let t = fig8(&zoo);
        assert!(t.rows.last().unwrap()[6].contains("regressed"));
    }

    #[test]
    fn fig8_search_columns_are_order_independent() {
        // The shared zoo cache must change only the amortized column:
        // running fig8 twice on one zoo (second run fully warm) yields
        // identical standalone search columns.
        let zoo = tiny_zoo();
        let a = fig8(&zoo);
        let b = fig8(&zoo);
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra[3], rb[3], "one-to-one search must not drift");
            assert_eq!(ra[4], rb[4], "mixed search must not drift");
        }
    }
}
