//! Reproductions of the paper's Tables 1–4.

use super::experiments::Zoo;
use crate::device::DeviceProfile;
use crate::models::{self, letters::LetterBook};
use crate::transfer::class_proportions;
use crate::util::table::{fmt_duration, fmt_speedup, Table};

/// Table 1: features of kernels in ResNet18 (class letter, shapes, fused
/// ops, use count). Needs no tuning.
pub fn table1() -> Table {
    let g = models::resnet::resnet18();
    let mut letters = LetterBook::new();
    // Pre-assign letters in paper order.
    for sig in ["conv2d_add", "max_pool2d", "global_avg_pool2d", "dense_add", "conv2d_bias_relu", "conv2d_bias_add_relu"] {
        letters.letter(sig);
    }
    let mut t = Table::new(
        "Table 1: kernels of ResNet18",
        &["ID", "Class", "input_shape", "weight/pool_shape", "TVM Ops", "Use Count"],
    );
    for (i, k) in g.kernels.iter().enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            letters.letter(&k.class_signature()),
            format!("{:?}", k.input_shape),
            format!("{:?}", k.weight_shape),
            k.class_signature(),
            g.use_count(i).to_string(),
        ]);
    }
    t
}

/// Table 2: kernel classes per model (count, % of untuned time) and the
/// heuristic's chosen tuning model.
pub fn table2(zoo: &Zoo) -> Table {
    let mut letters = LetterBook::new();
    let mut t = Table::new(
        "Table 2: kernel classes of DNN models + chosen tuning model",
        &["ID", "Model", "Kernel classes (count, % untuned time)", "Tuning Model"],
    );
    for m in &zoo.models {
        if m.name == "ResNet18" {
            continue; // Table 2 lists M1-M10 only.
        }
        let props = class_proportions(m, &zoo.config.device);
        let mut cells: Vec<String> = Vec::new();
        for (sig, p) in &props {
            let n = m.kernels_of_class(sig).len();
            cells.push(format!("{}({}, {:.0}%)", letters.letter(sig), n, p * 100.0));
        }
        let choice = zoo
            .choices(m)
            .first()
            .map(|(name, _)| name.clone())
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            models::paper_id(&m.name).unwrap_or("-").to_string(),
            m.name.clone(),
            cells.join("; "),
            choice,
        ]);
    }
    t
}

/// Table 3: transfer-tuning speedup using the heuristic's top-3 choices.
pub fn table3(zoo: &Zoo) -> Table {
    let mut t = Table::new(
        "Table 3: speedup with the heuristic's top 3 tuning-model choices",
        &["Model", "Choice 1", "Choice 2", "Choice 3"],
    );
    for m in &zoo.models {
        if m.name == "ResNet18" {
            continue;
        }
        let choices = zoo.choices(m);
        let mut cells = vec![m.name.clone()];
        for ci in 0..3 {
            match choices.get(ci) {
                // The paper leaves zero-score ties blank ("-").
                Some((src, score)) if *score > 1e-9 => {
                    let res = zoo.transfer(m, Some(src)).expect("transfer");
                    let id = models::paper_id(src).unwrap_or(src.as_str());
                    cells.push(format!("{id} ({})", fmt_speedup(res.speedup())));
                }
                _ => cells.push("-".into()),
            }
        }
        t.row(cells);
    }
    t
}

/// Table 4: transfer-tuning versus full Ansor (the zoo's trial budget;
/// the paper uses 20 000 iterations).
///
/// Speedup (%) is the share of Ansor's achievable *improvement* that
/// transfer-tuning reaches: 100*(S_tt - 1)/(S_ansor - 1); search time
/// (%) is the ledger ratio.
pub fn table4(zoo: &Zoo) -> Table {
    let mut t = Table::new(
        &format!("Table 4: transfer-tuning vs {} Ansor trials", zoo.config.trials),
        &["Model", "Speedup (%)", "Search time (%)"],
    );
    let mut sp = Vec::new();
    let mut st = Vec::new();
    for (mi, m) in zoo.models.iter().enumerate() {
        let Some(tt) = zoo.transfer(m, None) else { continue };
        let ansor_best = zoo.untuned_s[mi] / zoo.tunings[mi].final_model_time(m, &zoo.config.device);
        let speedup_pct = if ansor_best > 1.0 {
            100.0 * (tt.speedup() - 1.0).max(0.0) / (ansor_best - 1.0)
        } else {
            100.0
        };
        // Standalone (cold-equivalent) cost: stable no matter which
        // earlier tables/figures warmed the zoo's shared cache.
        let time_pct = 100.0 * tt.standalone_search_time_s() / zoo.tunings[mi].search_time_s;
        sp.push(speedup_pct);
        st.push(time_pct);
        t.row(vec![m.name.clone(), format!("{speedup_pct:.2}"), format!("{time_pct:.2}")]);
    }
    t.row(vec![
        "Mean".into(),
        format!("{:.2}", crate::util::stats::mean(&sp)),
        format!("{:.2}", crate::util::stats::mean(&st)),
    ]);
    t
}

/// The §4.1 GEMM example as a table: native vs transferred schedules for
/// the 512² and 1024² matmuls (simulated; the PJRT-executed counterpart
/// lives in `examples/end_to_end.rs`).
pub fn gemm_transfer(profile: &DeviceProfile, seed: u64) -> Table {
    use crate::autosched::{tune_model, TuneOptions};
    use crate::device::simulate;
    use crate::ir::{KernelBuilder, ModelGraph};
    use crate::sched::{apply, Schedule};

    let opts = TuneOptions { trials: 512, batch_size: 32, seed, ..Default::default() };
    let mut g512 = ModelGraph::new("gemm512");
    g512.push(KernelBuilder::dense(512, 512, 512, &[]));
    let mut g1024 = ModelGraph::new("gemm1024");
    g1024.push(KernelBuilder::dense(1024, 1024, 1024, &[]));

    let r512 = tune_model(&g512, profile, &opts);
    let r1024 = tune_model(&g1024, profile, &opts);
    let s512 = &r512.best[&0].schedule;
    let s1024 = &r1024.best[&0].schedule;
    let k512 = &g512.kernels[0];
    let k1024 = &g1024.kernels[0];

    let time = |s: &Schedule, k| -> Option<f64> { apply(s, k).ok().map(|n| simulate(k, &n, profile).total_s) };
    let naive512 = time(&Schedule::naive(k512), k512).unwrap();
    let naive1024 = time(&Schedule::naive(k1024), k1024).unwrap();

    let mut t = Table::new(
        "GEMM transfer (paper §4.1): native vs cross-applied auto-schedules",
        &["Kernel", "Schedule", "Time", "Speedup vs naive", "Penalty vs native"],
    );
    let mut push = |kname: &str, sname: &str, time_s: Option<f64>, naive: f64, native: f64| {
        match time_s {
            None => t.row(vec![kname.into(), sname.into(), "invalid".into(), "-".into(), "-".into()]),
            Some(ts) => t.row(vec![
                kname.into(),
                sname.into(),
                fmt_duration(ts),
                fmt_speedup(naive / ts),
                format!("{:+.1}%", (ts / native - 1.0) * 100.0),
            ]),
        }
    };
    let n512 = time(s512, k512).unwrap();
    let n1024 = time(s1024, k1024).unwrap();
    push("512x512", "native (tuned on 512)", Some(n512), naive512, n512);
    push("512x512", "transferred from 1024", time(s1024, k512), naive512, n512);
    push("1024x1024", "native (tuned on 1024)", Some(n1024), naive1024, n1024);
    push("1024x1024", "transferred from 512", time(s512, k1024), naive1024, n1024);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_18_rows_and_paper_letters() {
        let t = table1();
        assert_eq!(t.rows.len(), 18);
        let rendered = t.render();
        assert!(rendered.contains("conv2d_bias_add_relu"));
        // Stem conv is class E.
        assert!(t.rows.iter().any(|r| r[1] == "E" && r[2] == "[1, 3, 224, 224]"));
    }

    #[test]
    fn gemm_transfer_penalty_is_small() {
        // Paper: cross-applied GEMM schedules stay within ~5% of native
        // and ~hundreds x over naive. Allow slack for search variance.
        let t = gemm_transfer(&DeviceProfile::xeon_e5_2620(), 3);
        assert_eq!(t.rows.len(), 4);
        for r in &t.rows {
            assert_ne!(r[2], "invalid", "{r:?}");
            let sp: f64 = r[3].trim_end_matches('x').parse().unwrap();
            assert!(sp > 20.0, "speedup over naive too small: {r:?}");
        }
        // Transferred rows within 35% of native (paper: 5%; our search
        // budget here is tiny).
        for r in t.rows.iter().filter(|r| r[1].starts_with("transferred")) {
            let pen: f64 = r[4].trim_end_matches('%').parse().unwrap();
            assert!(pen.abs() < 35.0, "penalty {pen}% too large");
        }
    }
}
