//! Schedule (de)serialization: Ansor-log-like JSON records.
//!
//! The schedule store persists one JSON object per line; the format keeps
//! the shape-relative factors, so stored schedules transfer to new shapes
//! on load without modification.

use super::schedule::{AxisTiling, Schedule};
use crate::ir::AxisKind;
use crate::util::json::{self, Json};

fn kind_token(k: AxisKind) -> &'static str {
    match k {
        AxisKind::Spatial => "S",
        AxisKind::Reduction => "R",
    }
}

fn kind_from(tok: &str) -> anyhow::Result<AxisKind> {
    match tok {
        "S" => Ok(AxisKind::Spatial),
        "R" => Ok(AxisKind::Reduction),
        other => anyhow::bail!("bad axis kind `{other}`"),
    }
}

fn tiling_to_json(t: &AxisTiling) -> Json {
    Json::arr(t.factors.iter().map(|&f| Json::num(f as f64)))
}

fn tiling_from_json(j: &Json) -> anyhow::Result<AxisTiling> {
    let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("tiling must be an array"))?;
    let factors = arr
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as u64)
                .ok_or_else(|| anyhow::anyhow!("tiling factor must be a number"))
        })
        .collect::<anyhow::Result<Vec<u64>>>()?;
    Ok(AxisTiling { factors })
}

pub fn to_json(s: &Schedule) -> Json {
    Json::obj(vec![
        ("class", Json::str(&s.class_sig)),
        (
            "skeleton",
            Json::str(s.skeleton.iter().map(|&k| kind_token(k)).collect::<String>()),
        ),
        ("spatial", Json::arr(s.spatial.iter().map(tiling_to_json))),
        ("reduction", Json::arr(s.reduction.iter().map(tiling_to_json))),
        ("parallel_levels", Json::num(s.parallel_levels as f64)),
        ("vectorize", Json::Bool(s.vectorize)),
        ("unroll_max", Json::num(s.unroll_max as f64)),
        ("cache_write", Json::Bool(s.cache_write)),
    ])
}

pub fn from_json(j: &Json) -> anyhow::Result<Schedule> {
    let class_sig = j.req("class")?.as_str().unwrap_or_default().to_string();
    let skeleton = j
        .req("skeleton")?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("skeleton must be a string"))?
        .chars()
        .map(|c| kind_from(&c.to_string()))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let spatial = j
        .req("spatial")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("spatial must be an array"))?
        .iter()
        .map(tiling_from_json)
        .collect::<anyhow::Result<Vec<_>>>()?;
    let reduction = j
        .req("reduction")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("reduction must be an array"))?
        .iter()
        .map(tiling_from_json)
        .collect::<anyhow::Result<Vec<_>>>()?;
    Ok(Schedule {
        class_sig,
        skeleton,
        spatial,
        reduction,
        parallel_levels: j.req("parallel_levels")?.as_usize().unwrap_or(0),
        vectorize: j.req("vectorize")?.as_bool().unwrap_or(false),
        unroll_max: j.req("unroll_max")?.as_f64().unwrap_or(0.0) as u64,
        cache_write: j.req("cache_write")?.as_bool().unwrap_or(false),
    })
}

pub fn to_string(s: &Schedule) -> String {
    to_json(s).to_compact()
}

pub fn from_str(s: &str) -> anyhow::Result<Schedule> {
    from_json(&json::parse(s)?)
}

/// Canonical content hash of a schedule, used by the measurement cache
/// (`crate::coordinator::cache`) to address (kernel, schedule) pairs.
///
/// Defined as FNV-1a over the canonical JSON serialization: the writer
/// emits object keys in sorted order (`Json::Obj` is a `BTreeMap`) and
/// integral numbers without a fractional part, so the byte string — and
/// therefore the hash — is identical across processes, platforms, and
/// save/load round-trips. Two schedules hash equal iff they are equal as
/// structured records.
pub fn canonical_hash(s: &Schedule) -> u64 {
    crate::ir::workload::fnv1a(to_string(s).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;

    #[test]
    fn roundtrip() {
        let k = KernelBuilder::dense(512, 768, 3072, &[]);
        let mut s = Schedule::untuned_default(&k);
        s.spatial[0] = AxisTiling::of(&[4, 2, 8]);
        s.cache_write = true;
        s.unroll_max = 64;
        let text = to_string(&s);
        let back = from_str(&text).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn skeleton_string_roundtrips() {
        let k = KernelBuilder::conv2d(1, 3, 224, 224, 64, 7, 7, 2, 3, &[]);
        let s = Schedule::naive(&k);
        let j = to_json(&s);
        assert_eq!(j.get("skeleton").unwrap().as_str(), Some("SSSSRRR"));
        assert_eq!(from_json(&j).unwrap().skeleton, s.skeleton);
    }

    #[test]
    fn rejects_malformed() {
        assert!(from_str("{}").is_err());
        assert!(from_str("{\"class\":\"x\",\"skeleton\":\"Q\"}").is_err());
    }

    #[test]
    fn canonical_hash_survives_roundtrip_and_separates_schedules() {
        let k = KernelBuilder::dense(512, 768, 3072, &[]);
        let mut s = Schedule::untuned_default(&k);
        s.spatial[0] = AxisTiling::of(&[4, 2, 8]);
        let h = canonical_hash(&s);
        let back = from_str(&to_string(&s)).unwrap();
        assert_eq!(h, canonical_hash(&back), "hash must survive JSON roundtrip");

        let mut t = s.clone();
        t.unroll_max += 1;
        assert_ne!(h, canonical_hash(&t), "any field change must change the hash");
    }
}
