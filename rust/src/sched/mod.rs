//! Compute schedules: the transformation language of the paper.
//!
//! A [`Schedule`] is a structured, *shape-relative* record of the
//! transformations Ansor's CPU search space applies to a kernel's
//! canonical loop nest: multi-level tiling of every axis (the classic
//! "SSRSRS" structure), outer-loop fusion + parallelization,
//! vectorization of the innermost spatial part, unrolling, and an
//! optional local cache (accumulation) buffer — exactly the primitive
//! vocabulary of the paper's Algorithm 1 (Split / Reorder / Fuse /
//! Parallel / Unroll / Vectorize / ComputeAt + cache buffer).
//!
//! Shape-relative means split factors store only the *inner* tile sizes;
//! the outermost part is derived from the target extent
//! (`Split(N, N/8, 8)` in the paper's notation, §4.1). This is what makes
//! a schedule transferable to a kernel it was not tuned for — and what
//! makes some transfers fail (factor product exceeding the new extent),
//! producing the "-1 / invalid" entries of Fig 4.

pub mod adapt;
pub mod apply;
pub mod schedule;
pub mod serialize;
pub mod trace;

pub use adapt::{adapt_cross_class, is_adaptable};
pub use apply::{apply, ApplyError, Ann, SLoop, ScheduledNest};
pub use schedule::{AxisTiling, Schedule};
