//! Cross-class schedule adaptation (paper §4.2, explicitly left as
//! future work):
//!
//! > "In principle, for kernel classes which share some of the
//! > operations (e.g., classes E and F), their schedules could be
//! > adapted to allow a form of across-class transfer-tuning."
//!
//! Two classes are *adaptation-compatible* when they share the anchor
//! operation (hence the loop-nest skeleton): `conv2d_bias_relu` (E) and
//! `conv2d_bias_add_relu` (F) differ only in the fused epilogue, which
//! lives inside the innermost loop body and does not constrain the
//! tiling. Adapting a schedule = re-basing its class signature onto the
//! target class; every Split/annotation carries over unchanged, and
//! normal shape-relative legality still applies at `apply` time.

use super::schedule::Schedule;
use crate::ir::Kernel;

/// Anchor token of a class signature (`conv2d` of `conv2d_bias_relu`).
pub fn anchor_token(class_sig: &str) -> &str {
    class_sig.split('_').next().unwrap_or(class_sig)
}

/// Can `sched` be adapted onto `target`'s class? Requires the same
/// anchor op *and* the same loop skeleton (e.g. `conv2d` vs `dwconv2d`
/// share neither; `conv2d_bias_relu` vs `conv2d_add` share both).
pub fn is_adaptable(sched: &Schedule, target: &Kernel) -> bool {
    anchor_token(&sched.class_sig) == anchor_token(&target.class_signature())
        && sched.skeleton == target.nest.skeleton()
}

/// Adapt `sched` onto `target`'s class; returns `None` when the classes
/// are not adaptation-compatible. The returned schedule may still fail
/// `apply` on factor-vs-extent grounds, like any transfer.
pub fn adapt_cross_class(sched: &Schedule, target: &Kernel) -> Option<Schedule> {
    if !is_adaptable(sched, target) {
        return None;
    }
    let mut adapted = sched.clone();
    adapted.class_sig = target.class_signature();
    Some(adapted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{KernelBuilder, OpKind};
    use crate::sched::apply;

    fn conv_e() -> Kernel {
        KernelBuilder::conv2d(1, 64, 28, 28, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu])
    }
    fn conv_f() -> Kernel {
        KernelBuilder::conv2d(1, 64, 28, 28, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Add, OpKind::Relu])
    }

    #[test]
    fn e_to_f_adapts_and_applies() {
        // The paper's concrete example: classes E and F share conv2d.
        let e = conv_e();
        let f = conv_f();
        let sched = Schedule::untuned_default(&e);
        // Direct application across classes is invalid (paper §4.2)...
        assert!(apply(&sched, &f).is_err());
        // ...but the adapted schedule is valid.
        let adapted = adapt_cross_class(&sched, &f).expect("E~F share conv2d");
        assert_eq!(adapted.class_sig, "conv2d_bias_add_relu");
        assert!(apply(&adapted, &f).is_ok());
        // Tiling decisions carried over unchanged.
        assert_eq!(adapted.spatial, sched.spatial);
        assert_eq!(adapted.reduction, sched.reduction);
    }

    #[test]
    fn different_anchor_does_not_adapt() {
        let e = conv_e();
        let dense = KernelBuilder::dense(256, 512, 512, &[]);
        let dw = KernelBuilder::depthwise_conv2d(1, 64, 28, 28, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu6]);
        let sched = Schedule::untuned_default(&e);
        assert!(adapt_cross_class(&sched, &dense).is_none());
        assert!(adapt_cross_class(&sched, &dw).is_none());
    }

    #[test]
    fn adapted_schedule_still_checks_factors() {
        // Adaptation does not bypass the factor-vs-extent legality.
        let e = conv_e();
        let tiny_f = KernelBuilder::conv2d(1, 4, 4, 4, 4, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Add, OpKind::Relu]);
        let mut sched = Schedule::untuned_default(&e);
        sched.spatial[1] = crate::sched::AxisTiling::of(&[64]); // oc=4 < 64
        let adapted = adapt_cross_class(&sched, &tiny_f).unwrap();
        assert!(apply(&adapted, &tiny_f).is_err());
    }

    #[test]
    fn anchor_tokens() {
        assert_eq!(anchor_token("conv2d_bias_relu"), "conv2d");
        assert_eq!(anchor_token("dense"), "dense");
        assert_eq!(anchor_token("dwconv2d_bias_relu6"), "dwconv2d");
    }
}
