//! Render a schedule as the primitive trace of the paper's Algorithm 1:
//! Split / Reorder / Fuse / Parallel / Unroll / Vectorize / CacheWrite.
//!
//! Purely for humans (CLI `repro show-schedule`, EXPERIMENTS.md listings);
//! the machine representation stays the structured [`Schedule`].

use super::schedule::Schedule;
use crate::ir::Kernel;
use std::fmt::Write as _;

/// Subscript suffix for a tile level, innermost = `i`, then `o`, `oo`, ...
fn part_name(axis: &str, level: usize, levels: usize) -> String {
    if levels == 1 {
        return axis.to_string();
    }
    if level == levels - 1 {
        format!("{axis}_i")
    } else {
        format!("{axis}_{}", "o".repeat(levels - 1 - level))
    }
}

/// Produce the human-readable primitive trace of applying `sched` to
/// `kernel` (Algorithm-1 style pseudo-schedule).
pub fn trace(sched: &Schedule, kernel: &Kernel) -> String {
    let mut out = String::new();
    let spatial: Vec<(usize, &str, u64)> = kernel
        .nest
        .spatial_axes()
        .map(|(i, a)| (i, a.name, a.extent))
        .collect();
    let reduction: Vec<(usize, &str, u64)> = kernel
        .nest
        .reduction_axes()
        .map(|(i, a)| (i, a.name, a.extent))
        .collect();

    let ls = sched.spatial_levels();
    let lr = sched.reduction_levels();

    // Split lines: innermost factor first, like Alg. 1 lines 6-12.
    for (ti, &(_, name, extent)) in spatial.iter().enumerate() {
        let t = &sched.spatial[ti];
        let mut remaining = format!("{name}");
        for (rev, &f) in t.factors.iter().rev().enumerate() {
            let level = ls - 1 - rev; // level of the part being peeled
            let outer = part_name(name, level - 1, ls);
            let inner = part_name(name, level, ls);
            let _ = writeln!(out, "{outer}, {inner} <- Split({remaining}, {f})");
            remaining = outer;
        }
        if t.factors.is_empty() {
            let _ = writeln!(out, "# {name} left unsplit (extent {extent})");
        }
    }
    for (ti, &(_, name, extent)) in reduction.iter().enumerate() {
        let t = &sched.reduction[ti];
        let mut remaining = format!("{name}");
        for (rev, &f) in t.factors.iter().rev().enumerate() {
            let level = lr - 1 - rev;
            let outer = part_name(name, level - 1, lr);
            let inner = part_name(name, level, lr);
            let _ = writeln!(out, "{outer}, {inner} <- Split({remaining}, {f})");
            remaining = outer;
        }
        if t.factors.is_empty() {
            let _ = writeln!(out, "# {name} left unsplit (extent {extent})");
        }
    }

    if sched.cache_write {
        let _ = writeln!(out, "D <- CacheWrite({})", kernel.nest.output_buffer().name);
    }

    // Reorder line: the SSRSRS interleave.
    let mut order: Vec<String> = Vec::new();
    for level in 0..ls {
        for rl in 0..lr {
            if level >= 1 && ls as i64 - lr as i64 + rl as i64 == level as i64 {
                for &(_, name, _) in &reduction {
                    order.push(part_name(name, rl, lr));
                }
            }
        }
        for &(_, name, _) in &spatial {
            order.push(part_name(name, level, ls));
        }
    }
    for rl in 0..lr {
        if ls as i64 - lr as i64 + rl as i64 <= 0 {
            for &(_, name, _) in &reduction {
                order.push(part_name(name, rl, lr));
            }
        }
    }
    let _ = writeln!(out, "Reorder({})", order.join(", "));

    if sched.parallel_levels > 0 && ls > 1 {
        let fused: Vec<String> = spatial
            .iter()
            .flat_map(|&(_, name, _)| {
                (0..sched.parallel_levels.min(ls - 1)).map(move |l| part_name(name, l, ls))
            })
            .collect();
        let _ = writeln!(out, "F <- Fuse({})", fused.join(", "));
        let _ = writeln!(out, "Parallel(F)");
        if sched.unroll_max > 0 {
            let _ = writeln!(out, "Unroll(F, {})", sched.unroll_max);
        }
    } else if sched.unroll_max > 0 {
        let _ = writeln!(out, "Unroll(body, {})", sched.unroll_max);
    }

    if sched.vectorize {
        if let Some(&(_, name, _)) = spatial.last() {
            let _ = writeln!(out, "Vectorize({})", part_name(name, ls - 1, ls));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;
    use crate::sched::schedule::AxisTiling;

    #[test]
    fn alg1_trace_mentions_all_primitives() {
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let s = Schedule {
            class_sig: k.class_signature(),
            skeleton: k.nest.skeleton(),
            spatial: vec![AxisTiling::of(&[16, 1, 8]), AxisTiling::of(&[16, 1, 8])],
            reduction: vec![AxisTiling::of(&[1])],
            parallel_levels: 1,
            vectorize: true,
            unroll_max: 512,
            cache_write: true,
        };
        let t = trace(&s, &k);
        for needle in ["Split", "Reorder", "Fuse", "Parallel", "Unroll", "Vectorize", "CacheWrite"] {
            assert!(t.contains(needle), "trace missing {needle}:\n{t}");
        }
        // Split of m by 8 appears (innermost factor first).
        assert!(t.contains("Split(m, 8)"), "{t}");
    }

    #[test]
    fn naive_trace_is_reorder_only() {
        let k = KernelBuilder::dense(64, 64, 64, &[]);
        let s = Schedule::naive(&k);
        let t = trace(&s, &k);
        assert!(t.contains("Reorder(m, n, k)"), "{t}");
        assert!(!t.contains("Parallel"));
    }
}
