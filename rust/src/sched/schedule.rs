//! The structured schedule record and its canonical constructors.

use crate::ir::{AxisKind, Kernel};

/// Multi-level tiling of one axis. `factors` are the *inner* part sizes,
/// ordered outer→inner; the outermost part is derived from the target
/// extent at application time (shape-relative form, paper §4.1).
///
/// An axis with `factors = [16, 1, 8]` and extent 512 becomes the 4-level
/// loop (4, 16, 1, 8) — the exact N-axis tiling of the paper's
/// Algorithm 1 (lines 6–8).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AxisTiling {
    pub factors: Vec<u64>,
}

impl AxisTiling {
    pub fn flat() -> Self {
        AxisTiling { factors: vec![] }
    }
    pub fn of(factors: &[u64]) -> Self {
        AxisTiling { factors: factors.to_vec() }
    }
    pub fn inner_product(&self) -> u64 {
        self.factors.iter().product::<u64>().max(1)
    }
    pub fn levels(&self) -> usize {
        self.factors.len() + 1
    }
}

/// A complete schedule for one kernel-class loop skeleton.
///
/// Invariants: every spatial axis has the same number of tile levels
/// (`spatial_levels`), every reduction axis has `reduction_levels`; the
/// loop order is the standard CPU sketch interleaving (S…S R S R S…),
/// reproduced in [`super::apply`].
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    /// Class signature this schedule was *tuned* for (provenance; transfer
    /// legality is checked against the target's signature).
    pub class_sig: String,
    /// Axis-kind skeleton of the nest it applies to (structural check).
    pub skeleton: Vec<AxisKind>,
    /// Per-spatial-axis tilings, canonical axis order.
    pub spatial: Vec<AxisTiling>,
    /// Per-reduction-axis tilings, canonical axis order.
    pub reduction: Vec<AxisTiling>,
    /// Number of outermost spatial levels fused into the parallel loop
    /// (0 = single-threaded).
    pub parallel_levels: usize,
    /// Vectorize the innermost part of the last spatial axis.
    pub vectorize: bool,
    /// `pragma auto_unroll_max_step`-style unroll budget (0 = off).
    pub unroll_max: u64,
    /// Stage the output in a local accumulation buffer (Algorithm 1,
    /// line 22: "Create Local Cache Buffer").
    pub cache_write: bool,
}

impl Schedule {
    pub fn spatial_levels(&self) -> usize {
        self.spatial.first().map(|t| t.levels()).unwrap_or(1)
    }
    pub fn reduction_levels(&self) -> usize {
        self.reduction.first().map(|t| t.levels()).unwrap_or(1)
    }

    /// The completely unoptimized schedule: one loop per axis, no
    /// annotations. This is the paper's "unmodified computation" baseline
    /// from §4.1 (the one auto-schedules beat by ~250x on GEMM).
    pub fn naive(kernel: &Kernel) -> Schedule {
        let spatial = kernel.nest.spatial_axes().map(|_| AxisTiling::flat()).collect();
        let reduction = kernel.nest.reduction_axes().map(|_| AxisTiling::flat()).collect();
        Schedule {
            class_sig: kernel.class_signature(),
            skeleton: kernel.nest.skeleton(),
            spatial,
            reduction,
            parallel_levels: 0,
            vectorize: false,
            unroll_max: 0,
            cache_write: false,
        }
    }

    /// TVM-fallback-style default schedule: parallel over the outer
    /// spatial loop, vectorize the innermost spatial axis, small unroll —
    /// but *no* multi-level cache tiling and no cache write. This is the
    /// paper's "untuned" baseline (compiled "using TVM's standard untuned
    /// schedules", §5.1): decent for convolutions, poor for the large
    /// dense kernels that dominate BERT — which is why the paper's BERT
    /// max speedup is 59x while CNNs sit near 1.1–1.6x.
    pub fn untuned_default(kernel: &Kernel) -> Schedule {
        let n_spatial = kernel.nest.spatial_axes().count();
        let mut spatial: Vec<AxisTiling> = Vec::with_capacity(n_spatial);
        for (i, (_, axis)) in kernel.nest.spatial_axes().enumerate() {
            if i + 1 == n_spatial {
                // Innermost spatial axis: peel a vector-width tile if it
                // divides cleanly; 8 = f32 lanes of 256-bit SIMD.
                let f = if axis.extent % 8 == 0 { 8 } else { 1 };
                spatial.push(AxisTiling::of(&[f]));
            } else {
                spatial.push(AxisTiling::of(&[1]));
            }
        }
        let reduction = kernel.nest.reduction_axes().map(|_| AxisTiling::flat()).collect();
        Schedule {
            class_sig: kernel.class_signature(),
            skeleton: kernel.nest.skeleton(),
            spatial,
            reduction,
            parallel_levels: 1,
            vectorize: true,
            unroll_max: 16,
            cache_write: false,
        }
    }

    /// Human-readable one-line summary (used in Fig 4 row labels).
    pub fn summary(&self) -> String {
        let tiles: Vec<String> = self
            .spatial
            .iter()
            .map(|t| {
                format!(
                    "[{}]",
                    t.factors.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(",")
                )
            })
            .collect();
        let red: Vec<String> = self
            .reduction
            .iter()
            .map(|t| {
                format!(
                    "[{}]",
                    t.factors.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(",")
                )
            })
            .collect();
        format!(
            "S{} R{} par{}{}{} u{}",
            tiles.join(""),
            red.join(""),
            self.parallel_levels,
            if self.vectorize { " vec" } else { "" },
            if self.cache_write { " cw" } else { "" },
            self.unroll_max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{KernelBuilder, OpKind};

    #[test]
    fn naive_has_flat_tilings() {
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let s = Schedule::naive(&k);
        assert_eq!(s.spatial.len(), 2);
        assert_eq!(s.reduction.len(), 1);
        assert_eq!(s.spatial_levels(), 1);
        assert!(!s.vectorize && s.parallel_levels == 0);
    }

    #[test]
    fn default_vectorizes_when_divisible() {
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let s = Schedule::untuned_default(&k);
        assert_eq!(s.spatial[1].factors, vec![8]);
        assert!(s.vectorize);
    }

    #[test]
    fn default_skips_vector_tile_when_indivisible() {
        let k = KernelBuilder::dense(1, 512, 63, &[OpKind::Add]);
        let s = Schedule::untuned_default(&k);
        assert_eq!(s.spatial[1].factors, vec![1]);
    }

    #[test]
    fn algorithm1_tiling_roundtrip() {
        // Paper Algorithm 1, N axis of the 512 GEMM: parts (4,16,1,8).
        let t = AxisTiling::of(&[16, 1, 8]);
        assert_eq!(t.inner_product(), 128);
        assert_eq!(t.levels(), 4);
        // Derived outer for extent 512 = 4.
        assert_eq!(512 / t.inner_product(), 4);
    }
}
