//! Schedule application: (schedule, kernel) → concrete annotated loop nest.
//!
//! This is where transfer legality is decided (paper §4.1/§4.2):
//!
//! * a schedule can only be applied to a kernel whose loop skeleton
//!   matches (cross-class transfers "would always be invalid");
//! * a split whose inner-factor product exceeds the target extent
//!   produces invalid code ("if the schedule defines a loop splitting
//!   factor which is larger than the loop itself") — these are Fig 4's
//!   `-1` entries;
//! * a split that does not divide evenly is *valid* but pays a padding
//!   penalty (the reformulated `Split(N, ceil(N/8), 8)` covers the space
//!   with a partial tail tile).

use super::schedule::Schedule;
use crate::ir::Kernel;

/// Loop annotation, in increasing priority.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ann {
    None,
    Parallel,
    Unroll,
    Vectorize,
}

/// One loop of the scheduled nest, outer→inner order.
#[derive(Clone, Copy, Debug)]
pub struct SLoop {
    /// Canonical axis this loop is a part of.
    pub axis: usize,
    /// Trip count of this part.
    pub extent: u64,
    pub ann: Ann,
    /// Tile level within its axis (0 = outermost/derived part).
    pub level: usize,
}

/// The result of applying a schedule: what the cost simulator consumes.
#[derive(Clone, Debug)]
pub struct ScheduledNest {
    pub loops: Vec<SLoop>,
    pub cache_write: bool,
    /// Padding overhead from imperfect splits: ratio of padded iteration
    /// domain to the true domain (>= 1.0).
    pub waste: f64,
}

impl ScheduledNest {
    /// Product of extents of loops annotated Parallel.
    pub fn parallel_extent(&self) -> u64 {
        self.loops
            .iter()
            .filter(|l| l.ann == Ann::Parallel)
            .map(|l| l.extent)
            .product::<u64>()
            .max(1)
    }

    /// Extent of the vectorized loop (1 if none).
    pub fn vector_extent(&self) -> u64 {
        self.loops
            .iter()
            .find(|l| l.ann == Ann::Vectorize)
            .map(|l| l.extent)
            .unwrap_or(1)
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ApplyError {
    /// Op sequences differ — schedule references computations the target
    /// does not have.
    ClassMismatch { expected: String, got: String },
    /// Axis-kind skeletons differ (defensive; implied by class today).
    SkeletonMismatch,
    /// Inner-factor product exceeds the target axis extent → invalid code.
    FactorExceedsExtent { axis: usize, product: u64, extent: u64 },
    /// A zero split factor can never generate valid code.
    ZeroFactor { axis: usize },
}

impl std::fmt::Display for ApplyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApplyError::ClassMismatch { expected, got } => {
                write!(f, "class mismatch: schedule for `{expected}`, kernel is `{got}`")
            }
            ApplyError::SkeletonMismatch => write!(f, "loop skeleton mismatch"),
            ApplyError::FactorExceedsExtent { axis, product, extent } => write!(
                f,
                "split factors (product {product}) exceed extent {extent} on axis {axis}"
            ),
            ApplyError::ZeroFactor { axis } => write!(f, "zero split factor on axis {axis}"),
        }
    }
}

impl std::error::Error for ApplyError {}

/// Apply `sched` to `kernel`. `strict_class` gates the class-signature
/// check; the transfer engine always uses strict mode, matching the paper
/// (schedules are only reused within a kernel class).
pub fn apply(sched: &Schedule, kernel: &Kernel) -> Result<ScheduledNest, ApplyError> {
    if sched.class_sig != kernel.class_signature() {
        return Err(ApplyError::ClassMismatch {
            expected: sched.class_sig.clone(),
            got: kernel.class_signature(),
        });
    }
    if sched.skeleton != kernel.nest.skeleton() {
        return Err(ApplyError::SkeletonMismatch);
    }

    let spatial_axes: Vec<usize> = kernel.nest.spatial_axes().map(|(i, _)| i).collect();
    let reduction_axes: Vec<usize> = kernel.nest.reduction_axes().map(|(i, _)| i).collect();
    debug_assert_eq!(spatial_axes.len(), sched.spatial.len());
    debug_assert_eq!(reduction_axes.len(), sched.reduction.len());

    // Per-axis part extents: [derived outer, inner factors...]. Outer is
    // ceil(extent / prod) — the shape-relative reformulation; waste is the
    // padding this introduces.
    let mut waste = 1.0f64;
    let mut parts_of = |axis: usize, factors: &[u64]| -> Result<Vec<u64>, ApplyError> {
        let extent = kernel.nest.axes[axis].extent;
        if factors.iter().any(|&f| f == 0) {
            return Err(ApplyError::ZeroFactor { axis });
        }
        let prod: u64 = factors.iter().product::<u64>().max(1);
        if prod > extent {
            return Err(ApplyError::FactorExceedsExtent { axis, product: prod, extent });
        }
        let outer = extent.div_ceil(prod);
        waste *= (outer * prod) as f64 / extent as f64;
        let mut parts = Vec::with_capacity(factors.len() + 1);
        parts.push(outer);
        parts.extend_from_slice(factors);
        Ok(parts)
    };

    let mut spatial_parts: Vec<Vec<u64>> = Vec::with_capacity(spatial_axes.len());
    for (i, &axis) in spatial_axes.iter().enumerate() {
        spatial_parts.push(parts_of(axis, &sched.spatial[i].factors)?);
    }
    let mut reduction_parts: Vec<Vec<u64>> = Vec::with_capacity(reduction_axes.len());
    for (i, &axis) in reduction_axes.iter().enumerate() {
        reduction_parts.push(parts_of(axis, &sched.reduction[i].factors)?);
    }

    let ls = sched.spatial_levels();
    let lr = sched.reduction_levels();

    // Interleave levels in the standard CPU sketch order (paper Alg. 1
    // line 13/30): reduction level rl sits just above spatial level
    // `ls - lr + rl`; reduction levels whose slot falls at or below 0 go
    // innermost (classic untiled reduction).
    let mut loops: Vec<SLoop> = Vec::with_capacity(spatial_axes.len() * ls + reduction_axes.len() * lr);
    let parallel_levels = sched.parallel_levels.min(ls.saturating_sub(1));
    let emit_spatial = |loops: &mut Vec<SLoop>, level: usize| {
        for (i, &axis) in spatial_axes.iter().enumerate() {
            let ann = if level < parallel_levels { Ann::Parallel } else { Ann::None };
            loops.push(SLoop { axis, extent: spatial_parts[i][level], ann, level });
        }
    };
    let emit_reduction = |loops: &mut Vec<SLoop>, level: usize| {
        for (i, &axis) in reduction_axes.iter().enumerate() {
            loops.push(SLoop { axis, extent: reduction_parts[i][level], ann: Ann::None, level });
        }
    };

    // Parallel block first (fused outer spatial levels are hoisted above
    // any reduction loop, as Fuse+Parallel does in Alg. 1 lines 14-15).
    for level in 0..parallel_levels {
        emit_spatial(&mut loops, level);
    }
    let mut emitted_r = 0usize;
    for level in parallel_levels..ls {
        // Reductions slotted above this spatial level (slots < 1 go
        // innermost instead — the classic untiled reduction).
        while emitted_r < lr
            && level >= 1
            && (ls as i64 - lr as i64 + emitted_r as i64) == level as i64
        {
            emit_reduction(&mut loops, emitted_r);
            emitted_r += 1;
        }
        emit_spatial(&mut loops, level);
    }
    // Remaining reduction levels (slot <= 0 or beyond): innermost.
    while emitted_r < lr {
        emit_reduction(&mut loops, emitted_r);
        emitted_r += 1;
    }

    // Vectorize: innermost part of the last spatial axis.
    if sched.vectorize {
        if let Some(&last_sp) = spatial_axes.last() {
            if let Some(l) = loops
                .iter_mut()
                .rev()
                .find(|l| l.axis == last_sp && l.level == ls - 1)
            {
                if l.extent > 1 {
                    l.ann = Ann::Vectorize;
                }
            }
        }
    }

    // Unroll: innermost non-vectorized loops whose cumulative trip product
    // stays within the unroll budget.
    if sched.unroll_max > 0 {
        let mut budget = sched.unroll_max;
        for l in loops.iter_mut().rev() {
            if l.ann == Ann::Vectorize {
                continue;
            }
            if l.ann != Ann::None || l.extent > budget {
                break;
            }
            l.ann = Ann::Unroll;
            budget /= l.extent.max(1);
            if budget <= 1 {
                break;
            }
        }
    }

    Ok(ScheduledNest { loops, cache_write: sched.cache_write, waste })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{KernelBuilder, OpKind};
    use crate::sched::schedule::AxisTiling;

    fn gemm(n: u64) -> Kernel {
        KernelBuilder::dense(n, n, n, &[])
    }

    /// The paper's Algorithm 1 schedule for the 512 GEMM: N/M tiled
    /// (outer, 16, 1, 8), K tiled (outer, 1), fuse+parallel outer,
    /// unroll 512, vectorize M_i.
    fn alg1_512() -> Schedule {
        let k = gemm(512);
        Schedule {
            class_sig: k.class_signature(),
            skeleton: k.nest.skeleton(),
            spatial: vec![AxisTiling::of(&[16, 1, 8]), AxisTiling::of(&[16, 1, 8])],
            reduction: vec![AxisTiling::of(&[1])],
            parallel_levels: 1,
            vectorize: true,
            unroll_max: 512,
            cache_write: false,
        }
    }

    #[test]
    fn alg1_loop_structure() {
        let nest = apply(&alg1_512(), &gemm(512)).unwrap();
        // 2 spatial axes x 4 levels + 1 reduction x 2 levels = 10 loops
        // (paper line 13 reorder has exactly 10 ranges).
        assert_eq!(nest.loops.len(), 10);
        // Outer parallel pair: derived outer = 512/128 = 4 each.
        assert_eq!(nest.loops[0].extent, 4);
        assert_eq!(nest.loops[0].ann, Ann::Parallel);
        assert_eq!(nest.loops[1].extent, 4);
        assert_eq!(nest.parallel_extent(), 16);
        // Innermost loop is the vectorized M_i = 8.
        assert_eq!(nest.vector_extent(), 8);
        assert_eq!(nest.loops.last().unwrap().ann, Ann::Vectorize);
        assert!((nest.waste - 1.0).abs() < 1e-12);
    }

    #[test]
    fn transfer_512_schedule_to_1024_is_valid() {
        // The paper's §4.1 experiment: cross-applying the two GEMM
        // schedules still produces valid code.
        let nest = apply(&alg1_512(), &gemm(1024)).unwrap();
        // Derived outer becomes 1024/128 = 8.
        assert_eq!(nest.loops[0].extent, 8);
        assert!((nest.waste - 1.0).abs() < 1e-12);
    }

    #[test]
    fn factor_exceeding_extent_is_invalid() {
        // Applying the same schedule to a 56-extent kernel: 16*1*8 = 128 > 56.
        let err = apply(&alg1_512(), &gemm(56)).unwrap_err();
        assert!(matches!(err, ApplyError::FactorExceedsExtent { product: 128, extent: 56, .. }));
    }

    #[test]
    fn imperfect_split_pays_waste() {
        let k = gemm(96);
        let mut s = alg1_512();
        s.spatial = vec![AxisTiling::of(&[8]), AxisTiling::of(&[8])];
        s.reduction = vec![AxisTiling::flat()];
        // 96 % 8 == 0 -> no waste.
        assert!((apply(&s, &k).unwrap().waste - 1.0).abs() < 1e-12);
        // Extent 100 with factor 8: outer = 13, padded = 104, waste = 1.04 per axis.
        let k2 = gemm(100);
        let w = apply(&s, &k2).unwrap().waste;
        assert!((w - (104.0f64 / 100.0).powi(2)).abs() < 1e-9, "waste {w}");
    }

    #[test]
    fn cross_class_is_rejected() {
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]);
        let err = apply(&alg1_512(), &conv).unwrap_err();
        assert!(matches!(err, ApplyError::ClassMismatch { .. }));
    }

    #[test]
    fn naive_schedule_is_canonical_order() {
        let k = gemm(64);
        let nest = apply(&Schedule::naive(&k), &k).unwrap();
        // n, m, k single loops; reduction innermost.
        assert_eq!(nest.loops.len(), 3);
        assert_eq!(nest.loops[2].axis, 2);
        assert!(nest.loops.iter().all(|l| l.ann == Ann::None));
    }

    #[test]
    fn untuned_default_annotations() {
        let k = gemm(512);
        let nest = apply(&Schedule::untuned_default(&k), &k).unwrap();
        // Fused outer parallel loop: m (512) x n_outer (512/8 = 64).
        assert_eq!(nest.parallel_extent(), 512 * 64);
        assert_eq!(nest.vector_extent(), 8);
    }

    #[test]
    fn unroll_marks_inner_loops() {
        let k = gemm(512);
        let mut s = alg1_512();
        s.vectorize = false;
        let nest = apply(&s, &k).unwrap();
        let unrolled: Vec<_> = nest.loops.iter().filter(|l| l.ann == Ann::Unroll).collect();
        // Budget 512 covers the inner (8, 1, 8, ...) loops.
        assert!(!unrolled.is_empty());
        // Unrolled loops are a contiguous innermost suffix.
        let first = nest.loops.iter().position(|l| l.ann == Ann::Unroll).unwrap();
        assert!(nest.loops[first..].iter().all(|l| l.ann == Ann::Unroll));
    }

    #[test]
    fn zero_factor_rejected() {
        let k = gemm(64);
        let mut s = Schedule::naive(&k);
        s.spatial[0] = AxisTiling::of(&[0]);
        assert!(matches!(apply(&s, &k).unwrap_err(), ApplyError::ZeroFactor { .. }));
    }
}
