//! L3 coordination: measurement fan-out, search-time accounting, and
//! remote-device emulation.
//!
//! The paper's system is a *tuning pipeline*: candidates are generated,
//! compiled, and timed on a target device, with the total device
//! wall-clock being the quantity every experiment reports. This module
//! owns that machinery: a deterministic multi-threaded measurement pool
//! (host-side parallelism never leaks into device-time accounting), the
//! search-time [`Ledger`], and the RPC session model used for the
//! Raspberry-Pi experiments.

pub mod ledger;
pub mod metrics;
pub mod pool;
pub mod rpc;

pub use ledger::Ledger;
pub use metrics::LatencyHistogram;
pub use pool::{measure_pairs, PairOutcome};
pub use rpc::RemoteSession;
