//! L3 coordination: measurement fan-out, caching, search-time
//! accounting, and remote-device emulation.
//!
//! The paper's system is a *tuning pipeline*: candidates are generated,
//! compiled, and timed on a target device, with the total device
//! wall-clock being the quantity every experiment reports. This module
//! owns that machinery: a deterministic multi-threaded measurement pool
//! (host-side parallelism never leaks into device-time accounting), the
//! content-addressed [`MeasureCache`] that lets repeated sweeps pay for
//! a pair once, the search-time [`Ledger`], and the RPC session model
//! used for the Raspberry-Pi experiments (with a batched executor that
//! amortizes round-trips).

pub mod cache;
pub mod jobs;
pub mod ledger;
pub mod metrics;
pub mod pool;
pub mod rpc;

pub use cache::{
    content_from_parts, content_key, estimator_seed, pair_key, profile_key, speculative_seed,
    sweep_key, CacheStats, MeasureCache, Resolution,
};
pub use jobs::{effective_jobs, global_jobs, set_global_jobs};
pub use ledger::Ledger;
pub use metrics::{LatencyHistogram, SweepMetrics};
pub use pool::{
    measure_pairs, measure_pairs_cached, measure_pairs_cached_generic,
    measure_pairs_cached_precomputed, CacheOps, CachedBatch, PairOutcome,
};
pub use rpc::RemoteSession;
