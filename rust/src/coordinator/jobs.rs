//! The process-wide `--jobs` knob and the deterministic parallel map.
//!
//! Every parallel fan-out in the system — the measurement pool, the
//! tuner's per-round candidate evaluation, the zoo build's model-level
//! workers — resolves its thread count through [`effective_jobs`], so
//! one knob governs them all:
//!
//! 1. an explicit per-call request (`TuneOptions::jobs`,
//!    `ExperimentConfig::jobs`) when non-zero;
//! 2. else the process-global override set by `--jobs`
//!    ([`set_global_jobs`]);
//! 3. else the `TT_JOBS` environment variable (how CI pins constrained
//!    runners to reproducible thread counts);
//! 4. else [`std::thread::available_parallelism`].
//!
//! The knob is a *wall-clock* control only. Results are bit-identical
//! at any setting: parallel sections compute pure work (no RNG, no
//! shared mutable state) into index-ordered slots, and every seeded
//! draw happens serially in submission order — the same discipline as
//! `pool::measure_with_noise`'s content-derived noise. The property
//! suite (`rust/tests/property_parallel.rs`) holds `tune_model`, zoo
//! builds, and `ScheduleService::open_session` to that invariant across
//! `jobs ∈ {1, 2, 8}`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Process-global `--jobs` override; 0 = unset (fall through to
/// `TT_JOBS`, then auto-detection).
static GLOBAL_JOBS: AtomicUsize = AtomicUsize::new(0);

/// `TT_JOBS`, parsed once per process (the variable is a launch-time
/// setting; re-reading it per batch would only add syscalls).
fn env_jobs() -> usize {
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("TT_JOBS").ok().and_then(|s| s.parse::<usize>().ok()).unwrap_or(0)
    })
}

/// Set the process-global jobs override (the CLI's `--jobs`). 0 clears
/// it. Safe to change at any time: thread counts never affect results.
pub fn set_global_jobs(n: usize) {
    GLOBAL_JOBS.store(n, Ordering::Relaxed);
}

/// The current process-global override (0 = unset).
pub fn global_jobs() -> usize {
    GLOBAL_JOBS.load(Ordering::Relaxed)
}

/// Resolve a worker count: `requested` if non-zero, else the global
/// `--jobs` override, else `TT_JOBS`, else available parallelism.
/// Always returns at least 1.
pub fn effective_jobs(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    let global = global_jobs();
    if global > 0 {
        return global;
    }
    let env = env_jobs();
    if env > 0 {
        return env;
    }
    match std::thread::available_parallelism() {
        Ok(n) => n.get(),
        Err(err) => {
            // Logged once per process: results are identical at any
            // width, so a mis-sized pool is otherwise invisible — only
            // wall-clock (and CI timings) silently degrade.
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                eprintln!(
                    "[jobs] available_parallelism failed ({err}); assuming 4 workers \
                     (set --jobs or TT_JOBS to size the pool explicitly)"
                );
            });
            4
        }
    }
}

/// Deterministic indexed parallel map: applies `f` to every item on a
/// scoped thread pool of [`effective_jobs`]`(jobs)` workers and returns
/// the results **in input order**, regardless of which worker finished
/// first. `f` must be pure (it runs concurrently and its evaluation
/// order is unspecified); with that contract the output is bit-identical
/// at any thread count, which is what lets the tuner fan its candidate
/// batches out without perturbing a single seeded draw.
pub(crate) fn par_map_indexed<T, R, F>(items: &[T], jobs: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n_threads = effective_jobs(jobs).min(items.len().max(1));
    if n_threads <= 1 || items.len() < 2 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = items.len().div_ceil(n_threads).max(1);
    let mut results: Vec<Option<R>> = Vec::with_capacity(items.len());
    results.resize_with(items.len(), || None);
    std::thread::scope(|scope| {
        for (ci, (item_chunk, res_chunk)) in
            items.chunks(chunk).zip(results.chunks_mut(chunk)).enumerate()
        {
            let f = &f;
            scope.spawn(move || {
                for (j, (item, slot)) in item_chunk.iter().zip(res_chunk.iter_mut()).enumerate() {
                    *slot = Some(f(ci * chunk + j, item));
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_request_wins() {
        assert_eq!(effective_jobs(3), 3);
        assert_eq!(effective_jobs(1), 1);
    }

    #[test]
    fn resolution_always_positive() {
        assert!(effective_jobs(0) >= 1);
    }

    #[test]
    fn precedence_explicit_then_global_then_detected() {
        // Explicit per-call request beats the process-global override...
        set_global_jobs(3);
        assert_eq!(effective_jobs(5), 5);
        // ...the global override beats TT_JOBS and detection...
        assert_eq!(effective_jobs(0), 3);
        set_global_jobs(0);
        // ...and with both unset, resolution falls through to TT_JOBS
        // (OnceLock-latched at first use, so not assertable here) or
        // detected parallelism — positive either way, even when
        // `available_parallelism` fails and the logged 4-worker
        // fallback kicks in.
        assert!(effective_jobs(0) >= 1);
    }

    #[test]
    fn par_map_preserves_input_order_at_any_width() {
        let items: Vec<u64> = (0..97).collect();
        let serial: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        for jobs in [1, 2, 3, 8, 64] {
            let par = par_map_indexed(&items, jobs, |_, &x| x * x + 1);
            assert_eq!(par, serial, "jobs={jobs}");
        }
    }

    #[test]
    fn par_map_passes_true_indices() {
        let items: Vec<u64> = (0..57).collect();
        let idx = par_map_indexed(&items, 4, |i, _| i);
        assert_eq!(idx, (0..57).collect::<Vec<usize>>());
    }

    #[test]
    fn par_map_handles_tiny_inputs() {
        let empty: Vec<u64> = Vec::new();
        assert!(par_map_indexed(&empty, 8, |_, &x| x).is_empty());
        assert_eq!(par_map_indexed(&[9u64], 8, |_, &x| x + 1), vec![10]);
    }
}
