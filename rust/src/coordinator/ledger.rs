//! Search-time accounting.
//!
//! Every search-time number in the paper (Fig 1, 5b, 6b, 8b, Table 4) is
//! tuning wall-clock on the target device. Our measurements are
//! simulated, so the ledger charges what the real process would cost:
//! candidate codegen+compile overhead, timed repeats × kernel runtime,
//! RPC round-trips for remote (edge) tuning, and cost-model training.
//! The ledger is *sequential device time* — the host pipeline may
//! parallelize, but the device runs one candidate at a time, exactly
//! like Ansor's measurer.

use crate::device::DeviceProfile;

#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub seconds: f64,
    pub measurements: usize,
    pub compile_failures: usize,
    /// Measurements lost to runner/device failure (injected or real):
    /// the device time was spent but no runtime came back.
    pub measure_failures: usize,
    pub train_rounds: usize,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one successful candidate measurement.
    pub fn charge_measure(&mut self, profile: &DeviceProfile, runtime_s: f64) {
        self.seconds +=
            profile.measure_overhead_s + profile.rpc_overhead_s + profile.measure_repeats as f64 * runtime_s;
        self.measurements += 1;
    }

    /// Charge a candidate the compiler rejected (invalid transferred
    /// schedule / invalid mutation): codegen time is still spent.
    pub fn charge_compile_fail(&mut self, profile: &DeviceProfile) {
        self.seconds += 0.3 * (profile.measure_overhead_s + profile.rpc_overhead_s);
        self.compile_failures += 1;
    }

    /// Charge a measurement that was *lost* (crashed runner, dropped
    /// RPC, injected fault): the overhead was paid and `penalty_s`
    /// models the wasted device occupancy, but no runtime came back —
    /// so the pair stays uncached and is re-measured on the next sweep.
    /// This is how Ansor's measurer accounts for timeouts/crashes:
    /// routine outcomes that cost time, not errors that stop tuning.
    pub fn charge_measure_failure(&mut self, profile: &DeviceProfile, penalty_s: f64) {
        self.seconds += profile.measure_overhead_s + profile.rpc_overhead_s + penalty_s;
        self.measure_failures += 1;
    }

    /// Charge a cost-model training round.
    pub fn charge_train(&mut self, seconds: f64) {
        self.seconds += seconds;
        self.train_rounds += 1;
    }

    pub fn merge(&mut self, other: &Ledger) {
        self.seconds += other.seconds;
        self.measurements += other.measurements;
        self.compile_failures += other.compile_failures;
        self.measure_failures += other.measure_failures;
        self.train_rounds += other.train_rounds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut l = Ledger::new();
        l.charge_measure(&prof, 0.01);
        l.charge_measure(&prof, 0.02);
        l.charge_compile_fail(&prof);
        l.charge_train(1.5);
        assert_eq!(l.measurements, 2);
        assert_eq!(l.compile_failures, 1);
        let expect = 2.0 * prof.measure_overhead_s + 3.0 * 0.03 + 0.3 * prof.measure_overhead_s + 1.5;
        assert!((l.seconds - expect).abs() < 1e-9, "{} vs {expect}", l.seconds);
    }

    #[test]
    fn lost_measurement_charges_penalty_without_a_runtime() {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut l = Ledger::new();
        l.charge_measure_failure(&prof, 2.5);
        assert_eq!(l.measure_failures, 1);
        assert_eq!(l.measurements, 0, "a lost measurement is not a measurement");
        let expect = prof.measure_overhead_s + prof.rpc_overhead_s + 2.5;
        assert!((l.seconds - expect).abs() < 1e-12);
        let mut m = Ledger::new();
        m.merge(&l);
        assert_eq!(m.measure_failures, 1);
    }

    #[test]
    fn rpc_makes_edge_measurements_dearer() {
        let xeon = DeviceProfile::xeon_e5_2620();
        let edge = DeviceProfile::cortex_a72();
        let mut a = Ledger::new();
        let mut b = Ledger::new();
        a.charge_measure(&xeon, 0.01);
        b.charge_measure(&edge, 0.01);
        assert!(b.seconds > a.seconds);
    }

    #[test]
    fn merge_sums() {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut a = Ledger::new();
        a.charge_measure(&prof, 0.01);
        let mut b = Ledger::new();
        b.charge_measure(&prof, 0.02);
        b.merge(&a);
        assert_eq!(b.measurements, 2);
    }
}
