//! RPC-device emulation for remote (edge) tuning.
//!
//! Ansor tunes constrained devices by connecting them to a host over RPC
//! (paper §5.3): candidates are compiled on the host, shipped to the
//! device, timed there, and reported back. The emulation models the
//! request lifecycle — serialize/upload, remote execution, report — so
//! edge search-time experiments charge the right costs, and exposes
//! queue statistics like a real tracker would.
//!
//! [`RemoteSession::measure_batch`] is the batched executor: unique
//! cache-missing candidates are bundled into one upload (a single RTT
//! instead of one per candidate) and cached pairs never leave the host —
//! the two costs that dominate edge sweeps (paper Fig 6's search times
//! are RPC-bound).

use super::cache::{content_key, sweep_key, MeasureCache, Resolution};
use super::pool::noise_seed;
use crate::device::{measure, DeviceProfile};
use crate::ir::Kernel;
use crate::sched::{apply, Schedule};
use crate::util::rng::Rng;

/// Simulated remote measurement session against one device.
pub struct RemoteSession {
    pub profile: DeviceProfile,
    /// Session seed; pair noise derives from (seed, pair content), the
    /// same stream the host pool uses, so the per-candidate and batched
    /// entry points agree on every measurement.
    seed: u64,
    /// Upload bandwidth host→device for compiled artifacts, bytes/s.
    pub upload_bps: f64,
    /// Compiled artifact size per candidate (bytes).
    pub artifact_bytes: f64,
    /// Candidates actually executed on the device (cache/dedup hits in
    /// batched mode never become requests).
    pub requests: usize,
    pub failures: usize,
    /// Total device-side seconds consumed (the edge ledger component).
    pub device_seconds: f64,
    /// Total transport seconds (upload + RTT).
    pub transport_seconds: f64,
}

impl RemoteSession {
    pub fn new(profile: DeviceProfile, seed: u64) -> Self {
        RemoteSession {
            profile,
            seed,
            upload_bps: 10e6,        // 10 MB/s: Wi-Fi/100Mb ethernet class
            artifact_bytes: 1.5e6,   // shared object + params
            requests: 0,
            failures: 0,
            device_seconds: 0.0,
            transport_seconds: 0.0,
        }
    }

    /// Measure one candidate remotely. Returns the measured runtime, or
    /// `None` when codegen failed (still costs host time; no upload).
    /// Always ships and re-measures — use [`measure_batch`](Self::measure_batch)
    /// to go through the cache; both return identical runtimes for the
    /// same candidate.
    pub fn measure_remote(&mut self, kernel: &Kernel, sched: &Schedule) -> Option<f64> {
        self.requests += 1;
        match apply(sched, kernel) {
            Err(_) => {
                self.failures += 1;
                None
            }
            Ok(nest) => {
                let mut rng = Rng::new(noise_seed(self.seed, content_key(kernel, sched)));
                let runtime = measure(kernel, &nest, &self.profile, &mut rng);
                self.transport_seconds += self.artifact_bytes / self.upload_bps + 0.05; // RTT
                self.device_seconds += self.profile.measure_repeats as f64 * runtime;
                Some(runtime)
            }
        }
    }

    /// Total tuning seconds this session consumed (what the paper's edge
    /// search-time axis shows).
    pub fn total_seconds(&self) -> f64 {
        self.device_seconds
            + self.transport_seconds
            + self.requests as f64 * self.profile.measure_overhead_s
    }

    /// Batched remote measurement through the content-addressed cache.
    ///
    /// Compared to calling [`measure_remote`](Self::measure_remote) per
    /// candidate:
    ///
    /// * duplicate candidates in the batch and cache-resident candidates
    ///   are served host-side — no upload, no device seconds;
    /// * the remaining unique misses ship as **one** artifact bundle:
    ///   upload bytes scale with the miss count but the RTT is paid once
    ///   per batch instead of once per candidate;
    /// * measurement noise is derived from (seed, pair content), exactly
    ///   like the host pool, so cached entries interoperate between the
    ///   local and remote executors (for the same device profile — keys
    ///   are device-scoped).
    ///
    /// The hit/validate/corrupt-recovery semantics are shared with the
    /// host pool through [`MeasureCache::resolve_with`]; only the cost
    /// model (transport + per-request overhead instead of a ledger)
    /// lives here.
    ///
    /// Returns per-candidate runtimes in batch order (`None` = the
    /// schedule does not apply). Noise comes from the session seed and
    /// the pair content, so this agrees with both
    /// [`measure_remote`](Self::measure_remote) and host-pool sweeps at
    /// the same seed.
    pub fn measure_batch(
        &mut self,
        jobs: &[(&Kernel, &Schedule)],
        cache: &mut MeasureCache,
    ) -> Vec<Option<f64>> {
        let mut out: Vec<Option<f64>> = Vec::with_capacity(jobs.len());
        let mut miss_count = 0usize;
        let mut seen_in_batch: std::collections::HashMap<u64, Option<f64>> =
            std::collections::HashMap::new();
        for &(kernel, sched) in jobs {
            let content = content_key(kernel, sched);
            let key = sweep_key(content, self.seed, &self.profile);
            if let Some(&rt) = seen_in_batch.get(&key) {
                cache.stats.dedup_hits += 1;
                out.push(rt);
                continue;
            }
            // Shared resolution front half (same semantics as the host
            // pool — see MeasureCache::resolve_with); only the cost
            // model below differs.
            let rt = match cache.resolve_with(key, || apply(sched, kernel).map(|_| ())) {
                Resolution::Hit(t) => Some(t),
                Resolution::HitInvalid(_) => None,
                Resolution::Corrupt | Resolution::Miss => match apply(sched, kernel) {
                    Err(_) => {
                        // New codegen failure: host work, nothing shipped.
                        self.requests += 1;
                        self.failures += 1;
                        cache.insert(key, None);
                        None
                    }
                    Ok(nest) => {
                        // A real tuning request (cache and dedup hits
                        // never become one, so total_seconds() charges
                        // no per-measurement overhead for them).
                        self.requests += 1;
                        let mut rng = Rng::new(noise_seed(self.seed, content));
                        let runtime = measure(kernel, &nest, &self.profile, &mut rng);
                        self.transport_seconds += self.artifact_bytes / self.upload_bps;
                        self.device_seconds += self.profile.measure_repeats as f64 * runtime;
                        miss_count += 1;
                        cache.insert(key, Some(runtime));
                        Some(runtime)
                    }
                },
            };
            seen_in_batch.insert(key, rt);
            out.push(rt);
        }
        if miss_count > 0 {
            self.transport_seconds += 0.05; // one RTT for the whole bundle
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;

    #[test]
    fn remote_measurement_accumulates_costs() {
        let mut sess = RemoteSession::new(DeviceProfile::cortex_a72(), 3);
        let k = KernelBuilder::dense(128, 128, 128, &[]);
        let s = Schedule::untuned_default(&k);
        let t = sess.measure_remote(&k, &s).unwrap();
        assert!(t > 0.0);
        assert_eq!(sess.requests, 1);
        assert!(sess.total_seconds() > sess.device_seconds);
    }

    #[test]
    fn failures_counted_without_upload() {
        let mut sess = RemoteSession::new(DeviceProfile::cortex_a72(), 3);
        let k = KernelBuilder::dense(8, 8, 8, &[]);
        let mut s = Schedule::untuned_default(&k);
        s.spatial[1] = crate::sched::AxisTiling::of(&[64]);
        assert!(sess.measure_remote(&k, &s).is_none());
        assert_eq!(sess.failures, 1);
        assert_eq!(sess.transport_seconds, 0.0);
    }

    #[test]
    fn batch_amortizes_rtt_and_dedups() {
        let prof = DeviceProfile::cortex_a72();
        let k1 = KernelBuilder::dense(128, 128, 128, &[]);
        let k2 = KernelBuilder::dense(256, 256, 256, &[]);
        let s1 = Schedule::untuned_default(&k1);
        let s2 = Schedule::untuned_default(&k2);
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&k1, &s1), (&k2, &s2), (&k1, &s1)];

        // Per-candidate path: three RTTs, three uploads.
        let mut solo = RemoteSession::new(prof.clone(), 3);
        let mut solo_times = Vec::new();
        for &(k, s) in &jobs {
            solo_times.push(solo.measure_remote(k, s).unwrap());
        }

        // Batched path: duplicate collapsed, one RTT, two uploads —
        // same runtimes (both entry points draw content-derived noise).
        let mut sess = RemoteSession::new(prof.clone(), 3);
        let mut cache = MeasureCache::new();
        let out = sess.measure_batch(&jobs, &mut cache);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[2], "identical candidates measure identically");
        for (a, b) in solo_times.iter().zip(&out) {
            assert_eq!(Some(*a), *b, "per-candidate and batched APIs must agree");
        }
        assert!(sess.transport_seconds < solo.transport_seconds);
        let expected = 2.0 * sess.artifact_bytes / sess.upload_bps + 0.05;
        assert!((sess.transport_seconds - expected).abs() < 1e-9);

        // Warm batch: nothing ships, device idle, and no requests are
        // issued — the edge search-time axis (total_seconds) must not
        // grow for cached pairs.
        let before_device = sess.device_seconds;
        let before_transport = sess.transport_seconds;
        let before_requests = sess.requests;
        let before_total = sess.total_seconds();
        let warm = sess.measure_batch(&jobs, &mut cache);
        assert_eq!(warm, out);
        assert_eq!(sess.device_seconds, before_device);
        assert_eq!(sess.transport_seconds, before_transport);
        assert_eq!(sess.requests, before_requests);
        assert_eq!(sess.total_seconds(), before_total);
    }

    #[test]
    fn batch_interoperates_with_host_pool_cache() {
        use crate::coordinator::{measure_pairs_cached, Ledger};
        let prof = DeviceProfile::cortex_a72();
        let k = KernelBuilder::dense(128, 128, 128, &[]);
        let s = Schedule::untuned_default(&k);
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&k, &s)];

        // Warm the cache via the host pool...
        let mut cache = MeasureCache::new();
        let mut ledger = Ledger::new();
        let host = measure_pairs_cached(&jobs, &prof, 3, &mut cache, &mut ledger);

        // ...then the remote batch on the SAME device hits it and
        // returns the same value.
        let mut sess = RemoteSession::new(prof, 3);
        let remote = sess.measure_batch(&jobs, &mut cache);
        assert_eq!(remote[0], host[0].runtime());
        assert_eq!(sess.device_seconds, 0.0);

        // A session against a different device must not be served the
        // other profile's entries.
        let mut other = RemoteSession::new(DeviceProfile::xeon_e5_2620(), 3);
        let cross = other.measure_batch(&jobs, &mut cache);
        assert!(other.device_seconds > 0.0, "cross-device lookups must miss");
        assert_ne!(cross[0], remote[0]);
    }
}
