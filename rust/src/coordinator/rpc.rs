//! RPC-device emulation for remote (edge) tuning.
//!
//! Ansor tunes constrained devices by connecting them to a host over RPC
//! (paper §5.3): candidates are compiled on the host, shipped to the
//! device, timed there, and reported back. The emulation models the
//! request lifecycle — serialize/upload, remote execution, report — so
//! edge search-time experiments charge the right costs, and exposes
//! queue statistics like a real tracker would.

use crate::device::{measure, DeviceProfile};
use crate::ir::Kernel;
use crate::sched::{apply, Schedule};
use crate::util::rng::Rng;

/// Simulated remote measurement session against one device.
pub struct RemoteSession {
    pub profile: DeviceProfile,
    rng: Rng,
    /// Upload bandwidth host→device for compiled artifacts, bytes/s.
    pub upload_bps: f64,
    /// Compiled artifact size per candidate (bytes).
    pub artifact_bytes: f64,
    pub requests: usize,
    pub failures: usize,
    /// Total device-side seconds consumed (the edge ledger component).
    pub device_seconds: f64,
    /// Total transport seconds (upload + RTT).
    pub transport_seconds: f64,
}

impl RemoteSession {
    pub fn new(profile: DeviceProfile, seed: u64) -> Self {
        RemoteSession {
            profile,
            rng: Rng::new(seed),
            upload_bps: 10e6,        // 10 MB/s: Wi-Fi/100Mb ethernet class
            artifact_bytes: 1.5e6,   // shared object + params
            requests: 0,
            failures: 0,
            device_seconds: 0.0,
            transport_seconds: 0.0,
        }
    }

    /// Measure one candidate remotely. Returns the measured runtime, or
    /// `None` when codegen failed (still costs host time; no upload).
    pub fn measure_remote(&mut self, kernel: &Kernel, sched: &Schedule) -> Option<f64> {
        self.requests += 1;
        match apply(sched, kernel) {
            Err(_) => {
                self.failures += 1;
                None
            }
            Ok(nest) => {
                let runtime = measure(kernel, &nest, &self.profile, &mut self.rng);
                self.transport_seconds += self.artifact_bytes / self.upload_bps + 0.05; // RTT
                self.device_seconds += self.profile.measure_repeats as f64 * runtime;
                Some(runtime)
            }
        }
    }

    /// Total tuning seconds this session consumed (what the paper's edge
    /// search-time axis shows).
    pub fn total_seconds(&self) -> f64 {
        self.device_seconds
            + self.transport_seconds
            + self.requests as f64 * self.profile.measure_overhead_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;

    #[test]
    fn remote_measurement_accumulates_costs() {
        let mut sess = RemoteSession::new(DeviceProfile::cortex_a72(), 3);
        let k = KernelBuilder::dense(128, 128, 128, &[]);
        let s = Schedule::untuned_default(&k);
        let t = sess.measure_remote(&k, &s).unwrap();
        assert!(t > 0.0);
        assert_eq!(sess.requests, 1);
        assert!(sess.total_seconds() > sess.device_seconds);
    }

    #[test]
    fn failures_counted_without_upload() {
        let mut sess = RemoteSession::new(DeviceProfile::cortex_a72(), 3);
        let k = KernelBuilder::dense(8, 8, 8, &[]);
        let mut s = Schedule::untuned_default(&k);
        s.spatial[1] = crate::sched::AxisTiling::of(&[64]);
        assert!(sess.measure_remote(&k, &s).is_none());
        assert_eq!(sess.failures, 1);
        assert_eq!(sess.transport_seconds, 0.0);
    }
}
