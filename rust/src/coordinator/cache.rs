//! Content-addressed measurement cache.
//!
//! The paper's thesis is that *reusing* prior tuning work beats
//! re-searching (§4.3, §5: Ansor needs ~6.5x more search time to match
//! transfer-tuning), yet a naive engine re-measures every
//! (kernel, schedule) pair on every `transfer_tune` call — pooled-store
//! runs (Fig 8) and report sweeps re-pay identical device seconds dozens
//! of times. This module memoizes standalone pair measurements so a
//! deployment amortizes tuning cost: a cached pair costs **zero** device
//! seconds on the search-time ledger.
//!
//! ## Addressing
//!
//! Entries are addressed by content, never by position:
//!
//! * the **kernel** contributes its [`workload id`](crate::ir::workload)
//!   — FNV-1a of (class signature, axis extents, input/weight shapes) —
//!   so identical kernels hit regardless of which model or graph slot
//!   they appear in;
//! * the **schedule** contributes its
//!   [`canonical hash`](crate::sched::serialize::canonical_hash) — FNV-1a
//!   of the canonical (sorted-key, compact) JSON serialization — so a
//!   schedule hits after any store save/load round-trip;
//! * the **device profile** contributes its name hash: a runtime is a
//!   property of (pair, device), and a shared cache must never serve a
//!   Xeon measurement to a Cortex-A72 sweep;
//! * the **measurement seed** is folded in last: simulated measurements
//!   are seeded-noisy, and a cache entry records *the measurement that
//!   seed would produce*. Including the seed keeps the headline
//!   invariant exact instead of approximate.
//!
//! ## Invariants
//!
//! 1. **Transparency**: for a fixed seed, a sweep served from the cache
//!    returns bit-identical outcomes (and therefore a bit-identical
//!    `TransferResult::tuned_model_s`) to the same sweep with the cache
//!    disabled. This holds because the parallel executor derives each
//!    pair's measurement noise from the same content key the cache is
//!    addressed by (see [`super::pool`]), not from job order.
//! 2. **Zero-cost hits**: the ledger is charged only on misses; a warm
//!    sweep charges exactly 0.0 device seconds.
//! 3. **Stability**: keys are built exclusively from FNV-1a over
//!    canonical byte strings — identical across processes, platforms,
//!    and persistence round-trips (guarded by golden-file tests).
//! 4. **Bounded mode**: with a capacity, eviction is exact LRU on
//!    lookup/insert order; unbounded mode never evicts.
//!
//! Persistence is JSON via [`crate::util::json`] (the environment is
//! offline — no serde): keys serialize as 16-digit hex strings because
//! JSON numbers (f64) cannot carry 64-bit hashes losslessly.

use crate::device::DeviceProfile;
use crate::ir::workload::fnv1a;
use crate::ir::Kernel;
use crate::sched::{serialize, Schedule};
use crate::util::json::{self, Json};
use std::collections::{HashMap, VecDeque};
use std::path::Path;

/// Content key of a (kernel, schedule) pair, independent of the
/// measurement seed and device. Stable across processes (FNV-1a over
/// FNV-1a).
pub fn content_key(kernel: &Kernel, sched: &Schedule) -> u64 {
    content_from_parts(kernel.workload_id, serialize::canonical_hash(sched))
}

/// [`content_key`] from already-computed parts. Sweep planners hash
/// each store record's schedule once and reuse it across every kernel
/// it is tried on, instead of re-serializing the schedule per pair.
pub fn content_from_parts(workload_id: u64, sched_hash: u64) -> u64 {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&workload_id.to_le_bytes());
    bytes[8..].copy_from_slice(&sched_hash.to_le_bytes());
    fnv1a(&bytes)
}

/// Identity hash of a device profile. Profiles are a closed set named
/// by construction (`xeon-e5-2620`, `cortex-a72`), so the name is the
/// stable identity.
pub fn profile_key(profile: &DeviceProfile) -> u64 {
    fnv1a(profile.name.as_bytes())
}

/// Full cache key: content key + measurement-noise seed + device.
pub fn sweep_key(content: u64, seed: u64, profile: &DeviceProfile) -> u64 {
    let mut bytes = [0u8; 24];
    bytes[..8].copy_from_slice(&content.to_le_bytes());
    bytes[8..16].copy_from_slice(&seed.to_le_bytes());
    bytes[16..].copy_from_slice(&profile_key(profile).to_le_bytes());
    fnv1a(&bytes)
}

/// Convenience: the cache key of one pair under one seed and device.
pub fn pair_key(kernel: &Kernel, sched: &Schedule, seed: u64, profile: &DeviceProfile) -> u64 {
    sweep_key(content_key(kernel, sched), seed, profile)
}

/// Fold a draft-then-verify keep fraction into a measurement seed, so a
/// speculative sweep's cache entries can never collide with (or be
/// served to) an exact sweep at the same seed. `keep = 1.0` — the exact
/// path — returns the seed unchanged, keeping every legacy key and
/// golden fixture byte-identical; any other keep value mixes its exact
/// bit pattern in deterministically.
pub fn speculative_seed(seed: u64, keep: f64) -> u64 {
    if keep.to_bits() == 1.0f64.to_bits() {
        return seed;
    }
    seed ^ keep.to_bits().rotate_left(17).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Fold a trained cost model's content hash into a measurement seed, so
/// sweeps drafted by a learned prior live in their own cache key space
/// (a retrained model misses warm entries instead of being served
/// drafts ranked by a different model). `model_hash = 0` — the
/// untrained/static estimator, whose hash is defined as zero — returns
/// the seed unchanged, keeping every legacy key and golden fixture
/// byte-identical. Composes with [`speculative_seed`]: the keep
/// fraction and the model hash are independent key ingredients.
pub fn estimator_seed(seed: u64, model_hash: u64) -> u64 {
    if model_hash == 0 {
        return seed;
    }
    seed ^ model_hash.rotate_left(23).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Hit/miss/eviction counters. `hits` are lookups served from the map;
/// `dedup_hits` are duplicates collapsed within a single batch by the
/// executor before any measurement happened (same amortization, tracked
/// separately because the entry was not yet resident).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub dedup_hits: u64,
    pub inserts: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups that avoided device time (resident + dedup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.dedup_hits + self.misses;
        if total == 0 {
            0.0
        } else {
            (self.hits + self.dedup_hits) as f64 / total as f64
        }
    }

    pub fn lookups(&self) -> u64 {
        self.hits + self.dedup_hits + self.misses
    }

    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.dedup_hits += other.dedup_hits;
        self.inserts += other.inserts;
        self.evictions += other.evictions;
    }
}

/// Outcome of [`MeasureCache::resolve_with`].
pub enum Resolution<E> {
    /// Resident measured runtime.
    Hit(f64),
    /// Resident invalid pair, re-validated; carries the fresh error.
    HitInvalid(E),
    /// Resident entry disagreed with validation (corrupt/stale); it was
    /// reclassified as a miss — re-measure and overwrite.
    Corrupt,
    /// Not resident.
    Miss,
}

#[derive(Clone, Debug)]
struct Entry {
    /// Measured standalone runtime; `None` = the schedule does not apply
    /// to the kernel (Fig 4's `-1` entries are cacheable too).
    runtime: Option<f64>,
    /// Monotonic touch tick for exact LRU with lazy queue cleanup.
    tick: u64,
}

/// The content-addressed measurement cache. See the module doc for the
/// key derivation and invariants.
#[derive(Clone, Debug, Default)]
pub struct MeasureCache {
    map: HashMap<u64, Entry>,
    /// (key, tick) in touch order; stale pairs (tick != map tick) are
    /// skipped lazily during eviction.
    order: VecDeque<(u64, u64)>,
    capacity: Option<usize>,
    next_tick: u64,
    pub stats: CacheStats,
}

impl MeasureCache {
    /// Unbounded cache (never evicts).
    pub fn new() -> Self {
        Self::default()
    }

    /// Bounded LRU cache holding at most `capacity` entries.
    pub fn with_capacity(capacity: usize) -> Self {
        MeasureCache { capacity: Some(capacity.max(1)), ..Self::default() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Forget the counters (entries stay). Useful to meter one phase.
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Reclassify one recorded hit as a miss. Executors call this when
    /// a looked-up entry turns out to be corrupt/stale and they
    /// re-measure honestly — otherwise a poisoned cache could report a
    /// 100% hit rate on a run that charged device seconds.
    pub fn reclassify_hit_as_miss(&mut self) {
        debug_assert!(self.stats.hits > 0, "no hit to reclassify");
        self.stats.hits = self.stats.hits.saturating_sub(1);
        self.stats.misses += 1;
    }

    /// Resolve one lookup with cached-invalid re-validation — the
    /// shared front half of every executor (host pool and RPC batch),
    /// so hit/validate/corrupt semantics cannot drift between them.
    ///
    /// `validate` is consulted only for cached invalids: it re-checks
    /// whether the pair really fails to apply, returning the real error
    /// (served as [`Resolution::HitInvalid`]) or `Ok(())` — in which
    /// case the entry is corrupt/stale, the lookup is reclassified as a
    /// miss, and the caller must re-measure honestly
    /// ([`Resolution::Corrupt`]).
    pub fn resolve_with<E>(
        &mut self,
        key: u64,
        validate: impl FnOnce() -> Result<(), E>,
    ) -> Resolution<E> {
        match self.get(key) {
            Some(Some(t)) => Resolution::Hit(t),
            Some(None) => match validate() {
                Err(e) => Resolution::HitInvalid(e),
                Ok(()) => {
                    self.reclassify_hit_as_miss();
                    Resolution::Corrupt
                }
            },
            None => Resolution::Miss,
        }
    }

    fn touch(&mut self, key: u64) {
        self.next_tick += 1;
        let tick = self.next_tick;
        if let Some(e) = self.map.get_mut(&key) {
            e.tick = tick;
        }
        // The queue exists only to find eviction victims; unbounded
        // caches never evict, so recording touches there would just
        // grow memory O(lookups) for the cache's lifetime (LRU-order
        // persistence reads map ticks via keys_lru_order, not the
        // queue).
        if self.capacity.is_some() {
            self.order.push_back((key, tick));
            // Hit-heavy workloads retire stale queue entries only one
            // per eviction; compact before the lazy queue outgrows the
            // map it shadows.
            if self.order.len() > 8 * self.map.len().max(1) {
                self.order = self
                    .keys_lru_order()
                    .into_iter()
                    .map(|k| (k, self.map[&k].tick))
                    .collect();
            }
        }
    }

    /// Look up a pair measurement. `Some(runtime)` is a hit (runtime is
    /// `None` for a cached invalid pair); `None` is a miss. Both are
    /// counted and hits refresh LRU recency.
    pub fn get(&mut self, key: u64) -> Option<Option<f64>> {
        match self.map.get(&key).map(|e| e.runtime) {
            Some(rt) => {
                self.stats.hits += 1;
                self.touch(key);
                Some(rt)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Peek without touching the LRU order or counters.
    pub fn peek(&self, key: u64) -> Option<Option<f64>> {
        self.map.get(&key).map(|e| e.runtime)
    }

    /// Insert (or overwrite) a measurement, evicting LRU entries while
    /// over capacity.
    pub fn insert(&mut self, key: u64, runtime: Option<f64>) {
        let fresh = !self.map.contains_key(&key);
        self.map.insert(key, Entry { runtime, tick: 0 });
        self.touch(key);
        if fresh {
            self.stats.inserts += 1;
        }
        if let Some(cap) = self.capacity {
            while self.map.len() > cap {
                match self.order.pop_front() {
                    Some((k, t)) => {
                        // Skip stale queue entries from later touches.
                        if self.map.get(&k).map(|e| e.tick) == Some(t) {
                            self.map.remove(&k);
                            self.stats.evictions += 1;
                        }
                    }
                    None => break, // defensive; queue covers the map
                }
            }
        }
    }

    /// Entries in least-recently-used-first order, for callers that
    /// redistribute the cache (the service layer shards a flat snapshot
    /// across per-shard locks and merges shards back for persistence).
    pub fn entries_lru(&self) -> Vec<(u64, Option<f64>)> {
        self.keys_lru_order()
            .into_iter()
            .map(|k| (k, self.map[&k].runtime))
            .collect()
    }

    /// Keys in least-recently-used-first order (exact, stale-free).
    fn keys_lru_order(&self) -> Vec<u64> {
        let mut keys: Vec<(u64, u64)> =
            self.map.iter().map(|(&k, e)| (e.tick, k)).collect();
        keys.sort_unstable();
        keys.into_iter().map(|(_, k)| k).collect()
    }

    // ---- persistence -----------------------------------------------------

    /// Serialize to a single canonical JSON object. Entries are listed
    /// least-recently-used first so a load/save round-trip preserves both
    /// contents and eviction order.
    pub fn to_json(&self) -> Json {
        let entries = self.keys_lru_order().into_iter().map(|k| {
            let rt = self.map[&k].runtime;
            Json::arr([
                Json::str(format!("{k:016x}")),
                match rt {
                    Some(t) => Json::num(t),
                    None => Json::Null,
                },
            ])
        });
        Json::obj(vec![
            ("capacity", match self.capacity {
                Some(c) => Json::num(c as f64),
                None => Json::Null,
            }),
            ("entries", Json::arr(entries)),
            ("version", Json::num(1.0)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<MeasureCache> {
        let version = j.req("version")?.as_f64().unwrap_or(0.0) as u64;
        anyhow::ensure!(version == 1, "unsupported cache version {version}");
        let capacity = match j.req("capacity")? {
            Json::Null => None,
            v => Some(
                v.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("capacity must be a number or null"))?,
            ),
        };
        let mut cache = match capacity {
            Some(c) => MeasureCache::with_capacity(c),
            None => MeasureCache::new(),
        };
        for (i, e) in j.req("entries")?.as_arr().unwrap_or(&[]).iter().enumerate() {
            let pair = e
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow::anyhow!("entry {i}: expected [key, runtime]"))?;
            let key = pair[0]
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| anyhow::anyhow!("entry {i}: bad hex key"))?;
            let runtime = match &pair[1] {
                Json::Null => None,
                v => Some(
                    v.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("entry {i}: runtime must be a number"))?,
                ),
            };
            cache.insert(key, runtime);
        }
        cache.reset_stats(); // loading must not look like activity
        Ok(cache)
    }

    /// Persist to disk (single-line canonical JSON + trailing newline).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut text = self.to_json().to_compact();
        text.push('\n');
        std::fs::write(path, text)?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<MeasureCache> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&json::parse(text.trim_end())?)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;

    #[test]
    fn keys_are_content_addressed_not_positional() {
        let a = KernelBuilder::dense(256, 256, 256, &[]);
        let b = KernelBuilder::dense(256, 256, 256, &[]); // identical content
        let c = KernelBuilder::dense(512, 256, 256, &[]);
        let s = Schedule::untuned_default(&a);
        assert_eq!(content_key(&a, &s), content_key(&b, &s));
        assert_ne!(content_key(&a, &s), content_key(&c, &s));

        let mut s2 = s.clone();
        s2.unroll_max += 8;
        assert_ne!(content_key(&a, &s), content_key(&a, &s2));

        let xeon = DeviceProfile::xeon_e5_2620();
        let edge = DeviceProfile::cortex_a72();
        assert_ne!(
            pair_key(&a, &s, 1, &xeon),
            pair_key(&a, &s, 2, &xeon),
            "seed is part of the key"
        );
        assert_ne!(
            pair_key(&a, &s, 1, &xeon),
            pair_key(&a, &s, 1, &edge),
            "a runtime is a property of the device too"
        );
    }

    #[test]
    fn speculative_seed_separates_keep_fractions() {
        assert_eq!(speculative_seed(0xA45, 1.0), 0xA45, "keep=1.0 keeps legacy keys");
        let quarter = speculative_seed(0xA45, 0.25);
        let half = speculative_seed(0xA45, 0.5);
        assert_ne!(quarter, 0xA45);
        assert_ne!(half, 0xA45);
        assert_ne!(quarter, half, "distinct keeps get distinct key spaces");
        assert_eq!(quarter, speculative_seed(0xA45, 0.25), "deterministic");
    }

    #[test]
    fn estimator_seed_separates_trained_models() {
        assert_eq!(estimator_seed(0xA45, 0), 0xA45, "untrained model keeps legacy keys");
        let a = estimator_seed(0xA45, 0xDEAD_BEEF);
        let b = estimator_seed(0xA45, 0xFEED_FACE);
        assert_ne!(a, 0xA45);
        assert_ne!(b, 0xA45);
        assert_ne!(a, b, "distinct models get distinct key spaces");
        assert_eq!(a, estimator_seed(0xA45, 0xDEAD_BEEF), "deterministic");
        // Independent of the speculative-keep ingredient.
        let keep = speculative_seed(0xA45, 0.25);
        assert_ne!(estimator_seed(keep, 0xDEAD_BEEF), keep);
        assert_ne!(estimator_seed(keep, 0xDEAD_BEEF), a);
    }

    #[test]
    fn hit_miss_and_stats() {
        let mut c = MeasureCache::new();
        assert_eq!(c.get(42), None);
        c.insert(42, Some(1e-3));
        assert_eq!(c.get(42), Some(Some(1e-3)));
        c.insert(43, None); // invalid pairs cache too
        assert_eq!(c.get(43), Some(None));
        assert_eq!(c.stats.hits, 2);
        assert_eq!(c.stats.misses, 1);
        assert_eq!(c.stats.inserts, 2);
        assert!((c.stats.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = MeasureCache::with_capacity(2);
        c.insert(1, Some(0.1));
        c.insert(2, Some(0.2));
        assert_eq!(c.get(1), Some(Some(0.1))); // refresh 1; LRU is now 2
        c.insert(3, Some(0.3));
        assert_eq!(c.len(), 2);
        assert_eq!(c.peek(2), None, "2 was least recently used");
        assert_eq!(c.peek(1), Some(Some(0.1)));
        assert_eq!(c.peek(3), Some(Some(0.3)));
        assert_eq!(c.stats.evictions, 1);
    }

    #[test]
    fn overwrite_does_not_grow_or_double_count() {
        let mut c = MeasureCache::with_capacity(4);
        c.insert(7, Some(0.1));
        c.insert(7, Some(0.2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats.inserts, 1);
        assert_eq!(c.peek(7), Some(Some(0.2)));
    }

    #[test]
    fn roundtrips_through_disk_preserving_lru_order() {
        let mut c = MeasureCache::with_capacity(3);
        c.insert(10, Some(0.001));
        c.insert(11, None);
        c.insert(12, Some(0.25));
        assert_eq!(c.get(10), Some(Some(0.001))); // 11 becomes LRU

        let path = std::env::temp_dir().join("tt_measure_cache_test.json");
        c.save(&path).unwrap();
        let mut back = MeasureCache::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        assert_eq!(back.len(), 3);
        assert_eq!(back.capacity(), Some(3));
        assert_eq!(back.peek(10), Some(Some(0.001)));
        assert_eq!(back.peek(11), Some(None));
        assert_eq!(back.stats, CacheStats::default(), "load resets stats");
        // Eviction order survived: inserting a 4th entry evicts 11.
        back.insert(13, Some(0.5));
        assert_eq!(back.peek(11), None);
        assert_eq!(back.peek(10), Some(Some(0.001)));
    }

    #[test]
    fn rejects_malformed_files() {
        assert!(MeasureCache::from_json(&json::parse("{}").unwrap()).is_err());
        assert!(MeasureCache::from_json(
            &json::parse(r#"{"capacity":null,"entries":[["zzz",1]],"version":1}"#).unwrap()
        )
        .is_err());
        assert!(MeasureCache::from_json(
            &json::parse(r#"{"capacity":null,"entries":[],"version":9}"#).unwrap()
        )
        .is_err());
    }
}
