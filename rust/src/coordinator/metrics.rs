//! Serving + sweep metrics: latency histogram and throughput accounting
//! for the request loop (`repro serve`), and the sweep-side rollup of
//! ledger cost vs measurement-cache amortization.

use super::cache::CacheStats;
use super::ledger::Ledger;

/// One sweep's cost picture: what the device actually ran vs what the
/// content-addressed cache absorbed. Built from the engine's [`Ledger`]
/// and the cache's [`CacheStats`]; rendered as the one-line summary the
/// CLI and benches print after a sweep.
#[derive(Clone, Debug, Default)]
pub struct SweepMetrics {
    /// Sequential device seconds charged (misses only).
    pub device_seconds: f64,
    /// Candidates actually measured on the device.
    pub measurements: usize,
    /// Candidates the compiler rejected (still cost codegen time).
    pub compile_failures: usize,
    pub cache: CacheStats,
}

impl SweepMetrics {
    pub fn from_parts(ledger: &Ledger, cache: &CacheStats) -> SweepMetrics {
        SweepMetrics {
            device_seconds: ledger.seconds,
            measurements: ledger.measurements,
            compile_failures: ledger.compile_failures,
            cache: cache.clone(),
        }
    }

    /// `pairs=… measured=… device=…s hit-rate=…%` one-liner.
    pub fn summary(&self) -> String {
        format!(
            "pairs={} measured={} failed={} device={:.2}s hit-rate={:.1}% (hits={} dedup={} miss={} evict={})",
            self.cache.lookups(),
            self.measurements,
            self.compile_failures,
            self.device_seconds,
            self.cache.hit_rate() * 100.0,
            self.cache.hits,
            self.cache.dedup_hits,
            self.cache.misses,
            self.cache.evictions,
        )
    }
}

/// Log-bucketed latency histogram (microseconds to seconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Bucket upper bounds in seconds (ascending); the last is +inf.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    samples: Vec<f64>,
    pub total: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // 1us .. 10s, 1-2-5 sequence.
        let mut bounds = Vec::new();
        for exp in -6..1 {
            for m in [1.0, 2.0, 5.0] {
                bounds.push(m * 10f64.powi(exp));
            }
        }
        let n = bounds.len();
        LatencyHistogram { bounds, counts: vec![0; n + 1], samples: Vec::new(), total: 0 }
    }

    pub fn record(&mut self, latency_s: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| latency_s <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.samples.push(latency_s);
        self.total += 1;
    }

    /// Exact percentile from retained samples (serving runs are small
    /// enough to keep all samples; a production system would switch to
    /// the buckets beyond some size).
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx]
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Non-empty (bound, count) pairs for display.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        self.bounds
            .iter()
            .copied()
            .chain(std::iter::once(f64::INFINITY))
            .zip(self.counts.iter().copied())
            .filter(|&(_, c)| c > 0)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert!(h.percentile(50.0) <= h.percentile(95.0));
        assert!(h.percentile(95.0) <= h.percentile(99.0));
        assert!((h.percentile(50.0) - 0.050).abs() < 2e-3);
        assert_eq!(h.total, 100);
    }

    #[test]
    fn buckets_cover_all_samples() {
        let mut h = LatencyHistogram::new();
        h.record(1e-7); // below first bound
        h.record(0.5);
        h.record(100.0); // beyond last bound -> overflow bucket
        let total: u64 = h.buckets().iter().map(|(_, c)| c).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn sweep_metrics_rollup_and_summary() {
        let prof = crate::device::DeviceProfile::xeon_e5_2620();
        let mut ledger = Ledger::new();
        ledger.charge_measure(&prof, 0.01);
        let stats = CacheStats { misses: 1, hits: 9, ..Default::default() };
        let m = SweepMetrics::from_parts(&ledger, &stats);
        assert_eq!(m.measurements, 1);
        assert!(m.device_seconds > 0.0);
        let s = m.summary();
        assert!(s.contains("hit-rate=90.0%"), "{s}");
        assert!(s.contains("measured=1"), "{s}");
    }
}
