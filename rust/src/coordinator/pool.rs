//! Measurement worker pool.
//!
//! The transfer-tuning engine sweeps hundreds of kernel/schedule pairs
//! (764 for EfficientNetB0, §5.2); the pool fans the sweep across OS
//! threads. Determinism is preserved by forking a per-job RNG from the
//! job index, so results are identical at any thread count — the ledger
//! (sequential *device* seconds) is charged by the caller from the
//! returned runtimes, not from host wall-clock.

use crate::device::{measure, DeviceProfile};
use crate::ir::Kernel;
use crate::sched::{apply, ApplyError, Schedule};
use crate::util::rng::Rng;

/// Outcome of evaluating one kernel/schedule pair standalone.
#[derive(Clone, Debug)]
pub enum PairOutcome {
    /// Measured standalone runtime (noisy), seconds.
    Measured(f64),
    /// The schedule could not be applied (Fig 4's `-1` entries).
    Invalid(ApplyError),
}

impl PairOutcome {
    pub fn runtime(&self) -> Option<f64> {
        match self {
            PairOutcome::Measured(t) => Some(*t),
            PairOutcome::Invalid(_) => None,
        }
    }
}

/// Evaluate every (kernel, schedule) job standalone, in parallel.
/// `seed` fixes all measurement noise.
pub fn measure_pairs(
    jobs: &[(&Kernel, &Schedule)],
    profile: &DeviceProfile,
    seed: u64,
) -> Vec<PairOutcome> {
    let n_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let chunk = jobs.len().div_ceil(n_threads.max(1)).max(1);
    let mut results: Vec<Option<PairOutcome>> = vec![None; jobs.len()];

    std::thread::scope(|scope| {
        for (ci, (job_chunk, res_chunk)) in
            jobs.chunks(chunk).zip(results.chunks_mut(chunk)).enumerate()
        {
            scope.spawn(move || {
                for (ji, ((kernel, sched), slot)) in
                    job_chunk.iter().zip(res_chunk.iter_mut()).enumerate()
                {
                    let job_index = (ci * chunk + ji) as u64;
                    let mut rng = Rng::new(seed ^ job_index.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    *slot = Some(match apply(sched, kernel) {
                        Err(e) => PairOutcome::Invalid(e),
                        Ok(nest) => PairOutcome::Measured(measure(kernel, &nest, profile, &mut rng)),
                    });
                }
            });
        }
    });

    results.into_iter().map(|r| r.expect("worker filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;

    #[test]
    fn parallel_results_are_deterministic() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let s = Schedule::untuned_default(&k);
        let jobs: Vec<(&Kernel, &Schedule)> = (0..50).map(|_| (&k, &s)).collect();
        let a = measure_pairs(&jobs, &prof, 11);
        let b = measure_pairs(&jobs, &prof, 11);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.runtime(), y.runtime());
        }
    }

    #[test]
    fn invalid_pairs_reported() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let small = KernelBuilder::dense(8, 8, 8, &[]);
        let mut s = Schedule::untuned_default(&k);
        s.spatial[1] = crate::sched::AxisTiling::of(&[64]); // 64 > 8
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&small, &s)];
        let out = measure_pairs(&jobs, &prof, 1);
        assert!(matches!(out[0], PairOutcome::Invalid(_)));
    }

    #[test]
    fn empty_jobs_ok() {
        let prof = DeviceProfile::xeon_e5_2620();
        assert!(measure_pairs(&[], &prof, 0).is_empty());
    }
}
