//! Measurement worker pool.
//!
//! The transfer-tuning engine sweeps hundreds of kernel/schedule pairs
//! (764 for EfficientNetB0, §5.2); the pool fans the sweep across OS
//! threads. Determinism is preserved by deriving each pair's measurement
//! noise from its *content key* (see [`super::cache`]) and the sweep
//! seed — never from job order or thread count — so results are
//! identical at any parallelism, identical pairs measure identically
//! within a sweep, and a cache hit returns exactly what a fresh
//! measurement would have produced. The ledger (sequential *device*
//! seconds) is charged per unique measured pair, not per host thread.

use super::cache::{content_key, sweep_key, MeasureCache, Resolution};
use super::ledger::Ledger;
use crate::device::{measure, DeviceProfile};
use crate::ir::Kernel;
use crate::sched::{apply, ApplyError, Schedule};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Outcome of evaluating one kernel/schedule pair standalone.
#[derive(Clone, Debug)]
pub enum PairOutcome {
    /// Measured standalone runtime (noisy), seconds.
    Measured(f64),
    /// The schedule could not be applied (Fig 4's `-1` entries).
    Invalid(ApplyError),
    /// The measurement was *lost* — crashed runner, dropped RPC, or an
    /// injected `measure.pair` fault. Carries the penalty
    /// device-seconds the ledger was charged for the wasted attempt.
    /// Unlike [`PairOutcome::Invalid`] (a durable property of the pair,
    /// cached), a lost measurement is transient and is **never**
    /// cached: the next sweep re-measures the pair, so one flaky runner
    /// can't poison warm state. Ansor's measurer treats build/run
    /// failures the same way — routine outcomes, not fatal errors.
    Failed(f64),
}

impl PairOutcome {
    pub fn runtime(&self) -> Option<f64> {
        match self {
            PairOutcome::Measured(t) => Some(*t),
            PairOutcome::Invalid(_) | PairOutcome::Failed(_) => None,
        }
    }
}

/// RNG seed for one pair's measurement noise: a function of the sweep
/// seed and the pair's content only. Shared with the batched RPC
/// executor so host- and edge-measured cache entries interoperate.
pub(crate) fn noise_seed(sweep_seed: u64, content: u64) -> u64 {
    sweep_seed ^ content.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Measure one pair with a precomputed noise seed (so callers that
/// already hashed the pair's content don't serialize it twice).
fn measure_one_seeded(
    kernel: &Kernel,
    sched: &Schedule,
    profile: &DeviceProfile,
    noise: u64,
) -> PairOutcome {
    match apply(sched, kernel) {
        Err(e) => PairOutcome::Invalid(e),
        Ok(nest) => {
            let mut rng = Rng::new(noise);
            PairOutcome::Measured(measure(kernel, &nest, profile, &mut rng))
        }
    }
}

/// Parallel fan-out with one precomputed noise seed per job. Shared
/// with the service layer's sharded executor (`crate::service::shard`).
///
/// The worker count honors the `--jobs`/`TT_JOBS` override (see
/// [`super::jobs::effective_jobs`]) instead of unconditionally grabbing
/// `available_parallelism`, so constrained CI runners and benches get
/// reproducible thread counts — and because each job's noise seed is
/// content-derived, the outcomes are bit-identical at every setting.
pub(crate) fn measure_with_noise(
    jobs: &[(&Kernel, &Schedule)],
    profile: &DeviceProfile,
    noise: &[u64],
) -> Vec<PairOutcome> {
    debug_assert_eq!(jobs.len(), noise.len());
    super::jobs::par_map_indexed(jobs, 0, |i, &(kernel, sched)| {
        measure_one_seeded(kernel, sched, profile, noise[i])
    })
}

/// Evaluate every (kernel, schedule) job standalone, in parallel.
/// `seed` fixes all measurement noise; identical jobs yield identical
/// outcomes regardless of their position in the batch.
pub fn measure_pairs(
    jobs: &[(&Kernel, &Schedule)],
    profile: &DeviceProfile,
    seed: u64,
) -> Vec<PairOutcome> {
    let noise: Vec<u64> =
        jobs.iter().map(|&(k, s)| noise_seed(seed, content_key(k, s))).collect();
    measure_with_noise(jobs, profile, &noise)
}

/// A cached batch evaluation: outcomes in job order plus each job's
/// cache key (the engine uses the keys for cold-equivalent search-time
/// accounting without re-hashing every pair).
pub struct CachedBatch {
    pub outcomes: Vec<PairOutcome>,
    pub keys: Vec<u64>,
}

/// Evaluate a batch through the measurement cache.
///
/// The pipeline: duplicate pairs within the batch are collapsed first
/// (`dedup_hits`), resident pairs are served from `cache` (`hits`), and
/// only the remaining unique misses go to the parallel pool. The ledger
/// is charged **per unique miss** — cached pairs cost zero device
/// seconds, mirroring how a real deployment amortizes tuning — while the
/// returned outcomes are positionally identical to [`measure_pairs`] on
/// the same batch (the cache-transparency invariant of
/// [`super::cache`]).
pub fn measure_pairs_cached(
    jobs: &[(&Kernel, &Schedule)],
    profile: &DeviceProfile,
    seed: u64,
    cache: &mut MeasureCache,
    ledger: &mut Ledger,
) -> Vec<PairOutcome> {
    let contents: Vec<u64> = jobs.iter().map(|&(k, s)| content_key(k, s)).collect();
    measure_pairs_cached_precomputed(jobs, &contents, profile, seed, cache, ledger).outcomes
}

/// [`measure_pairs_cached`] with caller-supplied content keys:
/// `contents[i]` must equal `content_key(jobs[i].0, jobs[i].1)`. Sweep
/// planners that hash each store record once (see
/// `transfer::SweepPlan`) use this to avoid re-serializing the same
/// schedule for every kernel it is tried on.
pub fn measure_pairs_cached_precomputed(
    jobs: &[(&Kernel, &Schedule)],
    contents: &[u64],
    profile: &DeviceProfile,
    seed: u64,
    cache: &mut MeasureCache,
    ledger: &mut Ledger,
) -> CachedBatch {
    measure_pairs_cached_generic(jobs, contents, profile, seed, cache, ledger)
}

/// The three cache operations the batched measure pipeline needs. The
/// flat `&mut MeasureCache` executor and the service layer's sharded
/// executor (`crate::service::shard`) differ only in how these are
/// acquired (direct mutable access vs a per-key shard lock), so both
/// implement this trait and share one pipeline body —
/// [`measure_pairs_cached_generic`].
pub trait CacheOps {
    /// Count a batch-local duplicate of `key` (the stat lives with the
    /// entry's shard, hence the key parameter).
    fn record_dedup_hit(&mut self, key: u64);
    /// Look up `key`, re-validating hit-invalid entries via `validate`
    /// (see [`MeasureCache::resolve_with`]).
    fn resolve(
        &mut self,
        key: u64,
        validate: impl FnOnce() -> Result<(), ApplyError>,
    ) -> Resolution<ApplyError>;
    /// Record a fresh measurement (or compile failure) under `key`.
    fn insert_outcome(&mut self, key: u64, runtime: Option<f64>);
}

impl CacheOps for MeasureCache {
    fn record_dedup_hit(&mut self, _key: u64) {
        self.stats.dedup_hits += 1;
    }

    fn resolve(
        &mut self,
        key: u64,
        validate: impl FnOnce() -> Result<(), ApplyError>,
    ) -> Resolution<ApplyError> {
        self.resolve_with(key, validate)
    }

    fn insert_outcome(&mut self, key: u64, runtime: Option<f64>) {
        self.insert(key, runtime);
    }
}

/// The one dedup/resolve/measure/charge pipeline behind both cached
/// executors, parameterized over [`CacheOps`]. Measurement happens
/// outside every cache operation, so a locking impl only holds a lock
/// for the short resolve/insert critical sections.
pub fn measure_pairs_cached_generic<C: CacheOps>(
    jobs: &[(&Kernel, &Schedule)],
    contents: &[u64],
    profile: &DeviceProfile,
    seed: u64,
    cache: &mut C,
    ledger: &mut Ledger,
) -> CachedBatch {
    assert_eq!(jobs.len(), contents.len());

    /// Where job `i`'s outcome comes from.
    #[derive(Clone)]
    enum Slot {
        /// Cache hit with a measured runtime.
        Hit(f64),
        /// Cache hit on an invalid pair, re-validated against `apply`
        /// (so the error payload is real, and corrupt entries never
        /// reach here — they are reclassified as misses).
        HitInvalid(ApplyError),
        /// Index into the unique-miss list.
        Miss(usize),
        /// Measurement lost to an injected `measure.pair` fault; the
        /// penalty was charged, nothing was cached.
        Failed(f64),
    }

    let keys: Vec<u64> = contents.iter().map(|&c| sweep_key(c, seed, profile)).collect();

    // Batch-local dedup of every resolution (hits included): work is
    // proportional to unique pairs even on fully warm sweeps.
    let mut slot_of_key: HashMap<u64, usize> = HashMap::new();
    let mut unique_jobs: Vec<(&Kernel, &Schedule)> = Vec::new();
    let mut unique_keys: Vec<u64> = Vec::new();
    let mut unique_noise: Vec<u64> = Vec::new();
    let mut slots: Vec<Slot> = Vec::with_capacity(jobs.len());
    for (ji, &key) in keys.iter().enumerate() {
        if let Some(&si) = slot_of_key.get(&key) {
            cache.record_dedup_hit(key);
            let dup = slots[si].clone();
            slots.push(dup);
            continue;
        }
        let (kernel, sched) = jobs[ji];
        let slot = match cache.resolve(key, || apply(sched, kernel).map(|_| ())) {
            Resolution::Hit(t) => Slot::Hit(t),
            Resolution::HitInvalid(e) => Slot::HitInvalid(e),
            Resolution::Corrupt | Resolution::Miss => {
                // Fault injection happens only where a real measurement
                // would: warm pairs never re-measure, so they can never
                // "fail" — a fault changes when work happens, not what
                // completed work contains. The draw is keyed by the
                // pair's content (like its noise), so the same pair is
                // lost at any parallelism or batch order, the penalty
                // is charged once per unique pair, and the key is NOT
                // inserted — the next sweep re-measures it.
                if let Some(penalty) = crate::faults::measure_failure(contents[ji]) {
                    ledger.charge_measure_failure(profile, penalty);
                    Slot::Failed(penalty)
                } else {
                    let u = unique_jobs.len();
                    unique_jobs.push(jobs[ji]);
                    unique_keys.push(key);
                    unique_noise.push(noise_seed(seed, contents[ji]));
                    Slot::Miss(u)
                }
            }
        };
        slot_of_key.insert(key, slots.len());
        slots.push(slot);
    }

    // Fan the unique misses across the pool; charge sequential device
    // seconds per measured candidate, exactly as Ansor's measurer would.
    let measured = measure_with_noise(&unique_jobs, profile, &unique_noise);
    for (key, outcome) in unique_keys.iter().zip(&measured) {
        match outcome.runtime() {
            Some(t) => ledger.charge_measure(profile, t),
            None => ledger.charge_compile_fail(profile),
        }
        cache.insert_outcome(*key, outcome.runtime());
    }

    let outcomes: Vec<PairOutcome> = slots
        .into_iter()
        .map(|slot| match slot {
            Slot::Miss(u) => measured[u].clone(),
            Slot::Hit(t) => PairOutcome::Measured(t),
            Slot::HitInvalid(e) => PairOutcome::Invalid(e),
            Slot::Failed(p) => PairOutcome::Failed(p),
        })
        .collect();
    CachedBatch { outcomes, keys }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;

    #[test]
    fn parallel_results_are_deterministic() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let s = Schedule::untuned_default(&k);
        let jobs: Vec<(&Kernel, &Schedule)> = (0..50).map(|_| (&k, &s)).collect();
        let a = measure_pairs(&jobs, &prof, 11);
        let b = measure_pairs(&jobs, &prof, 11);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.runtime(), y.runtime());
        }
    }

    #[test]
    fn noise_is_content_derived_not_positional() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let s = Schedule::untuned_default(&k);
        let mut s2 = s.clone();
        s2.unroll_max += 16;
        // Same pair at different positions: identical measurement.
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&k, &s), (&k, &s2), (&k, &s)];
        let out = measure_pairs(&jobs, &prof, 11);
        assert_eq!(out[0].runtime(), out[2].runtime());
        // Distinct content draws independent noise (and different seeds
        // re-draw).
        let other = measure_pairs(&jobs, &prof, 12);
        assert_ne!(out[0].runtime(), other[0].runtime());
    }

    #[test]
    fn invalid_pairs_reported() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let small = KernelBuilder::dense(8, 8, 8, &[]);
        let mut s = Schedule::untuned_default(&k);
        s.spatial[1] = crate::sched::AxisTiling::of(&[64]); // 64 > 8
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&small, &s)];
        let out = measure_pairs(&jobs, &prof, 1);
        assert!(matches!(out[0], PairOutcome::Invalid(_)));
    }

    #[test]
    fn empty_jobs_ok() {
        let prof = DeviceProfile::xeon_e5_2620();
        assert!(measure_pairs(&[], &prof, 0).is_empty());
        let mut cache = MeasureCache::new();
        let mut ledger = Ledger::new();
        assert!(measure_pairs_cached(&[], &prof, 0, &mut cache, &mut ledger).is_empty());
        assert_eq!(ledger.seconds, 0.0);
    }

    #[test]
    fn cached_batch_matches_uncached_and_charges_misses_only() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k1 = KernelBuilder::dense(256, 256, 256, &[]);
        let k2 = KernelBuilder::dense(512, 512, 512, &[]);
        let s1 = Schedule::untuned_default(&k1);
        let s2 = Schedule::untuned_default(&k2);
        // k1/s1 appears twice: one unique measurement, one dedup hit.
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&k1, &s1), (&k2, &s2), (&k1, &s1)];

        let plain = measure_pairs(&jobs, &prof, 7);
        let mut cache = MeasureCache::new();
        let mut ledger = Ledger::new();
        let cached = measure_pairs_cached(&jobs, &prof, 7, &mut cache, &mut ledger);
        for (a, b) in plain.iter().zip(&cached) {
            assert_eq!(a.runtime(), b.runtime(), "cache must be transparent");
        }
        assert_eq!(ledger.measurements, 2, "duplicate pair measured once");
        assert_eq!(cache.stats.dedup_hits, 1);
        assert_eq!(cache.stats.misses, 2);

        // Second sweep: fully warm, zero device seconds.
        let mut ledger2 = Ledger::new();
        let warm = measure_pairs_cached(&jobs, &prof, 7, &mut cache, &mut ledger2);
        assert_eq!(ledger2.seconds, 0.0);
        assert_eq!(ledger2.measurements, 0);
        for (a, b) in plain.iter().zip(&warm) {
            assert_eq!(a.runtime(), b.runtime());
        }

        // Different seed: different keys, so it misses and re-charges.
        let mut ledger3 = Ledger::new();
        let _ = measure_pairs_cached(&jobs, &prof, 8, &mut cache, &mut ledger3);
        assert!(ledger3.seconds > 0.0);
    }

    #[test]
    fn caches_are_device_scoped() {
        let xeon = DeviceProfile::xeon_e5_2620();
        let edge = DeviceProfile::cortex_a72();
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let s = Schedule::untuned_default(&k);
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&k, &s)];

        let mut cache = MeasureCache::new();
        let mut ledger = Ledger::new();
        let server = measure_pairs_cached(&jobs, &xeon, 3, &mut cache, &mut ledger);

        // The same pair on a different device must re-measure, not be
        // served the server runtime.
        let mut edge_ledger = Ledger::new();
        let remote = measure_pairs_cached(&jobs, &edge, 3, &mut cache, &mut edge_ledger);
        assert!(edge_ledger.seconds > 0.0, "edge sweep must not hit the Xeon entry");
        assert_ne!(server[0].runtime(), remote[0].runtime());
    }

    #[test]
    fn cached_invalids_cost_zero_and_keep_their_error() {
        let prof = DeviceProfile::xeon_e5_2620();
        let small = KernelBuilder::dense(8, 8, 8, &[]);
        let big = KernelBuilder::dense(256, 256, 256, &[]);
        let mut s = Schedule::untuned_default(&big);
        s.spatial[1] = crate::sched::AxisTiling::of(&[64]);
        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&small, &s)];

        let mut cache = MeasureCache::new();
        let mut ledger = Ledger::new();
        let cold = measure_pairs_cached(&jobs, &prof, 3, &mut cache, &mut ledger);
        assert!(matches!(cold[0], PairOutcome::Invalid(_)));
        assert_eq!(ledger.compile_failures, 1);

        let mut ledger2 = Ledger::new();
        let warm = measure_pairs_cached(&jobs, &prof, 3, &mut cache, &mut ledger2);
        assert!(matches!(warm[0], PairOutcome::Invalid(_)), "error payload reconstructed");
        assert_eq!(ledger2.seconds, 0.0);
        assert_eq!(ledger2.compile_failures, 0);
    }

    #[test]
    fn corrupt_cache_entry_recovers_with_one_measurement_for_duplicates() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let s = Schedule::untuned_default(&k);
        // Poison the cache: claim a perfectly valid pair is invalid.
        let key = crate::coordinator::cache::pair_key(&k, &s, 3, &prof);
        let mut cache = MeasureCache::new();
        cache.insert(key, None);

        let jobs: Vec<(&Kernel, &Schedule)> = vec![(&k, &s), (&k, &s), (&k, &s)];
        let mut ledger = Ledger::new();
        let out = measure_pairs_cached(&jobs, &prof, 3, &mut cache, &mut ledger);
        // Recovered with exactly ONE honest measurement shared by all
        // three duplicates, and the poisoned entry is fixed in place.
        assert_eq!(ledger.measurements, 1);
        assert!(out.iter().all(|o| o.runtime() == out[0].runtime()));
        assert!(out[0].runtime().is_some());
        assert_eq!(cache.peek(key), Some(out[0].runtime()));
        // Stats reconcile with the ledger: the recovered lookup counts
        // as a miss, not a free hit, and the duplicates dedup against
        // the recovery measurement.
        assert_eq!(cache.stats.misses, 1);
        assert_eq!(cache.stats.hits, 0);
        assert_eq!(cache.stats.dedup_hits, 2);
    }
}
