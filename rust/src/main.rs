//! `repro` — the launcher for the transfer-tuning system.
//!
//! Every table and figure of the paper is a subcommand (see DESIGN.md §4
//! for the experiment index). Results print as aligned tables and are
//! also written as CSV under `results/`.
//!
//! ```text
//! repro models                         # model zoo inventory
//! repro table t1|t2|t3|t4              # paper tables
//! repro figure fig1|fig4|fig5|fig6|fig7|fig8
//! repro gemm-transfer                  # §4.1 GEMM example (simulated)
//! repro tune --model ResNet18          # Ansor-tune one model
//! repro transfer --model ResNet18 --source ResNet50
//! repro show-schedule --model ResNet18 --kernel 6
//! repro serve --listen 127.0.0.1:7461  # RPC server, streaming zoo build
//! repro serve --requests FILE          # ScheduleService session replay
//! repro call ADDR REQUEST              # thin client: one framed request
//! repro admin ADDR stats|shutdown|republish MODEL|republish --all
//! repro cache gc|merge DIR...          # artifact-store lifecycle
//! repro all                            # everything (one zoo per device)
//! ```
//!
//! Common flags: `--trials N` (Ansor budget; paper uses 20000),
//! `--seed S`, `--device server|edge`, `--out DIR` (CSV directory),
//! `--jobs N` (host threads for every parallel fan-out — wall-clock
//! only, results are bit-identical at any value; defaults to `TT_JOBS`
//! or all cores), and `--cache-dir DIR` — the persistent artifact store
//! (`transfer_tuning::artifact`). With `--cache-dir`, tunings, the
//! merged schedule store, and the measurement cache survive the
//! process: the first `repro table t2 --cache-dir .tt-cache` tunes the
//! zoo and persists it; every later table/figure/tune/transfer/all at
//! the same (device, trials, seed) re-tunes **nothing** and charges
//! **zero** device-seconds, with bit-identical output. `repro serve
//! --requests FILE` drives the multi-tenant `ScheduleService` (sharded
//! measurement cache, `--shards N`) from a JSONL request file.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use transfer_tuning::artifact::{self, ArtifactStore};
use transfer_tuning::autosched::{tune_model, CostModelKind, TuneOptions};
use transfer_tuning::device::{untuned_model_time, DeviceProfile};
use transfer_tuning::models;
use transfer_tuning::report::{figures, tables, ExperimentConfig, Zoo};
use transfer_tuning::sched::trace;
use transfer_tuning::transfer::{transfer_tune_one_to_one, ScheduleStore};
use transfer_tuning::util::table::{fmt_duration, fmt_speedup, Table};

#[derive(Clone, Debug)]
struct Cli {
    command: String,
    target: Option<String>, // first positional (table/figure name, ADDR)
    rest: Vec<String>,      // later positionals (client request, admin op, merge dirs)
    model: Option<String>,
    source: Option<String>,
    kernel: Option<usize>,
    trials: usize,
    seed: u64,
    device: DeviceProfile,
    out: PathBuf,
    store_path: Option<PathBuf>,
    /// Persistent artifact store (None = everything dies with the
    /// process, the pre-artifact behavior).
    cache_dir: Option<PathBuf>,
    /// JSONL session-request file for `serve`.
    requests: Option<PathBuf>,
    /// TCP bind address for `serve --listen` (the RPC front end).
    listen: Option<String>,
    /// Measurement-cache shards for the serving path.
    shards: usize,
    /// Artifact-store byte budget: persist phases GC the `--cache-dir`
    /// down to this size (live-pinned artifacts are never evicted).
    cache_budget: Option<u64>,
    /// Host worker threads for every parallel fan-out (zoo model
    /// tuning, tuner candidate batches, measurement pool, session
    /// replay). 0 = TT_JOBS env, else auto. Wall-clock only: results
    /// are bit-identical at any value.
    jobs: usize,
    /// Draft-then-verify keep fraction in (0, 1]: each candidate batch
    /// is ranked by the cost model and only the top fraction reaches
    /// full simulation. 1.0 (default) = exact path, byte-identical to
    /// builds without the flag. Unlike `--jobs` this changes results,
    /// so it is part of every artifact and measurement-cache key.
    speculative_keep: f64,
    /// Which cost estimator scores candidates: `static` (default —
    /// per-run models trained from scratch, no key ingredient) or
    /// `learned` (a GBDT prior fitted from the measure cache, persisted
    /// as a versioned artifact whose content hash keys everything it
    /// influences).
    cost_model: CostModelKind,
    /// Reactor connection cap for `serve --listen`. 0 = server default
    /// (see `rpc::DEFAULT_MAX_CONNS`); at the cap the listener pauses
    /// and further connects wait in the kernel backlog.
    max_conns: usize,
    /// Idle-connection deadline in seconds for `serve --listen`. 0 =
    /// server default (see `rpc::READ_STALL_TIMEOUT`).
    idle_timeout_s: u64,
    /// Mid-frame progress deadline in seconds for `serve --listen`
    /// (slowloris bound). 0 = server default.
    read_stall_s: u64,
    /// Outbound-progress deadline in seconds for `serve --listen`
    /// (client stopped reading its replies). 0 = server default.
    write_stall_s: u64,
    /// Worker-queue bound for `serve --listen`: a request landing on a
    /// full queue is answered with the typed v5 `overloaded` error
    /// (load shedding). 0 = unbounded (the default).
    max_queue: usize,
    /// `repro call`/`repro admin`: retry transient failures (connect
    /// refused, timeout, `overloaded`) up to N times with deterministic
    /// jittered exponential backoff. 0 (default) = one attempt.
    retries: usize,
    /// Deterministic fault-injection plan (`--fault-plan` / TT_FAULTS):
    /// a test/ops tool, never an artifact-key ingredient — see
    /// `transfer_tuning::faults` for the grammar.
    fault_plan: Option<String>,
    /// `repro admin ADDR republish --all`: republish every zoo model.
    all: bool,
    /// `repro fleet`: backend serve addresses, one `--instance` flag
    /// each. The router hashes them as a *set* — order never matters.
    instances: Vec<String>,
}

fn parse_args() -> Result<Cli> {
    let mut args = std::env::args().skip(1).peekable();
    let command = args.next().unwrap_or_else(|| "help".into());
    let mut cli = Cli {
        command,
        target: None,
        rest: Vec::new(),
        model: None,
        source: None,
        kernel: None,
        trials: 2000,
        seed: 0xA45,
        device: DeviceProfile::xeon_e5_2620(),
        out: PathBuf::from("results"),
        store_path: None,
        cache_dir: None,
        requests: None,
        listen: None,
        shards: 8,
        cache_budget: None,
        jobs: 0,
        speculative_keep: 1.0,
        cost_model: CostModelKind::Static,
        max_conns: 0,
        idle_timeout_s: 0,
        read_stall_s: 0,
        write_stall_s: 0,
        max_queue: 0,
        retries: 0,
        fault_plan: None,
        all: false,
        instances: Vec::new(),
    };
    while let Some(arg) = args.next() {
        let mut value = |name: &str| -> Result<String> {
            args.next().with_context(|| format!("flag {name} needs a value"))
        };
        match arg.as_str() {
            "--model" => cli.model = Some(value("--model")?),
            "--source" => cli.source = Some(value("--source")?),
            "--kernel" => cli.kernel = Some(value("--kernel")?.parse()?),
            "--trials" => cli.trials = value("--trials")?.parse()?,
            "--seed" => cli.seed = value("--seed")?.parse()?,
            "--device" => {
                let name = value("--device")?;
                cli.device = DeviceProfile::by_name(&name)
                    .with_context(|| format!("unknown device `{name}` (server|edge)"))?;
            }
            "--out" => cli.out = PathBuf::from(value("--out")?),
            "--store" => cli.store_path = Some(PathBuf::from(value("--store")?)),
            "--cache-dir" => cli.cache_dir = Some(PathBuf::from(value("--cache-dir")?)),
            "--requests" => cli.requests = Some(PathBuf::from(value("--requests")?)),
            "--listen" => cli.listen = Some(value("--listen")?),
            "--shards" => cli.shards = value("--shards")?.parse()?,
            "--cache-budget" => cli.cache_budget = Some(value("--cache-budget")?.parse()?),
            "--jobs" => cli.jobs = value("--jobs")?.parse()?,
            "--speculative-keep" => {
                let keep: f64 = value("--speculative-keep")?.parse()?;
                if !(keep > 0.0 && keep <= 1.0) {
                    bail!("--speculative-keep must be in (0, 1], got {keep}");
                }
                cli.speculative_keep = keep;
            }
            "--cost-model" => {
                let name = value("--cost-model")?;
                cli.cost_model = CostModelKind::parse(&name)
                    .with_context(|| format!("unknown cost model `{name}` (static|learned)"))?;
            }
            "--max-conns" => {
                let n: usize = value("--max-conns")?.parse()?;
                if n == 0 {
                    bail!("--max-conns must be >= 1");
                }
                cli.max_conns = n;
            }
            "--idle-timeout" => {
                let secs: u64 = value("--idle-timeout")?.parse()?;
                if secs == 0 {
                    bail!("--idle-timeout must be >= 1 (seconds)");
                }
                cli.idle_timeout_s = secs;
            }
            "--read-stall" => {
                let secs: u64 = value("--read-stall")?.parse()?;
                if secs == 0 {
                    bail!("--read-stall must be >= 1 (seconds)");
                }
                cli.read_stall_s = secs;
            }
            "--write-stall" => {
                let secs: u64 = value("--write-stall")?.parse()?;
                if secs == 0 {
                    bail!("--write-stall must be >= 1 (seconds)");
                }
                cli.write_stall_s = secs;
            }
            "--max-queue" => cli.max_queue = value("--max-queue")?.parse()?,
            "--retries" => cli.retries = value("--retries")?.parse()?,
            "--fault-plan" => cli.fault_plan = Some(value("--fault-plan")?),
            "--all" => cli.all = true,
            "--instance" => cli.instances.push(value("--instance")?),
            other if !other.starts_with("--") => {
                if cli.target.is_none() {
                    cli.target = Some(other.to_string());
                } else {
                    cli.rest.push(other.to_string());
                }
            }
            other => bail!("unknown flag `{other}` (see `repro help`)"),
        }
    }
    Ok(cli)
}

/// SIGINT/SIGTERM latch for `serve --listen`: the handler only flips an
/// atomic (async-signal-safe); the serve loop polls it and runs the
/// same drain + persist teardown a `shutdown` RPC triggers, so the two
/// exit paths leave byte-identical artifacts. Installed via the C
/// library's `signal` directly — the crate is dependency-free and std
/// already links libc.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TRIGGERED: AtomicBool = AtomicBool::new(false);

    extern "C" fn latch(signum: i32) {
        if TRIGGERED.swap(true, Ordering::SeqCst) {
            // Second signal: the serve loop only polls the latch
            // between model landings, so a mid-tune Ctrl-C can take a
            // while to honor — a repeat means the operator insists.
            // Die NOW with the shell's 128+signal convention,
            // explicitly forfeiting the persist teardown (`_exit` is
            // async-signal-safe; nothing else here is allowed to be).
            unsafe { _exit(128 + signum) }
        }
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        fn _exit(status: i32) -> !;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, latch);
            signal(SIGTERM, latch);
        }
    }

    pub fn triggered() -> bool {
        TRIGGERED.load(Ordering::SeqCst)
    }
}

/// Non-unix fallback: no signals to latch; the shutdown RPC (and
/// process kill) remain the ways out.
#[cfg(not(unix))]
mod sig {
    pub fn install() {}

    pub fn triggered() -> bool {
        false
    }
}

fn emit(table: &Table, out_dir: &Path, slug: &str) -> Result<()> {
    print!("{}", table.render());
    let path = table.write_csv(out_dir, slug)?;
    println!("[csv] {}\n", path.display());
    Ok(())
}

fn open_artifacts(cli: &Cli) -> Result<Option<ArtifactStore>> {
    match &cli.cache_dir {
        None => Ok(None),
        Some(dir) => {
            let store = ArtifactStore::open(dir)
                .with_context(|| format!("opening artifact store at {}", dir.display()))?;
            eprintln!(
                "[artifacts] {} entries at {}",
                store.len(),
                store.root().display()
            );
            Ok(Some(store))
        }
    }
}

/// Post-persist artifact hygiene: GC down to `--cache-budget` (when
/// set) and settle pending LRU ticks. One helper, called by every
/// persist phase (`with_zoo`, session replay, the serve teardown), so
/// lifecycle behavior cannot drift between subcommands.
fn finish_artifacts(cli: &Cli, artifacts: &mut ArtifactStore) -> Result<()> {
    if let Some(budget) = cli.cache_budget {
        let gc = artifacts.gc(budget)?;
        eprintln!(
            "[artifacts] gc to {budget} bytes: evicted {} ({} bytes), kept {} ({} bytes), {} orphans removed",
            gc.evicted, gc.evicted_bytes, gc.kept, gc.kept_bytes, gc.orphans_removed
        );
        if gc.kept_bytes > budget {
            eprintln!(
                "[artifacts] warn: {} live-pinned artifacts keep the store over budget",
                gc.pinned
            );
        }
    }
    artifacts.flush()?;
    Ok(())
}

fn build_zoo_with(cli: &Cli, artifacts: Option<&mut ArtifactStore>) -> Zoo {
    eprintln!(
        "building zoo: device={} trials={} seed={} (deterministic{})",
        cli.device.name,
        cli.trials,
        cli.seed,
        if artifacts.is_some() { ", artifact-backed" } else { "" },
    );
    let zoo = Zoo::build_incremental(
        ExperimentConfig {
            trials: cli.trials,
            seed: cli.seed,
            device: cli.device.clone(),
            jobs: cli.jobs,
            speculative_keep: cli.speculative_keep,
            cost_model: cli.cost_model,
        },
        artifacts,
        |line| eprintln!("  {line}"),
    );
    let s = &zoo.build_stats;
    eprintln!(
        "  zoo ready: {} tuned / {} from artifacts ({} trials, {:.1}s tuning charged)",
        s.models_tuned, s.models_from_artifacts, s.trials_run, s.tuning_seconds_charged
    );
    zoo
}

/// Build a zoo (artifact-backed when `--cache-dir` is set), run `f`
/// over it, then persist the zoo-level artifacts — including the
/// measurement cache as warmed by whatever sweeps `f` ran.
fn with_zoo(cli: &Cli, f: impl FnOnce(&Zoo) -> Result<()>) -> Result<()> {
    let mut artifacts = open_artifacts(cli)?;
    let zoo = build_zoo_with(cli, artifacts.as_mut());
    f(&zoo)?;
    if let Some(a) = artifacts.as_mut() {
        zoo.persist(a)?;
        finish_artifacts(cli, a)?;
        eprintln!("[artifacts] persisted zoo store + measurement cache to {}", a.root().display());
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new(
        "Model zoo",
        &["Model", "Unique kernels", "Instances", "Classes", "GFLOPs"],
    );
    for m in models::all_models() {
        t.row(vec![
            m.name.clone(),
            m.kernels.len().to_string(),
            m.instances.len().to_string(),
            m.class_signatures().len().to_string(),
            format!("{:.2}", m.total_flops() / 1e9),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_devices() -> Result<()> {
    let mut t = Table::new(
        "Device profiles",
        &["Name", "Cores", "Freq", "SIMD", "Peak GFLOP/s", "DRAM GB/s", "RPC/meas"],
    );
    for p in [DeviceProfile::xeon_e5_2620(), DeviceProfile::cortex_a72()] {
        t.row(vec![
            p.name.to_string(),
            p.cores.to_string(),
            format!("{:.1} GHz", p.freq_ghz),
            format!("{}-bit", p.simd_bits),
            format!("{:.0}", p.peak_flops() / 1e9),
            format!("{:.0}", p.dram_gbps),
            format!("{:.1}s", p.rpc_overhead_s),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_table(cli: &Cli) -> Result<()> {
    let which = cli.target.clone().unwrap_or_default();
    match which.as_str() {
        "t1" | "table1" | "1" => emit(&tables::table1(), &cli.out, "table1")?,
        "t2" | "table2" | "2" => {
            with_zoo(cli, |zoo| emit(&tables::table2(zoo), &cli.out, "table2"))?;
        }
        "t3" | "table3" | "3" => {
            with_zoo(cli, |zoo| emit(&tables::table3(zoo), &cli.out, "table3"))?;
        }
        "t4" | "table4" | "4" => {
            with_zoo(cli, |zoo| emit(&tables::table4(zoo), &cli.out, "table4"))?;
        }
        other => bail!("unknown table `{other}` (t1|t2|t3|t4)"),
    }
    Ok(())
}

fn cmd_figure(cli: &Cli) -> Result<()> {
    let which = cli.target.clone().unwrap_or_default();
    match which.as_str() {
        "fig1" | "1" => {
            with_zoo(cli, |zoo| emit(&figures::fig1(zoo), &cli.out, "fig1"))?;
        }
        "fig4" | "4" => {
            with_zoo(cli, |zoo| emit(&figures::fig4(zoo), &cli.out, "fig4"))?;
        }
        "fig5" | "5" => {
            with_zoo(cli, |zoo| emit(&figures::fig5(zoo), &cli.out, "fig5"))?;
        }
        "fig6" | "6" => {
            // Fig 6 is Fig 5 on the edge device (its own zoo + its own
            // artifact keys; both zoos share one --cache-dir safely).
            let mut edge_cli = cli.clone();
            edge_cli.device = DeviceProfile::cortex_a72();
            with_zoo(&edge_cli, |zoo| emit(&figures::fig5(zoo), &cli.out, "fig6"))?;
        }
        "fig7" | "7" => {
            let config = ExperimentConfig {
                trials: cli.trials,
                seed: cli.seed,
                device: cli.device.clone(),
                jobs: cli.jobs,
                speculative_keep: cli.speculative_keep,
                cost_model: cli.cost_model,
            };
            let t = figures::fig7(&config, |l| eprintln!("  {l}"));
            emit(&t, &cli.out, "fig7")?;
        }
        "fig8" | "8" => {
            with_zoo(cli, |zoo| emit(&figures::fig8(zoo), &cli.out, "fig8"))?;
        }
        other => bail!("unknown figure `{other}` (fig1|fig4|fig5|fig6|fig7|fig8)"),
    }
    Ok(())
}

/// Tune one model, going through the artifact store when `--cache-dir`
/// is set: a matching artifact (same model, device, trials, seed) is
/// loaded instead of tuned, and a fresh tuning is persisted — the same
/// artifacts `Zoo::build_incremental` reads and writes, so `repro tune`
/// pre-warms `repro table/figure/all` and vice versa.
fn tune_cached(
    cli: &Cli,
    graph: &transfer_tuning::ir::ModelGraph,
    artifacts: &mut Option<ArtifactStore>,
) -> Result<transfer_tuning::autosched::TuningResult> {
    // Standalone tunes run under no learned prior (hash 0): they are
    // the base artifacts zoo builds share.
    let key = artifact::tuning_key(
        &graph.name,
        &cli.device,
        cli.trials,
        cli.seed,
        cli.speculative_keep,
        0,
    );
    if let Some(res) = artifacts.as_mut().and_then(|a| a.load_tuning(key)) {
        eprintln!("loaded {} from artifacts (0 trials run)", graph.name);
        return Ok(res);
    }
    let opts = TuneOptions {
        trials: cli.trials,
        seed: cli.seed,
        jobs: cli.jobs,
        speculative_keep: cli.speculative_keep,
        ..Default::default()
    };
    eprintln!("tuning {} ({} unique kernels) ...", graph.name, graph.kernels.len());
    let res = tune_model(graph, &cli.device, &opts);
    if let Some(a) = artifacts.as_mut() {
        a.save_tuning(key, &res)?;
    }
    Ok(res)
}

fn cmd_tune(cli: &Cli) -> Result<()> {
    let name = cli.model.clone().context("--model required")?;
    let graph = models::by_name(&name).with_context(|| format!("unknown model `{name}`"))?;
    let mut artifacts = open_artifacts(cli)?;
    let res = tune_cached(cli, &graph, &mut artifacts)?;
    let untuned = untuned_model_time(&graph, &cli.device);
    let tuned = res.final_model_time(&graph, &cli.device);
    let mut t = Table::new(
        &format!("Ansor tuning of {name} on {}", cli.device.name),
        &["Trials", "Search time", "Untuned", "Tuned", "Speedup"],
    );
    t.row(vec![
        res.trials_used.to_string(),
        fmt_duration(res.search_time_s),
        fmt_duration(untuned),
        fmt_duration(tuned),
        fmt_speedup(untuned / tuned),
    ]);
    emit(&t, &cli.out, &format!("tune_{}", name.to_lowercase()))?;

    if let Some(path) = &cli.store_path {
        let mut store = ScheduleStore::new();
        store.add_tuning(&graph, &res);
        store.save(path)?;
        println!("[store] {} records -> {}", store.records.len(), path.display());
    }
    Ok(())
}

fn cmd_transfer(cli: &Cli) -> Result<()> {
    let target_name = cli.model.clone().context("--model required")?;
    let target =
        models::by_name(&target_name).with_context(|| format!("unknown model `{target_name}`"))?;

    // Load a store from disk, or tune the source model on the fly.
    let (store, source) = match (&cli.store_path, &cli.source) {
        (Some(path), src) => {
            let store = ScheduleStore::load(path)?;
            let source = src
                .clone()
                .or_else(|| store.source_models().first().cloned())
                .context("store is empty")?;
            (store, source)
        }
        (None, Some(src)) => {
            let sg = models::by_name(src).with_context(|| format!("unknown model `{src}`"))?;
            let mut artifacts = open_artifacts(cli)?;
            let res = tune_cached(cli, &sg, &mut artifacts)?;
            let mut store = ScheduleStore::new();
            store.add_tuning(&sg, &res);
            (store, src.clone())
        }
        (None, None) => bail!("need --source MODEL or --store FILE"),
    };

    let res = transfer_tune_one_to_one(&target, &store, &source, &cli.device, cli.seed);
    let mut t = Table::new(
        &format!("Transfer-tuning {target_name} from {source} ({})", cli.device.name),
        &["Pairs", "Invalid", "Search time", "Untuned", "Transfer-tuned", "Speedup"],
    );
    t.row(vec![
        res.pairs_evaluated().to_string(),
        res.invalid_pairs().to_string(),
        fmt_duration(res.search_time_s()),
        fmt_duration(res.untuned_model_s),
        fmt_duration(res.tuned_model_s),
        fmt_speedup(res.speedup()),
    ]);
    emit(&t, &cli.out, &format!("transfer_{}", target_name.to_lowercase()))?;
    Ok(())
}

fn cmd_show_schedule(cli: &Cli) -> Result<()> {
    let name = cli.model.clone().context("--model required")?;
    let graph = models::by_name(&name).with_context(|| format!("unknown model `{name}`"))?;
    let kidx = cli.kernel.unwrap_or(0);
    let kernel = graph.kernels.get(kidx).with_context(|| {
        format!("kernel {kidx} out of range (model has {})", graph.kernels.len())
    })?;
    let opts = TuneOptions {
        trials: cli.trials.min(512),
        seed: cli.seed,
        jobs: cli.jobs,
        speculative_keep: cli.speculative_keep,
        ..Default::default()
    };
    let mut solo = transfer_tuning::ir::ModelGraph::new("solo");
    solo.push(kernel.clone());
    let res = tune_model(&solo, &cli.device, &opts);
    let best = res.best.get(&0).context("no schedule found")?;
    println!(
        "# {} kernel {} ({}), input {:?}",
        name,
        kidx,
        kernel.class_signature(),
        kernel.input_shape
    );
    println!("# best cost {:.4} ms — Algorithm-1 style trace:\n", best.cost_s * 1e3);
    print!("{}", trace::trace(&best.schedule, kernel));
    Ok(())
}

fn cmd_all(cli: &Cli) -> Result<()> {
    emit(&tables::table1(), &cli.out, "table1")?;
    emit(&tables::gemm_transfer(&cli.device, cli.seed), &cli.out, "gemm_transfer")?;

    with_zoo(cli, |zoo| {
        emit(&figures::fig1(zoo), &cli.out, "fig1")?;
        emit(&figures::fig4(zoo), &cli.out, "fig4")?;
        emit(&figures::fig5(zoo), &cli.out, "fig5")?;
        emit(&tables::table2(zoo), &cli.out, "table2")?;
        emit(&tables::table3(zoo), &cli.out, "table3")?;
        emit(&tables::table4(zoo), &cli.out, "table4")?;
        emit(&figures::fig8(zoo), &cli.out, "fig8")?;
        Ok(())
    })?;

    let config = ExperimentConfig {
        trials: cli.trials,
        seed: cli.seed,
        device: cli.device.clone(),
        jobs: cli.jobs,
        speculative_keep: cli.speculative_keep,
        cost_model: cli.cost_model,
    };
    emit(&figures::fig7(&config, |l| eprintln!("  {l}")), &cli.out, "fig7")?;

    let mut edge_cli = cli.clone();
    edge_cli.device = DeviceProfile::cortex_a72();
    with_zoo(&edge_cli, |zoo| emit(&figures::fig5(zoo), &cli.out, "fig6"))?;
    Ok(())
}

/// `repro serve --requests FILE`: drive the multi-tenant
/// [`ScheduleService`](transfer_tuning::service::ScheduleService) from
/// a JSONL request file. Each line is one tenant session:
///
/// ```text
/// {"model":"ResNet18"}
/// {"model":"BERT","device":"edge","budget_s":600,"seed":7}
/// ```
///
/// `device`/`seed` default to the CLI flags; omitting `budget_s` sweeps
/// the full mixed pool. Sessions are served concurrently against one
/// shared sharded measurement cache (`--shards`), and every reply is
/// deterministic in its request line alone. With `--cache-dir`, the
/// zoo behind the service is artifact-backed and the cache the sessions
/// warmed is persisted back.
fn cmd_serve_requests(cli: &Cli, path: &Path) -> Result<()> {
    use transfer_tuning::service::rpc::{parse_request, RpcDefaults};
    use transfer_tuning::service::{ServiceOptions, SessionReply, SessionRequest};

    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading request file {}", path.display()))?;
    // Same request schema + validation as the RPC front end — one
    // parser (rpc::parse_request) so the two transports cannot drift.
    let defaults = RpcDefaults { device: cli.device.clone(), seed: cli.seed };
    let mut requests: Vec<SessionRequest> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let req = parse_request(line, &defaults).map_err(|e| {
            anyhow::anyhow!("{}:{}: {} ({})", path.display(), lineno + 1, e.message, e.code)
        })?;
        requests.push(req);
    }
    anyhow::ensure!(!requests.is_empty(), "{}: no requests", path.display());

    let mut artifacts = open_artifacts(cli)?;
    let zoo = build_zoo_with(cli, artifacts.as_mut());
    let zoo_key = zoo.artifact_key();
    let service = ServiceOptions { speculative_keep: Some(cli.speculative_keep), cost_model: None }
        .service_from_zoo(zoo, cli.shards);

    // Fan sessions across workers; replies land in request order.
    // Worker count follows the --jobs/TT_JOBS knob (host-parallelism
    // concern, deliberately independent of --shards, which is a
    // cache-contention knob).
    let n_workers =
        transfer_tuning::coordinator::effective_jobs(cli.jobs).clamp(1, requests.len());
    let mut slots: Vec<Option<Result<SessionReply>>> = (0..requests.len()).map(|_| None).collect();
    let chunk = requests.len().div_ceil(n_workers).max(1);
    std::thread::scope(|scope| {
        for (req_chunk, slot_chunk) in requests.chunks(chunk).zip(slots.chunks_mut(chunk)) {
            let svc = service.clone();
            scope.spawn(move || {
                for (req, slot) in req_chunk.iter().zip(slot_chunk.iter_mut()) {
                    *slot = Some(svc.open_session(req));
                }
            });
        }
    });

    let mut t = Table::new(
        &format!(
            "ScheduleService: {} sessions, {} workers, {}-shard cache",
            requests.len(),
            n_workers,
            cli.shards.max(1)
        ),
        &[
            "#", "Target", "Device", "Budget", "Epoch", "Sources", "Speedup", "Standalone",
            "Charged",
        ],
    );
    for (i, (req, slot)) in requests.iter().zip(&slots).enumerate() {
        let budget = match req.budget_s {
            Some(b) => fmt_duration(b),
            None => "-".into(),
        };
        match slot.as_ref().expect("worker filled every slot") {
            Ok(reply) => {
                let sources = match reply.sources.len() {
                    0 => "-".to_string(),
                    1 => reply.sources[0].clone(),
                    n => format!("mixed({n})"),
                };
                t.row(vec![
                    (i + 1).to_string(),
                    reply.target.clone(),
                    reply.device.to_string(),
                    budget,
                    reply.epoch.to_string(),
                    sources,
                    fmt_speedup(reply.predicted_speedup()),
                    fmt_duration(reply.standalone_search_time_s),
                    fmt_duration(reply.charged_search_time_s),
                ]);
            }
            Err(e) => {
                t.row(vec![
                    (i + 1).to_string(),
                    req.model.clone(),
                    req.device.name.to_string(),
                    budget,
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    format!("error: {e}"),
                ]);
            }
        }
    }
    emit(&t, &cli.out, "serve_sessions")?;
    let stats = service.cache_stats();
    eprintln!(
        "[service] shared cache: hit-rate={:.1}% (hits={} dedup={} miss={})",
        stats.hit_rate() * 100.0,
        stats.hits,
        stats.dedup_hits,
        stats.misses,
    );
    if let Some(a) = artifacts.as_mut() {
        a.save_schedule_store(zoo_key, &service.store())?;
        a.save_measure_cache(zoo_key, &service.snapshot_cache())?;
        finish_artifacts(cli, a)?;
        eprintln!("[artifacts] persisted session-warmed cache to {}", a.root().display());
    }
    Ok(())
}

/// What the serve loop's admin hook shares with its RPC workers: the
/// zoo build accounting `stats` replies report. Updated by the main
/// thread at every landing; read by any worker at any time.
struct ServeState {
    zoo: std::sync::Mutex<transfer_tuning::report::ZooBuildStats>,
    complete: std::sync::atomic::AtomicBool,
}

/// What a landed republish reports back to its waiting RPC worker: the
/// new epoch and where the tuning came from — or a typed RPC error.
type RepublishReply = Result<(u64, &'static str), transfer_tuning::service::rpc::RpcError>;

/// What a landed `republish --all` reports back: the first and last
/// epochs of the serial run (consecutive by construction — the ops
/// loop is the only publisher) and how many models it covered.
type RepublishAllReply = Result<(u64, u64, usize), transfer_tuning::service::rpc::RpcError>;

/// Commands the admin hook forwards to the serve loop's main thread —
/// the only thread that owns the artifact store and may exit the
/// process. `Republish`/`RepublishAll` carry a reply channel: the RPC
/// worker blocks until the main thread lands the new tuning(s)
/// (clients see the epochs their republish produced, not a
/// fire-and-forget ack).
enum ServeControl {
    Republish(String, std::sync::mpsc::Sender<RepublishReply>),
    RepublishAll(std::sync::mpsc::Sender<RepublishAllReply>),
}

/// `repro serve --listen ADDR`: the real RPC front end — a
/// multi-threaded TCP server speaking length-prefixed JSONL (see
/// `transfer_tuning::service::rpc` for the frame format and README
/// §Wire protocol for schemas) over a **streaming** zoo build. The
/// server binds and answers sessions immediately; the zoo's models are
/// tuned (or loaded from `--cache-dir` artifacts) on the main thread
/// and published into the service one by one, each publish bumping the
/// store epoch that replies carry. Tenants connecting early are served
/// from whatever sources exist at that moment — the overlap of tuning
/// and serving the paper's economics argue for — instead of waiting for
/// all 11 models.
///
/// The server then stays up as an *operable* service:
///
/// * `repro admin ADDR stats` reports epoch, sources, cache counters,
///   and the build accounting at any time;
/// * `repro admin ADDR republish MODEL` re-tunes (or re-loads) one
///   model through the producer path and swaps it in at `epoch + 1`;
/// * `repro admin ADDR shutdown` — or SIGINT/SIGTERM — drains
///   connections and runs the teardown below.
///
/// **Persistence on any exit.** Whatever ends the serve loop (shutdown
/// RPC, signal, zoo completion + shutdown), one teardown path persists
/// the merged store and the *session-warmed* measurement cache to
/// `--cache-dir` and applies `--cache-budget` GC — so the cache a live
/// service accumulated survives, not just what the zoo build produced.
/// The RPC and signal paths are byte-identical by construction (they
/// are the same code); `rust/tests/serve_ops.rs` proves it.
///
/// **Resume after a crash.** A restart on the same `--cache-dir`
/// resumes an interrupted build: the store's open-time recovery pass
/// quarantines crash residue (reported in `stats` as
/// `server.quarantined`), committed tunings load warm at 0 trials, and
/// only the models the store does not cover are tuned — the artifact
/// store is the checkpoint (see `ZooProducer`'s resume notes).
fn cmd_serve_rpc(cli: &Cli, bind: &str) -> Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{mpsc, Arc};
    use transfer_tuning::report::{republish_model, ZooProducer};
    use transfer_tuning::service::rpc::{
        self as rpc, AdminRequest, RpcDefaults, RpcError, RpcServer,
    };
    use transfer_tuning::service::ServiceOptions;
    use transfer_tuning::util::json::Json;

    sig::install();
    let mut artifacts = open_artifacts(cli)?;
    let config = ExperimentConfig {
        trials: cli.trials,
        seed: cli.seed,
        device: cli.device.clone(),
        jobs: cli.jobs,
        speculative_keep: cli.speculative_keep,
        cost_model: cli.cost_model,
    };
    // Seed the serving cache from the persisted zoo-level measurement
    // cache (if any) BEFORE serving: a warm --cache-dir keeps serving
    // for free, and the save-on-exit below writes back a superset of
    // what was loaded, never a clobbered subset. Zoo-level artifacts
    // (store, cache, cost model) all live under the BASE key
    // (model_hash 0) — the build itself always runs under the
    // untrained prior, so the key cannot depend on its own output.
    let zoo_names: Vec<String> = models::all_models().iter().map(|m| m.name.clone()).collect();
    let zoo_key = artifact::zoo_key(
        &zoo_names,
        &config.device,
        config.trials,
        config.seed,
        config.effective_keep(),
        0,
    );
    let warm_cache = artifacts
        .as_mut()
        .and_then(|a| a.load_measure_cache(zoo_key))
        .unwrap_or_default();
    // Under --cost-model learned, adopt the persisted fitted prior (if
    // one exists) with zero re-training: served sessions draft through
    // it, and its content hash re-keys their speculative sweeps.
    let cost_prior = match cli.cost_model {
        CostModelKind::Learned => artifacts
            .as_mut()
            .and_then(|a| a.load_cost_model(zoo_key))
            .unwrap_or_default(),
        CostModelKind::Static => transfer_tuning::autosched::CostModel::default(),
    };
    if cost_prior.is_trained() {
        eprintln!(
            "[rpc] learned cost prior loaded (hash {:016x}, 0 re-training)",
            cost_prior.content_hash()
        );
    }
    let options = ServiceOptions {
        speculative_keep: Some(cli.speculative_keep),
        cost_model: Some(cost_prior),
    };
    let service = options.service_with_cache(&warm_cache, cli.shards);
    let defaults = RpcDefaults { device: cli.device.clone(), seed: cli.seed };

    let state = Arc::new(ServeState {
        zoo: std::sync::Mutex::new(transfer_tuning::report::ZooBuildStats::default()),
        complete: std::sync::atomic::AtomicBool::new(false),
    });
    // Set by the shutdown RPC; polled together with the signal latch.
    let stop_flag = Arc::new(AtomicBool::new(false));
    // False until the streaming build completes: a republish that
    // queued during the build would park an RPC worker in recv() for
    // the rest of the build (the producer owns the artifact-store
    // borrow until then) — so the hook refuses instead, and the
    // operator retries once `stats` reports the zoo complete.
    let republish_ready = Arc::new(AtomicBool::new(false));
    let (ctl_tx, ctl_rx) = mpsc::channel::<ServeControl>();
    // Created before the server so the admin hook can close over the
    // same gauges instance the reactor updates.
    let gauges = Arc::new(rpc::ServerGauges::default());
    let admin: rpc::AdminHook = {
        let state = state.clone();
        let stop_flag = stop_flag.clone();
        let republish_ready = republish_ready.clone();
        let gauges = gauges.clone();
        let refuse_during_build = || {
            rpc::error_json(&RpcError::new(
                "admin_unavailable",
                "initial zoo build in progress — retry once `stats` reports \
                 the zoo complete",
            ))
        };
        Arc::new(move |req, service| match req {
            AdminRequest::Stats => {
                let zoo = state.zoo.lock().expect("zoo stats lock").clone();
                rpc::stats_json(
                    service,
                    Some((&zoo, state.complete.load(Ordering::SeqCst))),
                    Some(rpc::ServerStats::snapshot(&gauges)),
                )
            }
            AdminRequest::Shutdown => {
                stop_flag.store(true, Ordering::SeqCst);
                rpc::admin_ack_json("shutdown", vec![("draining", Json::Bool(true))])
            }
            AdminRequest::Republish { model } => {
                if !republish_ready.load(Ordering::SeqCst) {
                    return refuse_during_build();
                }
                let (reply_tx, reply_rx) = mpsc::channel();
                if ctl_tx.send(ServeControl::Republish(model.clone(), reply_tx)).is_err() {
                    return rpc::error_json(&RpcError::new("internal", "server is stopping"));
                }
                match reply_rx.recv() {
                    Ok(Ok((epoch, origin))) => rpc::admin_ack_json(
                        "republish",
                        vec![
                            ("model", Json::str(model.as_str())),
                            ("epoch", Json::num(epoch as f64)),
                            ("origin", Json::str(origin)),
                        ],
                    ),
                    Ok(Err(e)) => rpc::error_json(&e),
                    Err(_) => rpc::error_json(&RpcError::new(
                        "internal",
                        "server stopped before the republish landed",
                    )),
                }
            }
            AdminRequest::RepublishAll => {
                if !republish_ready.load(Ordering::SeqCst) {
                    return refuse_during_build();
                }
                let (reply_tx, reply_rx) = mpsc::channel();
                if ctl_tx.send(ServeControl::RepublishAll(reply_tx)).is_err() {
                    return rpc::error_json(&RpcError::new("internal", "server is stopping"));
                }
                match reply_rx.recv() {
                    Ok(Ok((first_epoch, epoch, count))) => rpc::admin_ack_json(
                        "republish",
                        vec![
                            ("all", Json::Bool(true)),
                            ("first_epoch", Json::num(first_epoch as f64)),
                            ("epoch", Json::num(epoch as f64)),
                            ("models", Json::num(count as f64)),
                        ],
                    ),
                    Ok(Err(e)) => rpc::error_json(&e),
                    Err(_) => rpc::error_json(&RpcError::new(
                        "internal",
                        "server stopped before the republish landed",
                    )),
                }
            }
        })
    };

    let mut server_config = rpc::ServerConfig::default();
    if cli.max_conns > 0 {
        server_config.max_conns = cli.max_conns;
    }
    if cli.idle_timeout_s > 0 {
        server_config.idle_timeout = std::time::Duration::from_secs(cli.idle_timeout_s);
    }
    if cli.read_stall_s > 0 {
        server_config.read_stall = std::time::Duration::from_secs(cli.read_stall_s);
    }
    if cli.write_stall_s > 0 {
        server_config.write_stall = std::time::Duration::from_secs(cli.write_stall_s);
    }
    server_config.max_queue = cli.max_queue;
    // Export what the store's recovery pass quarantined on open: the
    // reactor never touches this gauge, but `stats` reports crash
    // residue alongside the serving counters — one place to look.
    if let Some(a) = artifacts.as_ref() {
        gauges
            .quarantined
            .store(a.stats.quarantined as usize, Ordering::SeqCst);
        if a.stats.quarantined > 0 {
            eprintln!(
                "[artifacts] recovery quarantined {} crash-residue file(s) into {}",
                a.stats.quarantined,
                a.root().join("quarantine").display()
            );
        }
    }
    let server = RpcServer::builder()
        .defaults(defaults)
        .config(server_config)
        .admin(admin)
        .gauges(gauges)
        .start(bind, service.clone())?;
    eprintln!(
        "[rpc] listening on {} (epoch 0; sources stream in as tunings land)",
        server.local_addr()
    );

    let stop_requested = || stop_flag.load(Ordering::SeqCst) || sig::triggered();

    // Phase 1: the streaming build. Stop requests are honored between
    // landings; republish requests are refused (`republish_ready` is
    // still false — the producer owns the artifact-store borrow, and a
    // queued republish would park an RPC worker for the whole build).
    let mut producer = ZooProducer::new(config.clone(), artifacts.as_mut());
    let total = producer.models().len();
    debug_assert_eq!(producer.zoo_key(), zoo_key, "seed/save keys must agree");
    while !stop_requested() {
        match producer.publish_next(&service, &mut |line| eprintln!("  {line}")) {
            Some(epoch) => {
                *state.zoo.lock().expect("zoo stats lock") = producer.stats.clone();
                eprintln!("[rpc] store epoch {epoch}: {epoch}/{total} sources live");
            }
            None => break,
        }
    }
    let zoo_complete = producer.remaining() == 0;
    let stats = producer.stats.clone();
    *state.zoo.lock().expect("zoo stats lock") = stats.clone();
    state.complete.store(zoo_complete, Ordering::SeqCst);
    drop(producer);
    if zoo_complete {
        eprintln!(
            "[rpc] zoo complete: {} tuned / {} from artifacts ({} trials, {:.1}s tuning charged)",
            stats.models_tuned,
            stats.models_from_artifacts,
            stats.trials_run,
            stats.tuning_seconds_charged
        );
    } else {
        eprintln!(
            "[rpc] build interrupted with {}/{total} sources live; persisting what landed",
            service.live_sources().len()
        );
    }

    // Phase 2: the operations loop — republishes land here, serialized
    // on the main thread (epochs stay totally ordered), until a
    // shutdown RPC or signal asks us down.
    republish_ready.store(zoo_complete, Ordering::SeqCst);
    if !stop_requested() {
        eprintln!("[rpc] serving (repro admin {} stats|republish|shutdown)", server.local_addr());
    }
    while !stop_requested() {
        match ctl_rx.recv_timeout(std::time::Duration::from_millis(100)) {
            Ok(ServeControl::Republish(name, reply)) => {
                let result = match models::by_name(&name) {
                    None => Err(RpcError::new(
                        "unknown_model",
                        format!("unknown model `{name}` (see `repro models`)"),
                    )),
                    Some(graph) => {
                        eprintln!("[rpc] republish {name}:");
                        // The service's live prior feeds forward into the
                        // republish: a trained model re-keys (and re-tunes)
                        // the refreshed tuning; untrained = legacy keys.
                        let (epoch, cost) = republish_model(
                            graph,
                            config.clone(),
                            service.cost_model().as_ref().clone(),
                            artifacts.as_mut(),
                            &service,
                            &mut |line| eprintln!("  {line}"),
                        );
                        let mut zoo = state.zoo.lock().expect("zoo stats lock");
                        zoo.models_tuned += cost.models_tuned;
                        zoo.models_from_artifacts += cost.models_from_artifacts;
                        zoo.trials_run += cost.trials_run;
                        zoo.tuning_seconds_charged += cost.tuning_seconds_charged;
                        let origin =
                            if cost.models_from_artifacts == 1 { "artifact" } else { "tuned" };
                        eprintln!("[rpc] store epoch {epoch}: republished {name} ({origin})");
                        Ok((epoch, origin))
                    }
                };
                let _ = reply.send(result);
            }
            Ok(ServeControl::RepublishAll(reply)) => {
                // Serial on purpose: the ops loop is the only
                // publisher, so the run lands at strictly consecutive
                // epochs [first_epoch, epoch] and `stats` observers see
                // a totally ordered refresh.
                let zoo_models = models::all_models();
                eprintln!("[rpc] republish --all: {} models", zoo_models.len());
                let mut first_epoch = 0u64;
                let mut last_epoch = 0u64;
                let mut count = 0usize;
                for graph in zoo_models {
                    let name = graph.name.clone();
                    let (epoch, cost) = republish_model(
                        graph,
                        config.clone(),
                        service.cost_model().as_ref().clone(),
                        artifacts.as_mut(),
                        &service,
                        &mut |line| eprintln!("  {line}"),
                    );
                    {
                        let mut zoo = state.zoo.lock().expect("zoo stats lock");
                        zoo.models_tuned += cost.models_tuned;
                        zoo.models_from_artifacts += cost.models_from_artifacts;
                        zoo.trials_run += cost.trials_run;
                        zoo.tuning_seconds_charged += cost.tuning_seconds_charged;
                    }
                    let origin =
                        if cost.models_from_artifacts == 1 { "artifact" } else { "tuned" };
                    eprintln!("[rpc] store epoch {epoch}: republished {name} ({origin})");
                    if count == 0 {
                        first_epoch = epoch;
                    }
                    last_epoch = epoch;
                    count += 1;
                }
                let _ = reply.send(Ok((first_epoch, last_epoch, count)));
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }

    // Teardown — ONE path for every exit (shutdown RPC, SIGINT,
    // SIGTERM): drop the control queue first (queued republishes error
    // out instead of deadlocking their workers), drain the server, then
    // persist the session-warmed state.
    eprintln!("[rpc] shutting down: draining connections");
    drop(ctl_rx);
    server.shutdown();
    if let Some(a) = artifacts.as_mut() {
        a.save_schedule_store(zoo_key, &service.store())?;
        a.save_measure_cache(zoo_key, &service.snapshot_cache())?;
        finish_artifacts(cli, a)?;
        eprintln!(
            "[artifacts] persisted zoo store + session-warmed measurement cache to {}",
            a.root().display()
        );
    }
    eprintln!("[rpc] shutdown complete");
    Ok(())
}

/// One framed request/response round-trip against a live server — the
/// thin client both `repro call` and `repro admin` stand on, so
/// operators never hand-roll length prefixes. I/O failures keep their
/// `std::io::Error` in the anyhow chain so the retry layer can
/// classify them without sniffing message strings.
fn rpc_roundtrip(addr: &str, line: &str) -> Result<String> {
    use std::io::Write as _;
    use transfer_tuning::service::rpc;

    let mut stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr}"))?;
    // Client-half fault sites (the server's reactor has its own): a
    // `--fault-plan` here rehearses a flaky client→server link. Injected
    // errors are ErrorKind::Other — NOT transient by the retry contract
    // — so a faulted run fails deterministically instead of retrying.
    if transfer_tuning::faults::should_fail("rpc.write") {
        return Err(anyhow::Error::new(transfer_tuning::faults::io_error("rpc.write")))
            .context("sending request frame");
    }
    let frame = rpc::encode_frame(line).map_err(|e| anyhow::anyhow!("encoding request: {e}"))?;
    stream.write_all(&frame).context("sending request frame")?;
    if transfer_tuning::faults::should_fail("rpc.read") {
        return Err(anyhow::Error::new(transfer_tuning::faults::io_error("rpc.read")))
            .context("reading response frame");
    }
    rpc::read_frame(&mut stream).map_err(|e| match e {
        rpc::FrameError::Io(io) => anyhow::Error::new(io).context("reading response frame"),
        other => anyhow::anyhow!("reading response frame: {other}"),
    })
}

/// Is a failed round-trip transient by the retry contract? Only
/// connect-refused (server restarting or not yet bound) and timeouts
/// qualify — a bad address, a framing violation, or any in-band
/// application error is deterministic and must not be retried.
fn transient_io(e: &anyhow::Error) -> bool {
    e.chain().any(|cause| {
        cause.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::ConnectionRefused
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::WouldBlock
            )
        })
    })
}

/// If `payload` is a retryable in-band refusal — the `overloaded`
/// error, or a fleet router's `fleet_unavailable` (wire v6) — its
/// `retry_after_ms` hint (defaulted when absent); `None` for every
/// other payload. Both codes mean the request never reached a worker;
/// no other in-band error is retryable.
fn overloaded_hint_ms(payload: &str) -> Option<u64> {
    let j = transfer_tuning::util::json::parse(payload).ok()?;
    let err = j.get("error")?;
    if !matches!(err.get("code")?.as_str()?, "overloaded" | "fleet_unavailable") {
        return None;
    }
    Some(
        err.get("retry_after_ms")
            .and_then(|v| v.as_f64())
            .filter(|ms| ms.is_finite() && *ms >= 0.0)
            .map(|ms| ms as u64)
            .unwrap_or(transfer_tuning::service::rpc::OVERLOADED_RETRY_AFTER_MS),
    )
}

/// [`rpc_roundtrip`] under the `--retries` contract: up to `retries`
/// re-attempts after a transient failure — connect refused, timeout,
/// or a typed `overloaded` reply — with exponential backoff seeded by
/// the request bytes and the attempt index, so two runs of the same
/// command sleep identically (deterministic jitter, same discipline as
/// every other noise source in the tree). The base delay honors the
/// server's `retry_after_ms` hint when one was sent.
fn rpc_roundtrip_retrying(addr: &str, line: &str, retries: usize) -> Result<String> {
    use transfer_tuning::util::rng::Rng;

    // FNV-1a over the request line: the jitter seed is content-derived,
    // never wall-clock.
    let mut seed: u64 = 0xcbf2_9ce4_8422_2325;
    for b in line.bytes() {
        seed ^= b as u64;
        seed = seed.wrapping_mul(0x0000_0100_0000_01b3);
    }
    for attempt in 0..=retries {
        let (reason, base_ms) = match rpc_roundtrip(addr, line) {
            Ok(payload) => match overloaded_hint_ms(&payload) {
                Some(hint) if attempt < retries => ("server overloaded".to_string(), hint),
                _ => return Ok(payload),
            },
            Err(e) if transient_io(&e) && attempt < retries => (format!("{e:#}"), 50),
            Err(e) => return Err(e),
        };
        let backoff_ms = base_ms.saturating_mul(1u64 << attempt.min(5)).min(2_000);
        let jitter_ms = Rng::new(seed ^ attempt as u64).range(0, (backoff_ms / 4 + 1) as usize);
        let delay = std::time::Duration::from_millis(backoff_ms + jitter_ms as u64);
        eprintln!(
            "[client] attempt {}/{} failed ({reason}); retrying in {}ms",
            attempt + 1,
            retries + 1,
            delay.as_millis()
        );
        std::thread::sleep(delay);
    }
    unreachable!("the final attempt returns above")
}

/// Print one response payload and mirror its `ok` field in the exit
/// status (scripts branch on `repro admin`/`repro call` exit codes).
fn emit_rpc_payload(payload: &str) -> Result<()> {
    println!("{payload}");
    let ok = transfer_tuning::util::json::parse(payload)
        .ok()
        .and_then(|j| j.get("ok").and_then(|v| v.as_bool()))
        .unwrap_or(false);
    anyhow::ensure!(ok, "server answered with an error (payload above)");
    Ok(())
}

/// `repro call ADDR REQUEST`: frame one raw request payload (session or
/// admin JSON — exactly what a `--requests` line holds), print the
/// response payload on stdout.
fn cmd_call(cli: &Cli) -> Result<()> {
    let addr = cli.target.clone().context("usage: repro call ADDR REQUEST")?;
    let request = cli.rest.first().context("usage: repro call ADDR REQUEST")?;
    anyhow::ensure!(
        cli.rest.len() == 1,
        "unexpected argument `{}` — quote the request payload as ONE argument",
        cli.rest[1]
    );
    emit_rpc_payload(&rpc_roundtrip_retrying(&addr, request, cli.retries)?)
}

/// `repro admin ADDR stats|shutdown|republish MODEL|republish --all`:
/// the operator verbs, encoded for you. `stats` reports serving + build
/// state; `shutdown` asks the server to drain and persist; `republish`
/// swaps a refreshed tuning into the live service at `epoch + 1`
/// (`--all` walks the whole zoo at consecutive epochs).
fn cmd_admin(cli: &Cli) -> Result<()> {
    use transfer_tuning::util::json::Json;

    const USAGE: &str = "usage: repro admin ADDR stats|shutdown|republish MODEL|republish --all";
    let addr = cli.target.clone().context(USAGE)?;
    let op = cli.rest.first().context(USAGE)?;
    let expect_args = |n: usize| -> Result<()> {
        anyhow::ensure!(
            cli.rest.len() == n,
            "unexpected argument `{}` after `{op}` ({USAGE})",
            cli.rest[n]
        );
        Ok(())
    };
    let line = match op.as_str() {
        "stats" | "shutdown" => {
            expect_args(1)?;
            Json::obj(vec![("op", Json::str(op.as_str()))]).to_compact()
        }
        "republish" if cli.all => {
            expect_args(1)?;
            Json::obj(vec![("op", Json::str("republish")), ("all", Json::Bool(true))])
                .to_compact()
        }
        "republish" => {
            let model = cli
                .rest
                .get(1)
                .context("usage: repro admin ADDR republish MODEL (or republish --all)")?;
            expect_args(2)?;
            Json::obj(vec![("op", Json::str("republish")), ("model", Json::str(model.as_str()))])
                .to_compact()
        }
        other => bail!("unknown admin op `{other}` ({USAGE})"),
    };
    emit_rpc_payload(&rpc_roundtrip_retrying(&addr, &line, cli.retries)?)
}

/// `repro fleet`: consistent-hash routing over multiple serve
/// instances, plus the sync verb that converges their artifact state.
///
/// * `repro fleet --listen ADDR --instance ADDR...` — run the router: a
///   transparent proxy that hashes each session's `(model, device)`
///   pair onto a ring of the instances and forwards frames verbatim
///   (see `transfer_tuning::service::fleet`). `overloaded` replies
///   redirect to the next replica; connect/forward failures rehash to
///   the successor and probe the downed instance on seeded backoff.
/// * `repro fleet sync DIR... [--instance ADDR...]` — converge the
///   instances' `--cache-dir`s to their union (all-ordered-pairs
///   `merge_from`), then ask each `--instance` to `republish --all` so
///   the reconciled artifacts go live at consecutive epochs.
fn cmd_fleet(cli: &Cli) -> Result<()> {
    use transfer_tuning::service::fleet::{FleetConfig, FleetRouter};
    use transfer_tuning::util::json::Json;

    if cli.target.as_deref() == Some("sync") {
        anyhow::ensure!(
            cli.rest.len() >= 2,
            "usage: repro fleet sync DIR DIR... [--instance ADDR...]"
        );
        let roots: Vec<PathBuf> = cli.rest.iter().map(PathBuf::from).collect();
        let report = transfer_tuning::artifact::sync_stores(&roots)?;
        println!(
            "[fleet] sync: {} stores converged over {} ordered pairs ({} added, {} caches \
             unioned, {} identical, {} conflicts, {} rejected)",
            report.stores,
            report.pairs,
            report.added,
            report.caches_unioned,
            report.identical,
            report.conflicts,
            report.rejected,
        );
        let republish = Json::obj(vec![("op", Json::str("republish")), ("all", Json::Bool(true))])
            .to_compact();
        for addr in &cli.instances {
            let payload = rpc_roundtrip_retrying(addr, &republish, cli.retries)
                .with_context(|| format!("republish --all on {addr}"))?;
            println!("[fleet] {addr}: {payload}");
        }
        return Ok(());
    }
    anyhow::ensure!(
        cli.target.is_none(),
        "unknown fleet verb `{}` (usage: repro fleet --listen ADDR --instance ADDR... \
         | repro fleet sync DIR DIR...)",
        cli.target.as_deref().unwrap_or_default()
    );
    let bind = cli
        .listen
        .as_deref()
        .context("usage: repro fleet --listen ADDR --instance ADDR...")?;
    anyhow::ensure!(
        !cli.instances.is_empty(),
        "repro fleet needs at least one --instance ADDR backend"
    );

    sig::install();
    let mut config = FleetConfig::default();
    if cli.max_conns > 0 {
        config.server.max_conns = cli.max_conns;
    }
    if cli.idle_timeout_s > 0 {
        config.server.idle_timeout = std::time::Duration::from_secs(cli.idle_timeout_s);
    }
    if cli.read_stall_s > 0 {
        config.server.read_stall = std::time::Duration::from_secs(cli.read_stall_s);
    }
    if cli.write_stall_s > 0 {
        config.server.write_stall = std::time::Duration::from_secs(cli.write_stall_s);
    }
    config.server.max_queue = cli.max_queue;
    let router = FleetRouter::start(bind, &cli.instances, config)?;
    eprintln!(
        "[fleet] routing on {} across {} instance(s): {}",
        router.local_addr(),
        router.ring().len(),
        router.ring().instances().join(", ")
    );
    while !sig::triggered() && !router.stop_requested() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    eprintln!("[fleet] shutting down: draining connections");
    eprintln!("[fleet] final stats: {}", router.stats().to_compact());
    router.shutdown();
    eprintln!("[fleet] shutdown complete");
    Ok(())
}

/// `repro cache gc|merge|stats`: offline artifact-store lifecycle.
///
/// * `repro cache gc --cache-dir DIR --cache-budget BYTES` — shrink a
///   directory to the budget, least-recently-used artifacts first.
/// * `repro cache merge SRC... --cache-dir DEST` — union other
///   machines' artifact dirs into DEST (content-addressed keys make the
///   union safe; measurement caches are merged entry-wise).
/// * `repro cache stats --cache-dir DIR` — artifact count + bytes.
fn cmd_cache(cli: &Cli) -> Result<()> {
    let sub = cli.target.clone().unwrap_or_default();
    let dir = cli
        .cache_dir
        .clone()
        .context("`repro cache` needs --cache-dir DIR (the store to operate on)")?;
    let mut store = ArtifactStore::open(&dir)
        .with_context(|| format!("opening artifact store at {}", dir.display()))?;
    if matches!(sub.as_str(), "gc" | "stats") && !cli.rest.is_empty() {
        bail!(
            "unexpected argument `{}` — `repro cache {sub}` takes flags only \
             (--cache-dir, --cache-budget)",
            cli.rest[0]
        );
    }
    match sub.as_str() {
        "gc" => {
            let budget = cli
                .cache_budget
                .context("usage: repro cache gc --cache-dir DIR --cache-budget BYTES")?;
            let before = store.total_bytes();
            let gc = store.gc(budget)?;
            println!(
                "[cache] gc {}: {} -> {} bytes (budget {budget}); evicted {} artifacts ({} bytes), {} orphaned files removed",
                dir.display(),
                before,
                gc.kept_bytes,
                gc.evicted,
                gc.evicted_bytes,
                gc.orphans_removed,
            );
        }
        "merge" => {
            anyhow::ensure!(
                !cli.rest.is_empty(),
                "usage: repro cache merge SRC_DIR... --cache-dir DEST"
            );
            for src in &cli.rest {
                let m = store
                    .merge_from(Path::new(src))
                    .with_context(|| format!("merging {src}"))?;
                println!(
                    "[cache] merged {src}: {} added, {} caches unioned, {} identical, {} conflicts (kept ours), {} rejected",
                    m.added, m.caches_unioned, m.identical, m.conflicts, m.rejected
                );
            }
        }
        "stats" => {
            println!(
                "[cache] {}: {} artifacts, {} bytes",
                dir.display(),
                store.len(),
                store.total_bytes()
            );
            // Crash residue: what THIS open's recovery pass moved into
            // quarantine/, plus whatever earlier passes left there for
            // inspection (quarantined files are never deleted by us).
            let held = std::fs::read_dir(dir.join("quarantine"))
                .map(|d| d.count())
                .unwrap_or(0);
            if store.stats.quarantined > 0 || held > 0 {
                println!(
                    "[cache] quarantine: {} file(s) moved on this open, {} held in {}",
                    store.stats.quarantined,
                    held,
                    dir.join("quarantine").display()
                );
            }
        }
        other => bail!("unknown cache subcommand `{other}` (gc|merge|stats)"),
    }
    Ok(())
}

/// `repro serve` (without `--requests`): a real serving loop over the
/// AOT-compiled CNN artifacts — Poisson request arrivals, FIFO queue,
/// PJRT execution, latency percentiles. Demonstrates the L3 request
/// path end to end (Python nowhere in sight).
fn cmd_serve(cli: &Cli) -> Result<()> {
    if let Some(path) = &cli.requests {
        return cmd_serve_requests(cli, path);
    }
    if let Some(bind) = cli.listen.clone() {
        return cmd_serve_rpc(cli, &bind);
    }
    use transfer_tuning::coordinator::LatencyHistogram;
    use transfer_tuning::runtime::{artifacts_dir, Runtime};
    use transfer_tuning::util::rng::Rng;

    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        bail!("artifacts not found in {} — run `make artifacts` first", dir.display());
    }
    let n_requests = cli.trials.min(2000); // reuse --trials as request count
    let variant = cli.source.clone().unwrap_or_else(|| "tuned".into());
    let rt = Runtime::cpu()?;
    let kernel = rt.load_hlo_text(&dir.join(format!("model_{variant}.hlo.txt")))?;

    // Inputs: synthetic image + weights (weight-value independent timing).
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))?;
    let manifest = transfer_tuning::util::json::parse(&manifest)?;
    let shapes: Vec<Vec<i64>> = manifest
        .req(&format!("model_{variant}"))?
        .req("inputs")?
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.as_arr().unwrap().iter().map(|d| d.as_f64().unwrap() as i64).collect())
        .collect();
    let mut rng = Rng::new(cli.seed);
    let buffers: Vec<Vec<f32>> = shapes
        .iter()
        .map(|s| {
            (0..s.iter().product::<i64>() as usize)
                .map(|_| rng.f64() as f32 - 0.5)
                .collect()
        })
        .collect();
    let inputs: Vec<(&[f32], &[i64])> =
        buffers.iter().zip(&shapes).map(|(b, s)| (b.as_slice(), s.as_slice())).collect();

    // Warm up, then estimate service rate to set a 70%-utilization
    // arrival rate (stable queue).
    let service_s = kernel.bench_f32(&inputs, 3, 10)?;
    let arrival_rate = 0.7 / service_s;
    eprintln!(
        "serving model_{variant}: service time {:.3} ms -> offered load {:.0} req/s (70% util), {n_requests} requests",
        service_s * 1e3,
        arrival_rate
    );

    // Poisson arrivals; FIFO queue; sequential device (one executable).
    let mut hist = LatencyHistogram::new();
    let mut queue_free_at = 0.0f64; // when the device becomes free (virtual clock)
    let mut arrival = 0.0f64;
    let t0 = std::time::Instant::now();
    for _ in 0..n_requests {
        arrival += -arrival_rate.recip() * (1.0 - rng.f64()).ln();
        // Execute for real; use measured time as this request's service time.
        let s0 = std::time::Instant::now();
        let out = kernel.run_f32(&inputs)?;
        anyhow::ensure!(out.iter().all(|v| v.is_finite()), "non-finite logits");
        let service = s0.elapsed().as_secs_f64();
        let start = queue_free_at.max(arrival);
        let done = start + service;
        queue_free_at = done;
        hist.record(done - arrival);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        &format!("Serving report: model_{variant} (PJRT CPU, Poisson open loop @70% util)"),
        &["Requests", "Throughput", "p50", "p95", "p99", "Mean"],
    );
    t.row(vec![
        hist.total.to_string(),
        format!("{:.0} req/s", n_requests as f64 / wall),
        format!("{:.3} ms", hist.percentile(50.0) * 1e3),
        format!("{:.3} ms", hist.percentile(95.0) * 1e3),
        format!("{:.3} ms", hist.percentile(99.0) * 1e3),
        format!("{:.3} ms", hist.mean() * 1e3),
    ]);
    emit(&t, &cli.out, &format!("serve_{variant}"))?;
    Ok(())
}

const HELP: &str = "\
repro — Transfer-Tuning reproduction (Gibson & Cano, 2022)

USAGE: repro <command> [args] [flags]

COMMANDS
  models                      list the 11-model zoo
  devices                     list device profiles
  table t1|t2|t3|t4           reproduce a paper table
  figure fig1|fig4|fig5|fig6|fig7|fig8
                              reproduce a paper figure (as data table + CSV)
  gemm-transfer               the §4.1 GEMM cross-application example
  tune --model M              Ansor-tune one model (--store F saves schedules)
  transfer --model M --source S | --store F
                              transfer-tune M from S's schedules
  show-schedule --model M --kernel I
                              print a tuned schedule as an Algorithm-1 trace
  serve --listen ADDR         RPC front end: multi-threaded TCP server
                              (length-prefixed JSONL frames; see README
                              \"Wire protocol\") over a STREAMING zoo build —
                              sessions are answered from whatever sources
                              have landed; replies carry the store epoch
  serve --requests FILE       replayable client mode: one JSONL line per
                              session ({\"model\":..,\"device\":..,
                              \"budget_s\":..,\"seed\":..}), served concurrently
                              against a sharded measurement cache
  serve [--source default|tuned] [--trials N]
                              serve the AOT CNN artifact: Poisson open loop,
                              latency percentiles (real PJRT execution)
  call ADDR REQUEST           thin client: send one framed request payload
                              (session or admin JSON) and print the response
  admin ADDR stats            report epoch/sources/cache/build state
  admin ADDR republish MODEL  re-tune (or re-load) MODEL and swap it into
                              the live service at epoch+1
  admin ADDR republish --all  republish every zoo model serially, landing
                              at consecutive epochs
  admin ADDR shutdown         drain connections, persist the warmed cache
                              (SIGINT/SIGTERM run the same teardown)
  fleet --listen ADDR --instance ADDR...
                              consistent-hash router over N serve
                              instances: sessions hash by (model, device)
                              onto a virtual-node ring and are forwarded
                              verbatim (replies byte-identical to a direct
                              backend call); `overloaded` redirects to the
                              next replica, a dead instance rehashes to
                              its successor (seeded backoff probes);
                              `admin ADDR stats` on the router reports the
                              wire-v6 `fleet` block
  fleet sync DIR... [--instance ADDR...]
                              converge instance cache-dirs to their union
                              (pairwise merge_from), then `republish
                              --all` on each --instance so the reconciled
                              artifacts go live
  cache gc --cache-dir D --cache-budget BYTES
                              shrink an artifact dir to BYTES (LRU first;
                              live-pinned artifacts never evicted)
  cache merge SRC... --cache-dir DEST
                              union artifact dirs from other machines into
                              DEST (content-addressed keys; measurement
                              caches merge entry-wise)
  cache stats --cache-dir D   artifact count + total payload bytes
  all                         every table + figure (server zoo + edge zoo)

FLAGS
  --trials N      Ansor trial budget (default 2000; paper uses 20000)
  --seed S        RNG seed (default 0xA45)
  --device D      server | edge (default server)
  --out DIR       CSV output directory (default results/)
  --store FILE    schedule-store path (JSONL)
  --cache-dir DIR persistent artifact store: tunings, the merged schedule
                  store, and the measurement cache survive the process, so
                  repeated table/figure/tune/transfer/all runs at the same
                  (device, trials, seed) re-tune nothing, charge zero
                  device-seconds, and print bit-identical results
  --requests FILE session-request JSONL for `serve`
  --listen ADDR   TCP bind address for the `serve` RPC front end
                  (e.g. 127.0.0.1:7461; port 0 picks one)
  --max-conns N   cap on concurrently open RPC connections for `serve
                  --listen` (default 16384); at the cap the listener
                  pauses and the kernel backlog queues new connects
  --idle-timeout SECS
                  reap RPC connections with no in-flight traffic after
                  SECS of silence (default 30)
  --read-stall SECS
                  evict RPC connections stalled mid-frame (a slowloris
                  drip) after SECS without a byte of progress
                  (default 30)
  --write-stall SECS
                  evict RPC connections whose outbound buffer makes no
                  progress (client stopped reading replies) for SECS
                  (default 30)
  --max-queue N   worker-queue bound for `serve --listen`: a request
                  landing when N decoded requests are already waiting
                  is answered at once with the typed `overloaded`
                  error (with a retry_after_ms hint) instead of
                  queueing — the connection stays healthy. 0 (default)
                  = unbounded
  --instance ADDR `fleet` only (repeatable): a backend serve instance.
                  The router hashes the instance SET — flag order and
                  duplicates never change placement
  --retries N     `call`/`admin` only: retry transient failures —
                  connect refused, timeout, `overloaded`,
                  `fleet_unavailable` — up to N times with
                  deterministic jittered exponential
                  backoff (honoring the server's retry_after_ms hint).
                  In-band application errors are never retried.
                  Default 0 (one attempt)
  --fault-plan SPEC
                  deterministic fault injection for crash-safety and
                  degradation testing (also: TT_FAULTS env var), e.g.
                  'io.write:after=3;rpc.accept:prob=0.05@seed=7;
                  persist.rename:nth=2'. Sites: io.write,
                  persist.rename, rpc.accept, rpc.read, rpc.write,
                  rpc.handler (delay-only), measure.pair. A test/ops
                  tool: the plan NEVER enters artifact keys — a run
                  under faults writes the same bytes as a clean run,
                  it just fails at the chosen points
  --shards N      measurement-cache shards for `serve` (default 8)
  --cache-budget BYTES
                  artifact-store size budget: every persist phase GCs the
                  --cache-dir down to BYTES, evicting least-recently-used
                  artifacts first but never one the running process loaded
                  or wrote (a warm restart after GC stays warm)
  --jobs N        host worker threads for every parallel fan-out: up to
                  N models tune concurrently during zoo builds, tuner
                  candidate batches and measurement sweeps fan across N
                  threads, and `serve --requests` replays sessions on N
                  workers. Purely a wall-clock knob — results are
                  bit-identical at any value. Default: TT_JOBS env var,
                  else all cores
  --speculative-keep F
                  draft-then-verify fraction in (0, 1]: each candidate
                  batch is ranked by the cost model and only the top F
                  reaches full simulation/measurement. 1.0 (default) is
                  the exact path, byte-identical to runs without the
                  flag. Unlike --jobs this changes results, so pruned
                  runs live under their own artifact and measurement-
                  cache keys
  --cost-model static|learned
                  candidate estimator. static (default): per-run models
                  trained from scratch, no key ingredient. learned: a
                  GBDT prior fitted deterministically from the measure
                  cache at fixed size thresholds, persisted as a
                  versioned artifact; once trained, its content hash
                  keys every tuning/sweep it influences (untrained it
                  appends nothing, so default runs keep legacy keys)
";

fn main() -> Result<()> {
    let cli = parse_args()?;
    // Only the client/lifecycle commands take positionals beyond the
    // first; anywhere else a stray one is a typo (e.g. a flag value
    // with its `--flag` forgotten) that must not be silently ignored.
    if !cli.rest.is_empty()
        && !matches!(cli.command.as_str(), "call" | "admin" | "cache" | "fleet")
    {
        bail!(
            "unexpected argument `{}` for `repro {}` (see `repro help`)",
            cli.rest[0],
            cli.command
        );
    }
    // One knob for every fan-out in the process: zoo model workers,
    // tuner candidate batches, the measurement pool, session replay.
    // Deterministic — thread counts never change results.
    transfer_tuning::coordinator::set_global_jobs(cli.jobs);
    // Deterministic fault injection (test/ops tool). The plan is
    // process state, NEVER an artifact-key ingredient: a run under
    // faults writes the same bytes as a clean one — it just fails at
    // the chosen points. `--fault-plan` beats the TT_FAULTS env var.
    let fault_spec = cli.fault_plan.clone().or_else(|| std::env::var("TT_FAULTS").ok());
    if let Some(spec) = fault_spec.filter(|s| !s.trim().is_empty()) {
        transfer_tuning::faults::install_spec(&spec)
            .map_err(|e| anyhow::anyhow!("--fault-plan: {e}"))?;
        eprintln!("[faults] plan active: {spec}");
    }
    match cli.command.as_str() {
        "models" => cmd_models(),
        "devices" => cmd_devices(),
        "table" => cmd_table(&cli),
        "figure" => cmd_figure(&cli),
        "gemm-transfer" => {
            emit(&tables::gemm_transfer(&cli.device, cli.seed), &cli.out, "gemm_transfer")
        }
        "tune" => cmd_tune(&cli),
        "transfer" => cmd_transfer(&cli),
        "serve" => cmd_serve(&cli),
        "call" => cmd_call(&cli),
        "admin" => cmd_admin(&cli),
        "fleet" => cmd_fleet(&cli),
        "cache" => cmd_cache(&cli),
        "show-schedule" => cmd_show_schedule(&cli),
        "all" => cmd_all(&cli),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n\n{HELP}"),
    }
}
