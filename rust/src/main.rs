//! `repro` — the launcher for the transfer-tuning system.
//!
//! Every table and figure of the paper is a subcommand (see DESIGN.md §4
//! for the experiment index). Results print as aligned tables and are
//! also written as CSV under `results/`.
//!
//! ```text
//! repro models                         # model zoo inventory
//! repro table t1|t2|t3|t4              # paper tables
//! repro figure fig1|fig4|fig5|fig6|fig7|fig8
//! repro gemm-transfer                  # §4.1 GEMM example (simulated)
//! repro tune --model ResNet18          # Ansor-tune one model
//! repro transfer --model ResNet18 --source ResNet50
//! repro show-schedule --model ResNet18 --kernel 6
//! repro all                            # everything (one zoo per device)
//! ```
//!
//! Common flags: `--trials N` (Ansor budget; paper uses 20000),
//! `--seed S`, `--device server|edge`, `--out DIR` (CSV directory).

use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use transfer_tuning::autosched::{tune_model, TuneOptions};
use transfer_tuning::device::{untuned_model_time, DeviceProfile};
use transfer_tuning::models;
use transfer_tuning::report::{figures, tables, ExperimentConfig, Zoo};
use transfer_tuning::sched::trace;
use transfer_tuning::transfer::{transfer_tune_one_to_one, ScheduleStore};
use transfer_tuning::util::table::{fmt_duration, fmt_speedup, Table};

#[derive(Clone, Debug)]
struct Cli {
    command: String,
    target: Option<String>, // positional after command (table/figure name)
    model: Option<String>,
    source: Option<String>,
    kernel: Option<usize>,
    trials: usize,
    seed: u64,
    device: DeviceProfile,
    out: PathBuf,
    store_path: Option<PathBuf>,
}

fn parse_args() -> Result<Cli> {
    let mut args = std::env::args().skip(1).peekable();
    let command = args.next().unwrap_or_else(|| "help".into());
    let mut cli = Cli {
        command,
        target: None,
        model: None,
        source: None,
        kernel: None,
        trials: 2000,
        seed: 0xA45,
        device: DeviceProfile::xeon_e5_2620(),
        out: PathBuf::from("results"),
        store_path: None,
    };
    while let Some(arg) = args.next() {
        let mut value = |name: &str| -> Result<String> {
            args.next().with_context(|| format!("flag {name} needs a value"))
        };
        match arg.as_str() {
            "--model" => cli.model = Some(value("--model")?),
            "--source" => cli.source = Some(value("--source")?),
            "--kernel" => cli.kernel = Some(value("--kernel")?.parse()?),
            "--trials" => cli.trials = value("--trials")?.parse()?,
            "--seed" => cli.seed = value("--seed")?.parse()?,
            "--device" => {
                let name = value("--device")?;
                cli.device = DeviceProfile::by_name(&name)
                    .with_context(|| format!("unknown device `{name}` (server|edge)"))?;
            }
            "--out" => cli.out = PathBuf::from(value("--out")?),
            "--store" => cli.store_path = Some(PathBuf::from(value("--store")?)),
            other if !other.starts_with("--") && cli.target.is_none() => {
                cli.target = Some(other.to_string())
            }
            other => bail!("unknown flag `{other}` (see `repro help`)"),
        }
    }
    Ok(cli)
}

fn emit(table: &Table, out_dir: &PathBuf, slug: &str) -> Result<()> {
    print!("{}", table.render());
    let path = table.write_csv(out_dir, slug)?;
    println!("[csv] {}\n", path.display());
    Ok(())
}

fn build_zoo(cli: &Cli) -> Zoo {
    eprintln!(
        "building zoo: device={} trials={} seed={} (deterministic)",
        cli.device.name, cli.trials, cli.seed
    );
    Zoo::build(
        ExperimentConfig { trials: cli.trials, seed: cli.seed, device: cli.device.clone() },
        |line| eprintln!("  {line}"),
    )
}

fn cmd_models() -> Result<()> {
    let mut t = Table::new(
        "Model zoo",
        &["Model", "Unique kernels", "Instances", "Classes", "GFLOPs"],
    );
    for m in models::all_models() {
        t.row(vec![
            m.name.clone(),
            m.kernels.len().to_string(),
            m.instances.len().to_string(),
            m.class_signatures().len().to_string(),
            format!("{:.2}", m.total_flops() / 1e9),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_devices() -> Result<()> {
    let mut t = Table::new(
        "Device profiles",
        &["Name", "Cores", "Freq", "SIMD", "Peak GFLOP/s", "DRAM GB/s", "RPC/meas"],
    );
    for p in [DeviceProfile::xeon_e5_2620(), DeviceProfile::cortex_a72()] {
        t.row(vec![
            p.name.to_string(),
            p.cores.to_string(),
            format!("{:.1} GHz", p.freq_ghz),
            format!("{}-bit", p.simd_bits),
            format!("{:.0}", p.peak_flops() / 1e9),
            format!("{:.0}", p.dram_gbps),
            format!("{:.1}s", p.rpc_overhead_s),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_table(cli: &Cli) -> Result<()> {
    let which = cli.target.clone().unwrap_or_default();
    match which.as_str() {
        "t1" | "table1" | "1" => emit(&tables::table1(), &cli.out, "table1")?,
        "t2" | "table2" | "2" => {
            let zoo = build_zoo(cli);
            emit(&tables::table2(&zoo), &cli.out, "table2")?;
        }
        "t3" | "table3" | "3" => {
            let zoo = build_zoo(cli);
            emit(&tables::table3(&zoo), &cli.out, "table3")?;
        }
        "t4" | "table4" | "4" => {
            let zoo = build_zoo(cli);
            emit(&tables::table4(&zoo), &cli.out, "table4")?;
        }
        other => bail!("unknown table `{other}` (t1|t2|t3|t4)"),
    }
    Ok(())
}

fn cmd_figure(cli: &Cli) -> Result<()> {
    let which = cli.target.clone().unwrap_or_default();
    match which.as_str() {
        "fig1" | "1" => {
            let zoo = build_zoo(cli);
            emit(&figures::fig1(&zoo), &cli.out, "fig1")?;
        }
        "fig4" | "4" => {
            let zoo = build_zoo(cli);
            emit(&figures::fig4(&zoo), &cli.out, "fig4")?;
        }
        "fig5" | "5" => {
            let zoo = build_zoo(cli);
            emit(&figures::fig5(&zoo), &cli.out, "fig5")?;
        }
        "fig6" | "6" => {
            // Fig 6 is Fig 5 on the edge device.
            let mut edge_cli = cli.clone();
            edge_cli.device = DeviceProfile::cortex_a72();
            let zoo = build_zoo(&edge_cli);
            emit(&figures::fig5(&zoo), &cli.out, "fig6")?;
        }
        "fig7" | "7" => {
            let config =
                ExperimentConfig { trials: cli.trials, seed: cli.seed, device: cli.device.clone() };
            let t = figures::fig7(&config, |l| eprintln!("  {l}"));
            emit(&t, &cli.out, "fig7")?;
        }
        "fig8" | "8" => {
            let zoo = build_zoo(cli);
            emit(&figures::fig8(&zoo), &cli.out, "fig8")?;
        }
        other => bail!("unknown figure `{other}` (fig1|fig4|fig5|fig6|fig7|fig8)"),
    }
    Ok(())
}

fn cmd_tune(cli: &Cli) -> Result<()> {
    let name = cli.model.clone().context("--model required")?;
    let graph = models::by_name(&name).with_context(|| format!("unknown model `{name}`"))?;
    let opts = TuneOptions { trials: cli.trials, seed: cli.seed, ..Default::default() };
    eprintln!("tuning {name} ({} unique kernels) ...", graph.kernels.len());
    let res = tune_model(&graph, &cli.device, &opts);
    let untuned = untuned_model_time(&graph, &cli.device);
    let tuned = res.final_model_time(&graph, &cli.device);
    let mut t = Table::new(
        &format!("Ansor tuning of {name} on {}", cli.device.name),
        &["Trials", "Search time", "Untuned", "Tuned", "Speedup"],
    );
    t.row(vec![
        res.trials_used.to_string(),
        fmt_duration(res.search_time_s),
        fmt_duration(untuned),
        fmt_duration(tuned),
        fmt_speedup(untuned / tuned),
    ]);
    emit(&t, &cli.out, &format!("tune_{}", name.to_lowercase()))?;

    if let Some(path) = &cli.store_path {
        let mut store = ScheduleStore::new();
        store.add_tuning(&graph, &res);
        store.save(path)?;
        println!("[store] {} records -> {}", store.records.len(), path.display());
    }
    Ok(())
}

fn cmd_transfer(cli: &Cli) -> Result<()> {
    let target_name = cli.model.clone().context("--model required")?;
    let target =
        models::by_name(&target_name).with_context(|| format!("unknown model `{target_name}`"))?;

    // Load a store from disk, or tune the source model on the fly.
    let (store, source) = match (&cli.store_path, &cli.source) {
        (Some(path), src) => {
            let store = ScheduleStore::load(path)?;
            let source = src
                .clone()
                .or_else(|| store.source_models().first().cloned())
                .context("store is empty")?;
            (store, source)
        }
        (None, Some(src)) => {
            let sg = models::by_name(src).with_context(|| format!("unknown model `{src}`"))?;
            eprintln!("tuning source {src} first ({} trials) ...", cli.trials);
            let res = tune_model(&sg, &cli.device, &TuneOptions { trials: cli.trials, seed: cli.seed, ..Default::default() });
            let mut store = ScheduleStore::new();
            store.add_tuning(&sg, &res);
            (store, src.clone())
        }
        (None, None) => bail!("need --source MODEL or --store FILE"),
    };

    let res = transfer_tune_one_to_one(&target, &store, &source, &cli.device, cli.seed);
    let mut t = Table::new(
        &format!("Transfer-tuning {target_name} from {source} ({})", cli.device.name),
        &["Pairs", "Invalid", "Search time", "Untuned", "Transfer-tuned", "Speedup"],
    );
    t.row(vec![
        res.pairs_evaluated().to_string(),
        res.invalid_pairs().to_string(),
        fmt_duration(res.search_time_s()),
        fmt_duration(res.untuned_model_s),
        fmt_duration(res.tuned_model_s),
        fmt_speedup(res.speedup()),
    ]);
    emit(&t, &cli.out, &format!("transfer_{}", target_name.to_lowercase()))?;
    Ok(())
}

fn cmd_show_schedule(cli: &Cli) -> Result<()> {
    let name = cli.model.clone().context("--model required")?;
    let graph = models::by_name(&name).with_context(|| format!("unknown model `{name}`"))?;
    let kidx = cli.kernel.unwrap_or(0);
    let kernel = graph.kernels.get(kidx).with_context(|| {
        format!("kernel {kidx} out of range (model has {})", graph.kernels.len())
    })?;
    let opts = TuneOptions { trials: cli.trials.min(512), seed: cli.seed, ..Default::default() };
    let mut solo = transfer_tuning::ir::ModelGraph::new("solo");
    solo.push(kernel.clone());
    let res = tune_model(&solo, &cli.device, &opts);
    let best = res.best.get(&0).context("no schedule found")?;
    println!(
        "# {} kernel {} ({}), input {:?}",
        name,
        kidx,
        kernel.class_signature(),
        kernel.input_shape
    );
    println!("# best cost {:.4} ms — Algorithm-1 style trace:\n", best.cost_s * 1e3);
    print!("{}", trace::trace(&best.schedule, kernel));
    Ok(())
}

fn cmd_all(cli: &Cli) -> Result<()> {
    emit(&tables::table1(), &cli.out, "table1")?;
    emit(&tables::gemm_transfer(&cli.device, cli.seed), &cli.out, "gemm_transfer")?;

    let zoo = build_zoo(cli);
    emit(&figures::fig1(&zoo), &cli.out, "fig1")?;
    emit(&figures::fig4(&zoo), &cli.out, "fig4")?;
    emit(&figures::fig5(&zoo), &cli.out, "fig5")?;
    emit(&tables::table2(&zoo), &cli.out, "table2")?;
    emit(&tables::table3(&zoo), &cli.out, "table3")?;
    emit(&tables::table4(&zoo), &cli.out, "table4")?;
    emit(&figures::fig8(&zoo), &cli.out, "fig8")?;

    let config = ExperimentConfig { trials: cli.trials, seed: cli.seed, device: cli.device.clone() };
    emit(&figures::fig7(&config, |l| eprintln!("  {l}")), &cli.out, "fig7")?;

    let mut edge_cli = cli.clone();
    edge_cli.device = DeviceProfile::cortex_a72();
    let edge_zoo = build_zoo(&edge_cli);
    emit(&figures::fig5(&edge_zoo), &cli.out, "fig6")?;
    Ok(())
}

/// `repro serve`: a real serving loop over the AOT-compiled CNN
/// artifacts — Poisson request arrivals, FIFO queue, PJRT execution,
/// latency percentiles. Demonstrates the L3 request path end to end
/// (Python nowhere in sight).
fn cmd_serve(cli: &Cli) -> Result<()> {
    use transfer_tuning::coordinator::LatencyHistogram;
    use transfer_tuning::runtime::{artifacts_dir, Runtime};
    use transfer_tuning::util::rng::Rng;

    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        bail!("artifacts not found in {} — run `make artifacts` first", dir.display());
    }
    let n_requests = cli.trials.min(2000); // reuse --trials as request count
    let variant = cli.source.clone().unwrap_or_else(|| "tuned".into());
    let rt = Runtime::cpu()?;
    let kernel = rt.load_hlo_text(&dir.join(format!("model_{variant}.hlo.txt")))?;

    // Inputs: synthetic image + weights (weight-value independent timing).
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))?;
    let manifest = transfer_tuning::util::json::parse(&manifest)?;
    let shapes: Vec<Vec<i64>> = manifest
        .req(&format!("model_{variant}"))?
        .req("inputs")?
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.as_arr().unwrap().iter().map(|d| d.as_f64().unwrap() as i64).collect())
        .collect();
    let mut rng = Rng::new(cli.seed);
    let buffers: Vec<Vec<f32>> = shapes
        .iter()
        .map(|s| {
            (0..s.iter().product::<i64>() as usize)
                .map(|_| rng.f64() as f32 - 0.5)
                .collect()
        })
        .collect();
    let inputs: Vec<(&[f32], &[i64])> =
        buffers.iter().zip(&shapes).map(|(b, s)| (b.as_slice(), s.as_slice())).collect();

    // Warm up, then estimate service rate to set a 70%-utilization
    // arrival rate (stable queue).
    let service_s = kernel.bench_f32(&inputs, 3, 10)?;
    let arrival_rate = 0.7 / service_s;
    eprintln!(
        "serving model_{variant}: service time {:.3} ms -> offered load {:.0} req/s (70% util), {n_requests} requests",
        service_s * 1e3,
        arrival_rate
    );

    // Poisson arrivals; FIFO queue; sequential device (one executable).
    let mut hist = LatencyHistogram::new();
    let mut queue_free_at = 0.0f64; // when the device becomes free (virtual clock)
    let mut arrival = 0.0f64;
    let t0 = std::time::Instant::now();
    for _ in 0..n_requests {
        arrival += -arrival_rate.recip() * (1.0 - rng.f64()).ln();
        // Execute for real; use measured time as this request's service time.
        let s0 = std::time::Instant::now();
        let out = kernel.run_f32(&inputs)?;
        anyhow::ensure!(out.iter().all(|v| v.is_finite()), "non-finite logits");
        let service = s0.elapsed().as_secs_f64();
        let start = queue_free_at.max(arrival);
        let done = start + service;
        queue_free_at = done;
        hist.record(done - arrival);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        &format!("Serving report: model_{variant} (PJRT CPU, Poisson open loop @70% util)"),
        &["Requests", "Throughput", "p50", "p95", "p99", "Mean"],
    );
    t.row(vec![
        hist.total.to_string(),
        format!("{:.0} req/s", n_requests as f64 / wall),
        format!("{:.3} ms", hist.percentile(50.0) * 1e3),
        format!("{:.3} ms", hist.percentile(95.0) * 1e3),
        format!("{:.3} ms", hist.percentile(99.0) * 1e3),
        format!("{:.3} ms", hist.mean() * 1e3),
    ]);
    emit(&t, &cli.out, &format!("serve_{variant}"))?;
    Ok(())
}

const HELP: &str = "\
repro — Transfer-Tuning reproduction (Gibson & Cano, 2022)

USAGE: repro <command> [args] [flags]

COMMANDS
  models                      list the 11-model zoo
  devices                     list device profiles
  table t1|t2|t3|t4           reproduce a paper table
  figure fig1|fig4|fig5|fig6|fig7|fig8
                              reproduce a paper figure (as data table + CSV)
  gemm-transfer               the §4.1 GEMM cross-application example
  tune --model M              Ansor-tune one model (--store F saves schedules)
  transfer --model M --source S | --store F
                              transfer-tune M from S's schedules
  show-schedule --model M --kernel I
                              print a tuned schedule as an Algorithm-1 trace
  serve [--source default|tuned] [--trials N]
                              serve the AOT CNN artifact: Poisson open loop,
                              latency percentiles (real PJRT execution)
  all                         every table + figure (server zoo + edge zoo)

FLAGS
  --trials N    Ansor trial budget (default 2000; paper uses 20000)
  --seed S      RNG seed (default 0xA45)
  --device D    server | edge (default server)
  --out DIR     CSV output directory (default results/)
  --store FILE  schedule-store path (JSONL)
";

fn main() -> Result<()> {
    let cli = parse_args()?;
    match cli.command.as_str() {
        "models" => cmd_models(),
        "devices" => cmd_devices(),
        "table" => cmd_table(&cli),
        "figure" => cmd_figure(&cli),
        "gemm-transfer" => {
            emit(&tables::gemm_transfer(&cli.device, cli.seed), &cli.out, "gemm_transfer")
        }
        "tune" => cmd_tune(&cli),
        "transfer" => cmd_transfer(&cli),
        "serve" => cmd_serve(&cli),
        "show-schedule" => cmd_show_schedule(&cli),
        "all" => cmd_all(&cli),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n\n{HELP}"),
    }
}
