//! # transfer-tuning
//!
//! A from-scratch reproduction of *Transfer-Tuning: Reusing
//! Auto-Schedules for Efficient Tensor Program Code Generation*
//! (Gibson & Cano, 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains the paper's complete system and every substrate it
//! depends on:
//!
//! * [`ir`] — tensor-program IR: kernels as canonical loop nests with
//!   affine buffer accesses, model graphs with use counts.
//! * [`models`] — the 11-model DNN zoo of the paper's evaluation
//!   (ResNet18/50, AlexNet, VGG-16, MobileNetV2, EfficientNetB0/B4,
//!   GoogLeNet, MnasNet1.0, BERT, MobileBERT).
//! * [`sched`] — the schedule language (Split/Reorder/Fuse/Parallel/
//!   Unroll/Vectorize/ComputeAt/cache-write) in shape-relative form,
//!   with application + transfer legality checking.
//! * [`device`] — analytic CPU cost simulator with Xeon-E5-2620 and
//!   Cortex-A72 profiles (the measurement substrate).
//! * [`autosched`] — the Ansor-like auto-scheduler baseline: sketch
//!   generation, evolutionary search, learned cost model, gradient task
//!   scheduler.
//! * [`transfer`] — the paper's contribution: kernel classes, the
//!   schedule store, the model-selection heuristic (Eq. 1), and the
//!   one-to-one / mixed-pool transfer-tuning engines.
//! * [`coordinator`] — measurement worker pool, the content-addressed
//!   measurement cache (repeated sweeps pay for a pair once), search-time
//!   ledger, and RPC-device emulation for edge tuning.
//! * [`artifact`] — the persistent artifact store: tuning results, the
//!   merged schedule store, and the measurement cache as durable,
//!   integrity-checked files under a `--cache-dir`, so tuned state
//!   survives the process and warm runs re-tune nothing.
//! * [`faults`] — deterministic fault injection: a seeded `FaultPlan`
//!   (`--fault-plan` / `TT_FAULTS`) drives injected write/rename/accept/
//!   read/measure failures so crash-safety and degradation are testable
//!   and bit-replayable, without ever entering artifact keys.
//! * [`service`] — multi-tenant serving: one shared zoo behind an
//!   `Arc`, a sharded measurement cache, a deterministic session API
//!   (`open_session`) answering concurrent schedule requests, the
//!   event-driven RPC front end (epoll reactor + timer wheel) that
//!   serves thousands of connections from one event-loop thread, and
//!   the fleet router (`service::fleet`) that consistent-hash-routes
//!   sessions over multiple serve instances as a transparent proxy.
//! * [`runtime`] — PJRT execution of the AOT-compiled Pallas/JAX
//!   artifacts (the *real* hot path; Python is never on it).
//! * [`report`] — regenerates every table and figure of the paper.

pub mod artifact;
pub mod autosched;
pub mod coordinator;
pub mod device;
pub mod faults;
pub mod ir;
pub mod models;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod transfer;
pub mod util;
