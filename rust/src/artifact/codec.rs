//! JSON codec for [`TuningResult`] — the per-model tuning artifact.
//!
//! Ansor's own workflow persists tuning *logs* and replays them to skip
//! re-search; the artifact store persists the distilled result instead
//! (best schedule + deterministic cost per kernel, plus the search
//! trajectory the paper's Fig 1/5 comparisons need). Every f64 is
//! written with Rust's shortest-round-trip formatting and every
//! schedule through the canonical serializer, so a load returns a
//! result whose downstream numbers are **bit-identical** to the run
//! that produced it — the warm-start invariant of `crate::artifact`.
//!
//! Crash safety lives one layer down, in `ArtifactStore::write_atomic`
//! (temp + fsync + rename, manifest last): the codec's canonical bytes
//! are untouched by it, which is why the golden manifest fixture — and
//! [`ARTIFACT_FORMAT_VERSION`](super::ARTIFACT_FORMAT_VERSION) — did
//! not move when the store became crash-safe. Faults injected on the
//! write path (`io.write`, `persist.rename`) can tear a *temp* file,
//! never a committed one, so a decoded artifact is always a fully
//! committed artifact.

use crate::autosched::{HistoryPoint, KernelBest, TuningResult};
use crate::sched::serialize;
use crate::util::json::Json;
use std::collections::HashMap;

/// Codec version of the tuning-artifact JSON (independent of the
/// store-level manifest version; bump on any schema change here).
pub const TUNING_CODEC_VERSION: u64 = 1;

/// One row of the artifact manifest (format v2): where an artifact
/// lives, how to verify it, and the lifecycle metadata the GC runs on.
/// `bytes` is the payload size (so a size budget needs no stat calls);
/// `last_used` is a store-wide monotonic tick bumped on every verified
/// load and every write — LRU order, durable across processes, and
/// deterministic (derived from access order, never from wall time).
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub kind: String,
    pub file: String,
    pub checksum: u64,
    pub bytes: u64,
    pub last_used: u64,
}

/// Encode one manifest row. Lives beside the other persisted-schema
/// codecs so the `format-drift` gate sees every byte-format change in
/// one place; the golden fixture `rust/tests/golden/
/// artifact_manifest.json` pins the resulting manifest bytes.
pub fn manifest_entry_to_json(e: &ManifestEntry) -> Json {
    Json::obj(vec![
        ("kind", Json::str(&e.kind)),
        ("file", Json::str(&e.file)),
        ("checksum", Json::str(format!("{:016x}", e.checksum))),
        ("bytes", Json::num(e.bytes as f64)),
        ("last_used", Json::num(e.last_used as f64)),
    ])
}

/// Decode one manifest row; `None` skips a malformed row (the store
/// keeps the rest — artifacts are a cache, not a database).
pub fn manifest_entry_from_json(j: &Json) -> Option<ManifestEntry> {
    Some(ManifestEntry {
        kind: j.get("kind")?.as_str()?.to_string(),
        file: j.get("file")?.as_str()?.to_string(),
        checksum: u64::from_str_radix(j.get("checksum")?.as_str()?, 16).ok()?,
        bytes: j.get("bytes")?.as_f64().filter(|b| *b >= 0.0)? as u64,
        last_used: j.get("last_used")?.as_f64().filter(|t| *t >= 0.0)? as u64,
    })
}

pub fn tuning_to_json(res: &TuningResult) -> Json {
    // HashMap iteration order is process-random; emit kernels sorted so
    // the artifact bytes are canonical.
    let mut kernels: Vec<usize> = res.best.keys().copied().collect();
    kernels.sort_unstable();
    let best = kernels.into_iter().map(|k| {
        let b = &res.best[&k];
        Json::obj(vec![
            ("kernel", Json::num(k as f64)),
            ("cost_s", Json::num(b.cost_s)),
            ("schedule", serialize::to_json(&b.schedule)),
        ])
    });
    let history = res.history.iter().map(|h| {
        Json::obj(vec![
            ("trials", Json::num(h.trials as f64)),
            ("search_time_s", Json::num(h.search_time_s)),
            ("model_time_s", Json::num(h.model_time_s)),
        ])
    });
    Json::obj(vec![
        ("version", Json::num(TUNING_CODEC_VERSION as f64)),
        ("model", Json::str(&res.model)),
        ("trials_used", Json::num(res.trials_used as f64)),
        ("search_time_s", Json::num(res.search_time_s)),
        ("best", Json::arr(best)),
        ("history", Json::arr(history)),
    ])
}

pub fn tuning_from_json(j: &Json) -> anyhow::Result<TuningResult> {
    let version = j.req("version")?.as_f64().unwrap_or(0.0) as u64;
    anyhow::ensure!(
        version == TUNING_CODEC_VERSION,
        "unsupported tuning-artifact version {version}"
    );
    let mut best: HashMap<usize, KernelBest> = HashMap::new();
    for (i, e) in j.req("best")?.as_arr().unwrap_or(&[]).iter().enumerate() {
        let kernel = e
            .req("kernel")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("best[{i}]: kernel must be a number"))?;
        let cost_s = e
            .req("cost_s")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("best[{i}]: cost_s must be a number"))?;
        let schedule = serialize::from_json(e.req("schedule")?)?;
        best.insert(kernel, KernelBest { schedule, cost_s });
    }
    let mut history = Vec::new();
    for (i, e) in j.req("history")?.as_arr().unwrap_or(&[]).iter().enumerate() {
        history.push(HistoryPoint {
            trials: e
                .req("trials")?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("history[{i}]: trials must be a number"))?,
            search_time_s: e
                .req("search_time_s")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("history[{i}]: bad search_time_s"))?,
            model_time_s: e
                .req("model_time_s")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("history[{i}]: bad model_time_s"))?,
            // Diagnostic-only field, deliberately not persisted (the
            // codec schema is unchanged); loads see 0.0.
            rank_corr: 0.0,
        });
    }
    Ok(TuningResult {
        model: j.req("model")?.as_str().unwrap_or_default().to_string(),
        best,
        search_time_s: j
            .req("search_time_s")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("search_time_s must be a number"))?,
        trials_used: j
            .req("trials_used")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("trials_used must be a number"))?,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::device::DeviceProfile;
    use crate::ir::{KernelBuilder, ModelGraph};
    use crate::util::json;

    fn small_tuning() -> (ModelGraph, TuningResult) {
        let mut g = ModelGraph::new("CodecModel");
        g.push(KernelBuilder::dense(256, 256, 256, &[]));
        g.push(KernelBuilder::dense(512, 512, 512, &[]));
        let prof = DeviceProfile::xeon_e5_2620();
        let opts = TuneOptions {
            trials: 48,
            batch_size: 16,
            population: 32,
            generations: 2,
            ..Default::default()
        };
        let res = tune_model(&g, &prof, &opts);
        (g, res)
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let (g, res) = small_tuning();
        let text = tuning_to_json(&res).to_compact();
        let back = tuning_from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.model, res.model);
        assert_eq!(back.trials_used, res.trials_used);
        assert_eq!(back.search_time_s.to_bits(), res.search_time_s.to_bits());
        assert_eq!(back.best.len(), res.best.len());
        for (k, b) in &res.best {
            let rb = &back.best[k];
            assert_eq!(rb.schedule, b.schedule);
            assert_eq!(rb.cost_s.to_bits(), b.cost_s.to_bits());
        }
        assert_eq!(back.history.len(), res.history.len());
        for (a, b) in back.history.iter().zip(&res.history) {
            assert_eq!(a.trials, b.trials);
            assert_eq!(a.search_time_s.to_bits(), b.search_time_s.to_bits());
            assert_eq!(a.model_time_s.to_bits(), b.model_time_s.to_bits());
        }
        // The downstream quantity the reports consume is bit-identical.
        let prof = DeviceProfile::xeon_e5_2620();
        assert_eq!(
            back.final_model_time(&g, &prof).to_bits(),
            res.final_model_time(&g, &prof).to_bits()
        );
    }

    #[test]
    fn serialization_is_canonical_across_equal_results() {
        // Two structurally equal results (independently computed, so the
        // HashMap iteration order may differ) serialize to equal bytes.
        let (_, a) = small_tuning();
        let (_, b) = small_tuning();
        assert_eq!(tuning_to_json(&a).to_compact(), tuning_to_json(&b).to_compact());
    }

    #[test]
    fn manifest_entry_round_trips_and_skips_malformed() {
        let e = ManifestEntry {
            kind: "tuning".into(),
            file: "tuning_00000000deadbeef.json".into(),
            checksum: 0xdead_beef,
            bytes: 42,
            last_used: 7,
        };
        assert_eq!(manifest_entry_from_json(&manifest_entry_to_json(&e)), Some(e));
        assert_eq!(manifest_entry_from_json(&json::parse("{}").unwrap()), None);
        let bad_checksum =
            r#"{"bytes":1,"checksum":"zz","file":"f","kind":"x","last_used":1}"#;
        assert!(manifest_entry_from_json(&json::parse(bad_checksum).unwrap()).is_none());
        let negative_tick =
            r#"{"bytes":1,"checksum":"00000000000000aa","file":"f","kind":"x","last_used":-1}"#;
        assert!(manifest_entry_from_json(&json::parse(negative_tick).unwrap()).is_none());
    }

    #[test]
    fn rejects_wrong_version_and_malformed() {
        assert!(tuning_from_json(&json::parse("{}").unwrap()).is_err());
        let (_, res) = small_tuning();
        let mut text = tuning_to_json(&res).to_compact();
        text = text.replace("\"version\":1", "\"version\":99");
        assert!(tuning_from_json(&json::parse(&text).unwrap()).is_err());
    }
}
