//! Persistent, content-addressed artifact store for tuned state.
//!
//! PR 1's [`MeasureCache`] proved content-addressed reuse inside one
//! process; this module extends the same discipline across processes:
//! everything expensive a `repro` run produces — per-model
//! [`TuningResult`]s, the merged [`ScheduleStore`], and the measurement
//! cache — becomes a durable, shareable artifact under a `--cache-dir`.
//! A warm run rebuilds a full zoo with **zero tuning trials and zero
//! charged device-seconds** while every reported (standalone) number
//! stays bit-identical to the cold run at the same seed.
//!
//! ## Addressing
//!
//! Artifacts are keyed by FNV-1a over length-prefixed canonical byte
//! strings (the same discipline as `coordinator/cache.rs`): artifact
//! kind, model name(s), device-profile name, trial budget, seed, and
//! the store-format version. Any input that could change the artifact's
//! bytes is part of the key, so a stale artifact can never be served
//! for a different configuration — it simply misses.
//!
//! ## Layout and integrity
//!
//! ```text
//! <cache-dir>/
//!   manifest.json            # version + {key -> kind, file, checksum,
//!                            #            bytes, last_used}
//!   tuning_<key>.json        # one TuningResult (codec.rs)
//!   store_<key>.jsonl        # merged ScheduleStore (canonical JSONL)
//!   mcache_<key>.json        # MeasureCache snapshot (cache.rs format)
//!   costmodel_<key>.json     # fitted CostModel (costmodel.rs format)
//! ```
//!
//! Loads are integrity-checked: the manifest records the FNV-1a
//! checksum of each artifact's bytes, and a mismatch (truncated file,
//! hand edit, torn write) rejects the entry — the caller re-tunes and
//! overwrites. A manifest whose `version` differs from
//! [`ARTIFACT_FORMAT_VERSION`] is discarded wholesale (stale-version
//! invalidation): version bumps accompany any change to the canonical
//! serialization formats the checksums and keys are built from.
//!
//! ## Crash safety
//!
//! Every persisted file — payloads *and* the manifest — is written as
//! write-temp (`.tmp.<name>`) + fsync + atomic rename, with the
//! manifest rename as the commit point of any batch. A crash at any
//! moment therefore leaves one of exactly three disk states: the old
//! committed state (torn temp beside it), new payloads the manifest
//! does not reference yet (half-committed), or the new committed state.
//! [`ArtifactStore::open`] runs a recovery pass that moves orphaned
//! temps and half-committed payloads into `<cache-dir>/quarantine/`
//! (counted in [`ArtifactStats::quarantined`], surfaced by
//! `repro cache stats`), so a post-crash directory always reloads as
//! warm-or-cold — never as an error. This extends the stale-version
//! invariant ("old directories read as cold") to torn state.
//!
//! ## Lifecycle
//!
//! Long-lived cache dirs grow without bound, so the store carries the
//! metadata to cap them: every entry records its payload size and a
//! monotonic `last_used` tick (bumped on verified loads and writes,
//! durable across processes — see [`codec::ManifestEntry`]).
//! [`ArtifactStore::gc`] evicts least-recently-used entries until the
//! directory fits a byte budget, but **never** evicts an entry this
//! process touched — the artifacts a live zoo or service was built
//! from stay resident, so a warm restart after GC is still warm.
//! [`ArtifactStore::merge_from`] unions another directory's manifest
//! into this one: keys are content-addressed over every configuration
//! input and artifacts are deterministic, so equal keys hold equal
//! bytes (measurement caches, which legitimately differ in *coverage*,
//! are unioned entry-wise) — merging dirs from different machines is
//! safe by construction.

pub mod codec;

pub use codec::{
    manifest_entry_from_json, manifest_entry_to_json, tuning_from_json, tuning_to_json,
    ManifestEntry, TUNING_CODEC_VERSION,
};

use crate::autosched::{CostModel, TuningResult};
use crate::coordinator::MeasureCache;
use crate::device::DeviceProfile;
use crate::ir::workload::fnv1a;
use crate::transfer::ScheduleStore;
use crate::util::json::{self, Json};
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

/// Version of the on-disk artifact layout. Bump whenever the manifest
/// schema, file naming, key derivation, or any persisted canonical
/// format changes; old directories then read as empty and are rebuilt.
/// v2: manifest entries carry `bytes` + `last_used` (GC metadata).
pub const ARTIFACT_FORMAT_VERSION: u64 = 2;

/// FNV-1a over length-prefixed parts: unambiguous concatenation, same
/// canonical-bytes discipline as the measurement-cache keys.
fn keyed(parts: &[&[u8]]) -> u64 {
    let mut bytes = Vec::new();
    for p in parts {
        bytes.extend_from_slice(&(p.len() as u64).to_le_bytes());
        bytes.extend_from_slice(p);
    }
    fnv1a(&bytes)
}

/// Key of one model's tuning artifact. `keep` is the draft-then-verify
/// keep fraction the tuning ran under; the exact path (`keep = 1.0`)
/// appends nothing, so pre-existing artifacts keep their keys, while a
/// pruned run keys separately and can never be served for an exact one.
/// `model_hash` is the [`CostModel::content_hash`] of the learned prior
/// the tuning was scored by, under the same conditional-append rule:
/// the untrained/static prior (hash 0) appends nothing, so legacy keys
/// stay byte-identical, while a run guided by a fitted prior keys
/// separately and a *retrained* prior misses rather than collides.
pub fn tuning_key(
    model: &str,
    device: &DeviceProfile,
    trials: usize,
    seed: u64,
    keep: f64,
    model_hash: u64,
) -> u64 {
    let trials_b = (trials as u64).to_le_bytes();
    let seed_b = seed.to_le_bytes();
    let version_b = ARTIFACT_FORMAT_VERSION.to_le_bytes();
    let keep_b = keep.to_bits().to_le_bytes();
    let hash_b = model_hash.to_le_bytes();
    let mut parts: Vec<&[u8]> = vec![
        b"tuning",
        model.as_bytes(),
        device.name.as_bytes(),
        &trials_b,
        &seed_b,
        &version_b,
    ];
    if keep.to_bits() != 1.0f64.to_bits() {
        parts.push(&keep_b);
    }
    if model_hash != 0 {
        parts.push(b"costmodel");
        parts.push(&hash_b);
    }
    keyed(&parts)
}

/// Key of zoo-level artifacts (merged schedule store, measurement
/// cache): the sorted model-name set plus the shared configuration.
/// `keep` and `model_hash` follow the same conditional-append rule as
/// [`tuning_key`] (1.0 / 0 append nothing).
pub fn zoo_key(
    model_names: &[String],
    device: &DeviceProfile,
    trials: usize,
    seed: u64,
    keep: f64,
    model_hash: u64,
) -> u64 {
    let mut names: Vec<&str> = model_names.iter().map(|s| s.as_str()).collect();
    names.sort_unstable();
    let joined = names.join("\u{1f}");
    let trials_b = (trials as u64).to_le_bytes();
    let seed_b = seed.to_le_bytes();
    let version_b = ARTIFACT_FORMAT_VERSION.to_le_bytes();
    let keep_b = keep.to_bits().to_le_bytes();
    let hash_b = model_hash.to_le_bytes();
    let mut parts: Vec<&[u8]> = vec![
        b"zoo",
        joined.as_bytes(),
        device.name.as_bytes(),
        &trials_b,
        &seed_b,
        &version_b,
    ];
    if keep.to_bits() != 1.0f64.to_bits() {
        parts.push(&keep_b);
    }
    if model_hash != 0 {
        parts.push(b"costmodel");
        parts.push(&hash_b);
    }
    keyed(&parts)
}

/// Load/save counters — the artifact-level analogue of `CacheStats`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArtifactStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries present in the manifest but rejected on load (checksum
    /// mismatch, unreadable file, undecodable payload).
    pub rejected: u64,
    pub writes: u64,
    /// Files the open-time recovery pass moved into `quarantine/`
    /// (orphaned `.tmp.*` temps + payloads no manifest row references —
    /// the residue of a crash between a payload write and its manifest
    /// commit).
    pub quarantined: u64,
}

/// What one [`ArtifactStore::gc`] pass did.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GcReport {
    /// Manifest entries evicted (files removed).
    pub evicted: usize,
    pub evicted_bytes: u64,
    /// Entries still resident after the pass.
    pub kept: usize,
    pub kept_bytes: u64,
    /// Entries that were over budget but untouchable (live-pinned).
    pub pinned: usize,
    /// Unreferenced `tuning_*`/`store_*`/`mcache_*`/`costmodel_*` files
    /// swept.
    pub orphans_removed: usize,
}

/// What one [`ArtifactStore::merge_from`] pass did.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MergeReport {
    /// Keys absent here and copied over verbatim.
    pub added: usize,
    /// Measurement-cache keys present on both sides whose entry sets
    /// were unioned.
    pub caches_unioned: usize,
    /// Keys present on both sides with identical bytes (no-ops).
    pub identical: usize,
    /// Keys present on both sides with different bytes outside the
    /// mcache kind — kept ours (deterministic artifacts should never
    /// collide; a conflict means a corrupt source).
    pub conflicts: usize,
    /// Source entries skipped without aborting the merge: missing or
    /// checksum-failing source payloads, undecodable source caches, and
    /// entries whose destination copy could not be written.
    pub rejected: usize,
}

/// The on-disk artifact store rooted at a `--cache-dir`.
#[derive(Debug)]
pub struct ArtifactStore {
    root: PathBuf,
    entries: BTreeMap<u64, ManifestEntry>,
    /// Next `last_used` tick; resumes past the largest persisted tick
    /// so LRU order is durable across processes.
    next_tick: u64,
    /// Keys this process loaded or wrote — the live pin set
    /// [`ArtifactStore::gc`] must never evict.
    touched: BTreeSet<u64>,
    /// Ticks changed since the manifest was last written (loads bump
    /// ticks without rewriting; [`ArtifactStore::flush`] settles them).
    dirty: bool,
    pub stats: ArtifactStats,
}

impl ArtifactStore {
    /// Open (or initialize) a store. An unreadable, malformed, or
    /// stale-versioned manifest yields an *empty* store over the same
    /// directory: artifacts are a cache, so the failure mode is
    /// re-computation, never an error the caller must handle twice.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<ArtifactStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let mut store = ArtifactStore {
            root,
            entries: BTreeMap::new(),
            next_tick: 1,
            touched: BTreeSet::new(),
            dirty: false,
            stats: ArtifactStats::default(),
        };
        let manifest = store.manifest_path();
        if let Ok(text) = std::fs::read_to_string(&manifest) {
            if let Ok(j) = json::parse(text.trim_end()) {
                let version = j.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                if version == ARTIFACT_FORMAT_VERSION {
                    if let Some(Json::Obj(map)) = j.get("entries") {
                        for (hex_key, e) in map {
                            let (Ok(key), Some(entry)) =
                                (u64::from_str_radix(hex_key, 16), manifest_entry_from_json(e))
                            else {
                                continue; // skip malformed rows, keep the rest
                            };
                            store.entries.insert(key, entry);
                        }
                    }
                }
                // version mismatch: stale-version invalidation — start
                // empty; the next save rewrites the manifest at the
                // current version and overwrites artifacts in place.
            }
        }
        store.next_tick =
            store.entries.values().map(|e| e.last_used).max().unwrap_or(0) + 1;
        store.recover();
        Ok(store)
    }

    /// Post-crash recovery: move orphaned write-temps (`.tmp.*`) and
    /// half-committed payloads (artifact-shaped files no manifest row
    /// references — written, but the manifest rename never committed
    /// them) into `quarantine/`. Best-effort by design: recovery must
    /// never turn a reopen into an error, so unmovable files are simply
    /// left for the next pass (or `gc`'s orphan sweep).
    fn recover(&mut self) {
        let referenced: BTreeSet<&str> =
            self.entries.values().map(|e| e.file.as_str()).collect();
        let mut pending: Vec<String> = Vec::new();
        if let Ok(dir) = std::fs::read_dir(&self.root) {
            for dirent in dir.flatten() {
                let name = dirent.file_name();
                let Some(name) = name.to_str() else { continue };
                let torn_temp = name.starts_with(".tmp.");
                let half_committed = !torn_temp
                    && (name.starts_with("tuning_")
                        || name.starts_with("store_")
                        || name.starts_with("mcache_")
                        || name.starts_with("costmodel_"))
                    && !referenced.contains(name);
                if torn_temp || half_committed {
                    pending.push(name.to_string());
                }
            }
        }
        if pending.is_empty() {
            return; // clean open: no quarantine dir, no extra syscalls
        }
        let quarantine = self.root.join("quarantine");
        if std::fs::create_dir_all(&quarantine).is_err() {
            return;
        }
        for name in pending {
            if std::fs::rename(self.root.join(&name), quarantine.join(&name)).is_ok() {
                self.stats.quarantined += 1;
            }
        }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total payload bytes the manifest accounts for (what
    /// [`ArtifactStore::gc`] budgets against; the manifest itself and
    /// orphaned files are not counted).
    pub fn total_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.bytes).sum()
    }

    fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.json")
    }

    /// Crash-safe file write: temp (`.tmp.<name>`) + fsync + atomic
    /// rename. A crash (or injected fault) at any point leaves either
    /// the old committed file or the new one — never a torn final file.
    /// Fault sites: `io.write` tears the temp mid-file; `persist.rename`
    /// leaves a fully-synced temp that never commits. Both are exactly
    /// the states [`ArtifactStore::recover`] quarantines.
    fn write_atomic(&self, name: &str, text: &str) -> std::io::Result<()> {
        use std::io::Write as _;
        let tmp = self.root.join(format!(".tmp.{name}"));
        if crate::faults::should_fail("io.write") {
            // Torn write: half the payload lands in the temp, the
            // final file is untouched.
            let _ = std::fs::write(&tmp, &text.as_bytes()[..text.len() / 2]);
            return Err(crate::faults::io_error("io.write"));
        }
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(text.as_bytes())?;
            f.sync_all()?;
        }
        if crate::faults::should_fail("persist.rename") {
            return Err(crate::faults::io_error("persist.rename"));
        }
        std::fs::rename(&tmp, self.root.join(name))?;
        // Durability of the rename itself needs the directory synced;
        // best-effort — a lost rename is indistinguishable from a crash
        // a moment earlier, which recovery already handles.
        #[cfg(unix)]
        if let Ok(dir) = std::fs::File::open(&self.root) {
            let _ = dir.sync_all();
        }
        Ok(())
    }

    fn write_manifest(&mut self) -> anyhow::Result<()> {
        let entries: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(k, e)| (format!("{k:016x}"), manifest_entry_to_json(e)))
            .collect();
        let j = Json::obj(vec![
            ("version", Json::num(ARTIFACT_FORMAT_VERSION as f64)),
            ("entries", Json::Obj(entries)),
        ]);
        let mut text = j.to_compact();
        text.push('\n');
        // The manifest rename is the commit point: payloads written
        // before this either become referenced now or stay orphans a
        // future open quarantines.
        self.write_atomic("manifest.json", &text)?;
        self.dirty = false;
        Ok(())
    }

    /// Persist any pending `last_used` tick updates. Loads bump ticks
    /// in memory only (a warm run should not rewrite the manifest per
    /// artifact read); callers that care about durable LRU order call
    /// this once at the end — the CLI does, after every persist phase.
    pub fn flush(&mut self) -> anyhow::Result<()> {
        if self.dirty {
            self.write_manifest()?;
        }
        Ok(())
    }

    /// Mark `key` used now: bump its LRU tick and pin it for this
    /// process's lifetime.
    fn touch(&mut self, key: u64) {
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_used = self.next_tick;
            self.next_tick += 1;
            self.dirty = true;
        }
        self.touched.insert(key);
    }

    /// Drop a rejected entry (corrupt payload / undecodable artifact)
    /// so the next save repairs it in place.
    fn forget(&mut self, key: u64) {
        if self.entries.remove(&key).is_some() {
            self.dirty = true;
        }
    }

    /// Read one artifact's text, integrity-checked against the
    /// manifest. `None` = miss (absent, wrong kind, checksum mismatch,
    /// or unreadable — the latter two also count as `rejected`). A
    /// verified read refreshes the entry's LRU tick and pins it against
    /// [`ArtifactStore::gc`] for this process's lifetime.
    fn read_checked(&mut self, key: u64, kind: &str) -> Option<String> {
        let (file, checksum) = match self.entries.get(&key) {
            Some(entry) if entry.kind == kind => (entry.file.clone(), entry.checksum),
            _ => {
                self.stats.misses += 1;
                return None;
            }
        };
        let path = self.root.join(&file);
        match std::fs::read_to_string(&path) {
            Ok(text) if fnv1a(text.as_bytes()) == checksum => {
                self.stats.hits += 1;
                self.touch(key);
                Some(text)
            }
            _ => {
                // Corrupt or vanished: drop the entry so it re-saves.
                self.forget(key);
                self.stats.rejected += 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Write one artifact's payload + in-memory manifest entry WITHOUT
    /// rewriting the manifest (the caller batches the rewrite — see
    /// [`ArtifactStore::merge_from`]). The entry is only marked dirty,
    /// so a crash before the next manifest write leaves at worst an
    /// orphaned file, never an unverifiable manifest row.
    fn put_deferred(&mut self, key: u64, kind: &str, text: &str) -> anyhow::Result<()> {
        let ext = if kind == "store" { "jsonl" } else { "json" };
        let file = format!("{kind}_{key:016x}.{ext}");
        self.write_atomic(&file, text)?;
        let last_used = self.next_tick;
        self.next_tick += 1;
        self.entries.insert(
            key,
            ManifestEntry {
                kind: kind.to_string(),
                file,
                checksum: fnv1a(text.as_bytes()),
                bytes: text.len() as u64,
                last_used,
            },
        );
        self.touched.insert(key);
        self.dirty = true;
        self.stats.writes += 1;
        Ok(())
    }

    /// Write one artifact + manifest entry. The payload is written
    /// before the manifest, so a torn write leaves at worst an orphaned
    /// file (never a manifest entry whose checksum cannot verify).
    fn put(&mut self, key: u64, kind: &str, text: &str) -> anyhow::Result<()> {
        self.put_deferred(key, kind, text)?;
        self.write_manifest()
    }

    // ---- typed artifacts -------------------------------------------------

    pub fn load_tuning(&mut self, key: u64) -> Option<TuningResult> {
        let text = self.read_checked(key, "tuning")?;
        match json::parse(text.trim_end()).and_then(|j| codec::tuning_from_json(&j)) {
            Ok(res) => Some(res),
            Err(_) => {
                // Decodes are part of integrity: an undecodable payload
                // (e.g. older codec) is a rejection, not an error.
                self.forget(key);
                self.stats.rejected += 1;
                self.stats.hits -= 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn save_tuning(&mut self, key: u64, res: &TuningResult) -> anyhow::Result<()> {
        let mut text = codec::tuning_to_json(res).to_compact();
        text.push('\n');
        self.put(key, "tuning", &text)
    }

    /// Zoo-level artifacts (merged store, measurement cache) share one
    /// zoo key; fold the kind in so they occupy distinct manifest rows.
    fn kind_scoped(kind: &str, key: u64) -> u64 {
        keyed(&[kind.as_bytes(), &key.to_le_bytes()])
    }

    pub fn load_schedule_store(&mut self, key: u64) -> Option<ScheduleStore> {
        let key = Self::kind_scoped("store", key);
        let text = self.read_checked(key, "store")?;
        match ScheduleStore::from_jsonl(&text, "schedule-store artifact") {
            Ok(store) => Some(store),
            Err(_) => {
                self.forget(key);
                self.stats.rejected += 1;
                self.stats.hits -= 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn save_schedule_store(&mut self, key: u64, store: &ScheduleStore) -> anyhow::Result<()> {
        self.put(Self::kind_scoped("store", key), "store", &store.to_jsonl())
    }

    pub fn load_measure_cache(&mut self, key: u64) -> Option<MeasureCache> {
        let key = Self::kind_scoped("mcache", key);
        let text = self.read_checked(key, "mcache")?;
        match json::parse(text.trim_end()).and_then(|j| MeasureCache::from_json(&j)) {
            Ok(cache) => Some(cache),
            Err(_) => {
                self.forget(key);
                self.stats.rejected += 1;
                self.stats.hits -= 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn save_measure_cache(&mut self, key: u64, cache: &MeasureCache) -> anyhow::Result<()> {
        let mut text = cache.to_json().to_compact();
        text.push('\n');
        self.put(Self::kind_scoped("mcache", key), "mcache", &text)
    }

    /// Load a fitted cost model saved under a zoo's *base* key (the key
    /// computed with `model_hash = 0`) — the model cannot be keyed by
    /// its own hash, so it lives beside the cache it was fitted from.
    /// An untrained model is never persisted, so a successful load is
    /// always a trained prior.
    pub fn load_cost_model(&mut self, key: u64) -> Option<CostModel> {
        let key = Self::kind_scoped("costmodel", key);
        let text = self.read_checked(key, "costmodel")?;
        match json::parse(text.trim_end()).and_then(|j| CostModel::from_json(&j)) {
            Ok(model) if model.is_trained() => Some(model),
            _ => {
                self.forget(key);
                self.stats.rejected += 1;
                self.stats.hits -= 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn save_cost_model(&mut self, key: u64, model: &CostModel) -> anyhow::Result<()> {
        let mut text = model.to_json().to_compact();
        text.push('\n');
        self.put(Self::kind_scoped("costmodel", key), "costmodel", &text)
    }

    // ---- lifecycle -------------------------------------------------------

    /// Shrink the directory to at most `budget_bytes` of artifact
    /// payload: evict least-recently-used entries (manifest row + file)
    /// first, then sweep files no manifest row references (orphans from
    /// torn writes or evictions interrupted before the manifest
    /// rewrite). Entries this process loaded or wrote are **pinned**
    /// and never evicted — the artifacts behind a live zoo/service
    /// survive any budget, so a GC'd cache dir still warm-starts the
    /// exact configuration that was just running (the directory may
    /// then exceed the budget; the report says so via `pinned`).
    pub fn gc(&mut self, budget_bytes: u64) -> anyhow::Result<GcReport> {
        let mut report = GcReport::default();
        let mut victims: Vec<(u64, u64)> = self
            .entries
            .iter()
            .filter(|(k, _)| !self.touched.contains(*k))
            .map(|(&k, e)| (e.last_used, k))
            .collect();
        victims.sort_unstable();
        let mut total = self.total_bytes();
        for (_, key) in victims {
            if total <= budget_bytes {
                break;
            }
            let entry = self.entries.remove(&key).expect("victim key is resident");
            let _ = std::fs::remove_file(self.root.join(&entry.file));
            total -= entry.bytes;
            report.evicted += 1;
            report.evicted_bytes += entry.bytes;
        }
        if total > budget_bytes {
            report.pinned =
                self.entries.iter().filter(|(k, _)| self.touched.contains(*k)).count();
        }
        report.kept = self.entries.len();
        report.kept_bytes = total;

        // Orphan sweep: artifact-shaped files the manifest no longer
        // (or never did) reference are dead weight on the budget.
        let referenced: BTreeSet<&str> =
            self.entries.values().map(|e| e.file.as_str()).collect();
        if let Ok(dir) = std::fs::read_dir(&self.root) {
            for dirent in dir.flatten() {
                let name = dirent.file_name();
                let Some(name) = name.to_str() else { continue };
                let artifact_shaped = name.starts_with("tuning_")
                    || name.starts_with("store_")
                    || name.starts_with("mcache_")
                    || name.starts_with("costmodel_");
                if artifact_shaped
                    && !referenced.contains(name)
                    && std::fs::remove_file(dirent.path()).is_ok()
                {
                    report.orphans_removed += 1;
                }
            }
        }
        self.write_manifest()?;
        Ok(report)
    }

    /// Union another artifact directory into this one (multi-machine
    /// merge). Safe by construction: keys are content-addressed over
    /// every configuration input and artifact bytes are deterministic
    /// in the key, so a key present on both sides names the same bytes
    /// — except measurement caches, which can differ in *coverage* (two
    /// machines warmed different pairs) and are therefore unioned
    /// entry-wise (identical keys in a cache carry identical values, so
    /// the union's *contents* are order-independent). Caveat: a
    /// destination cache persisted with a `capacity` bound keeps that
    /// bound — a union that overflows it evicts LRU entries exactly as
    /// live inserts would, and *which* pairs survive then depends on
    /// merge order. Serving caches are unbounded, so this only affects
    /// deliberately bounded snapshots. Source payloads are checksum-
    /// verified before anything is copied; a stale-versioned source
    /// manifest reads as empty and merges nothing.
    pub fn merge_from(&mut self, other_root: &Path) -> anyhow::Result<MergeReport> {
        // A typo'd source path must be an error, not a silent 0-entry
        // merge — `open` would create the directory and report success.
        anyhow::ensure!(
            other_root.join("manifest.json").is_file(),
            "{} is not an artifact store (no manifest.json)",
            other_root.display()
        );
        let other = ArtifactStore::open(other_root)?;
        let mut report = MergeReport::default();
        for (key, entry) in &other.entries {
            let text = match std::fs::read_to_string(other.root.join(&entry.file)) {
                Ok(text) if fnv1a(text.as_bytes()) == entry.checksum => text,
                _ => {
                    report.rejected += 1;
                    continue;
                }
            };
            match self.entries.get(key) {
                None => {
                    // Payloads land now; ONE manifest rewrite below
                    // covers the whole merge (per-entry rewrites would
                    // make a large merge quadratic in manifest bytes).
                    // A copy that fails to land (full disk, injected
                    // fault) is skip-and-count, never an abort that
                    // strands a half-done merge.
                    if self.put_deferred(*key, &entry.kind, &text).is_ok() {
                        report.added += 1;
                    } else {
                        report.rejected += 1;
                    }
                }
                Some(mine) if mine.checksum == entry.checksum => report.identical += 1,
                Some(mine) if mine.kind == "mcache" && entry.kind == "mcache" => {
                    let mine_checksum = mine.checksum;
                    let mine_text = std::fs::read_to_string(self.root.join(&mine.file))
                        .unwrap_or_default();
                    let mut merged = json::parse(mine_text.trim_end())
                        .and_then(|j| MeasureCache::from_json(&j))
                        .unwrap_or_default();
                    // A checksum-valid but undecodable source cache is
                    // skipped like any other bad source entry — never
                    // abort a half-done merge over one rotten payload.
                    let Ok(theirs) =
                        json::parse(text.trim_end()).and_then(|j| MeasureCache::from_json(&j))
                    else {
                        report.rejected += 1;
                        continue;
                    };
                    for (k, runtime) in theirs.entries_lru() {
                        if merged.peek(k).is_none() {
                            merged.insert(k, runtime);
                        }
                    }
                    let mut merged_text = merged.to_json().to_compact();
                    merged_text.push('\n');
                    if fnv1a(merged_text.as_bytes()) == mine_checksum {
                        // Union added nothing (e.g. a re-merge of the
                        // same peer): skip the rewrite so repeated
                        // merges neither churn disk nor distort the
                        // destination's LRU order.
                        report.identical += 1;
                    } else if self.put_deferred(*key, "mcache", &merged_text).is_ok() {
                        report.caches_unioned += 1;
                    } else {
                        report.rejected += 1;
                    }
                }
                Some(_) => report.conflicts += 1,
            }
        }
        self.write_manifest()?;
        Ok(report)
    }
}

/// What one [`sync_stores`] pass did: the [`MergeReport`] totals summed
/// over every ordered (destination, source) pair, plus the pass shape.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SyncReport {
    /// Store directories visited (each flushed once).
    pub stores: usize,
    /// Ordered pairs merged (`stores * (stores - 1)`).
    pub pairs: usize,
    pub added: usize,
    pub caches_unioned: usize,
    pub identical: usize,
    pub conflicts: usize,
    pub rejected: usize,
}

/// Converge a fleet's artifact directories to their union: every store
/// [`ArtifactStore::merge_from`]s every *other* directory, in index
/// order. Because each destination is flushed before it is read as a
/// source, one pass suffices — store 0 absorbs all peers and becomes
/// the union, and every later store absorbs store 0. This is the
/// `repro fleet sync` primitive: after it, every instance restarted (or
/// `republish --all`ed) over its own `--cache-dir` serves the same
/// artifact set, so epoch-stamped replies agree across the fleet.
///
/// Merging is crash-safe and skip-and-count per entry (see
/// [`ArtifactStore::merge_from`]); a missing or typo'd directory is an
/// error before anything is touched.
pub fn sync_stores(roots: &[PathBuf]) -> anyhow::Result<SyncReport> {
    anyhow::ensure!(roots.len() >= 2, "fleet sync needs at least two cache dirs");
    for root in roots {
        anyhow::ensure!(
            root.join("manifest.json").is_file(),
            "{} is not an artifact store (no manifest.json)",
            root.display()
        );
    }
    let mut report = SyncReport { stores: roots.len(), ..SyncReport::default() };
    for (i, dst_root) in roots.iter().enumerate() {
        let mut dst = ArtifactStore::open(dst_root)?;
        for (j, src_root) in roots.iter().enumerate() {
            if i == j {
                continue;
            }
            let m = dst.merge_from(src_root)?;
            report.pairs += 1;
            report.added += m.added;
            report.caches_unioned += m.caches_unioned;
            report.identical += m.identical;
            report.conflicts += m.conflicts;
            report.rejected += m.rejected;
        }
        dst.flush()?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::{KernelBuilder, ModelGraph};

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tt_artifact_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_tuning() -> (ModelGraph, TuningResult) {
        let mut g = ModelGraph::new("ArtModel");
        g.push(KernelBuilder::dense(256, 256, 256, &[]));
        let prof = DeviceProfile::xeon_e5_2620();
        let opts = TuneOptions {
            trials: 32,
            batch_size: 16,
            population: 32,
            generations: 2,
            ..Default::default()
        };
        let res = tune_model(&g, &prof, &opts);
        (g, res)
    }

    #[test]
    fn keys_separate_every_configuration_axis() {
        let xeon = DeviceProfile::xeon_e5_2620();
        let edge = DeviceProfile::cortex_a72();
        let base = tuning_key("ResNet18", &xeon, 2000, 7, 1.0, 0);
        assert_eq!(base, tuning_key("ResNet18", &xeon, 2000, 7, 1.0, 0), "deterministic");
        assert_ne!(base, tuning_key("ResNet50", &xeon, 2000, 7, 1.0, 0));
        assert_ne!(base, tuning_key("ResNet18", &edge, 2000, 7, 1.0, 0));
        assert_ne!(base, tuning_key("ResNet18", &xeon, 2001, 7, 1.0, 0));
        assert_ne!(base, tuning_key("ResNet18", &xeon, 2000, 8, 1.0, 0));
        // A pruned run keys separately from the exact one, and keep
        // fractions key separately from each other.
        let pruned = tuning_key("ResNet18", &xeon, 2000, 7, 0.25, 0);
        assert_ne!(base, pruned);
        assert_ne!(pruned, tuning_key("ResNet18", &xeon, 2000, 7, 0.5, 0));
        // A learned prior keys separately; distinct fits key apart; and
        // the keep/model ingredients are independent axes.
        let primed = tuning_key("ResNet18", &xeon, 2000, 7, 1.0, 0xDEAD_BEEF);
        assert_ne!(base, primed);
        assert_ne!(primed, tuning_key("ResNet18", &xeon, 2000, 7, 1.0, 0xFEED_FACE));
        assert_ne!(primed, tuning_key("ResNet18", &xeon, 2000, 7, 0.25, 0xDEAD_BEEF));
        // Zoo keys are order-independent in the model set.
        let a = zoo_key(&["B".into(), "A".into()], &xeon, 100, 1, 1.0, 0);
        let b = zoo_key(&["A".into(), "B".into()], &xeon, 100, 1, 1.0, 0);
        assert_eq!(a, b);
        assert_ne!(a, zoo_key(&["A".into()], &xeon, 100, 1, 1.0, 0));
        assert_ne!(a, zoo_key(&["B".into(), "A".into()], &xeon, 100, 1, 0.25, 0));
        assert_ne!(a, zoo_key(&["B".into(), "A".into()], &xeon, 100, 1, 1.0, 0xDEAD_BEEF));
    }

    #[test]
    fn tuning_roundtrips_through_reopened_store() {
        let root = tmp_root("roundtrip");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let key = tuning_key(&g.name, &xeon, 32, 0xA45, 1.0, 0);

        let mut store = ArtifactStore::open(&root).unwrap();
        assert!(store.load_tuning(key).is_none());
        assert_eq!(store.stats.misses, 1);
        store.save_tuning(key, &res).unwrap();

        // "New process": reopen from disk.
        let mut store2 = ArtifactStore::open(&root).unwrap();
        assert_eq!(store2.len(), 1);
        let back = store2.load_tuning(key).unwrap();
        assert_eq!(store2.stats.hits, 1);
        assert_eq!(back.search_time_s.to_bits(), res.search_time_s.to_bits());
        assert_eq!(back.best[&0].schedule, res.best[&0].schedule);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_artifact_is_rejected_and_resaveable() {
        let root = tmp_root("corrupt");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let key = tuning_key(&g.name, &xeon, 32, 0xA45, 1.0, 0);
        let mut store = ArtifactStore::open(&root).unwrap();
        store.save_tuning(key, &res).unwrap();

        // Flip bytes in the payload: checksum must catch it.
        let file = root.join(format!("tuning_{key:016x}.json"));
        std::fs::write(&file, "{\"definitely\":\"not it\"}\n").unwrap();
        let mut store2 = ArtifactStore::open(&root).unwrap();
        assert!(store2.load_tuning(key).is_none());
        assert_eq!(store2.stats.rejected, 1);
        // Re-save repairs in place.
        store2.save_tuning(key, &res).unwrap();
        assert!(store2.load_tuning(key).is_some());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn stale_manifest_version_reads_as_empty() {
        let root = tmp_root("stale");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let key = tuning_key(&g.name, &xeon, 32, 0xA45, 1.0, 0);
        let mut store = ArtifactStore::open(&root).unwrap();
        store.save_tuning(key, &res).unwrap();

        // Rewrite the manifest claiming a future format version.
        let manifest = std::fs::read_to_string(root.join("manifest.json")).unwrap();
        std::fs::write(root.join("manifest.json"), manifest.replace("\"version\":2", "\"version\":999"))
            .unwrap();
        let store2 = ArtifactStore::open(&root).unwrap();
        assert!(store2.is_empty(), "stale version must invalidate all entries");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn garbage_manifest_reads_as_empty() {
        let root = tmp_root("garbage");
        std::fs::create_dir_all(&root).unwrap();
        std::fs::write(root.join("manifest.json"), "not json at all").unwrap();
        let store = ArtifactStore::open(&root).unwrap();
        assert!(store.is_empty());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn schedule_store_and_measure_cache_artifacts_roundtrip() {
        let root = tmp_root("zoo_level");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let mut sched_store = ScheduleStore::new();
        sched_store.add_tuning(&g, &res);
        let mut mcache = MeasureCache::new();
        mcache.insert(42, Some(1e-3));
        mcache.insert(43, None);

        let zk = zoo_key(&[g.name.clone()], &xeon, 32, 0xA45, 1.0, 0);
        let mut store = ArtifactStore::open(&root).unwrap();
        // Both zoo-level artifacts live under the same zoo key (the
        // store derives kind-scoped manifest rows internally).
        store.save_schedule_store(zk, &sched_store).unwrap();
        store.save_measure_cache(zk, &mcache).unwrap();

        let mut store2 = ArtifactStore::open(&root).unwrap();
        let back = store2.load_schedule_store(zk).unwrap();
        assert_eq!(back.records.len(), sched_store.records.len());
        for (a, b) in back.records.iter().zip(&sched_store.records) {
            assert_eq!(a.schedule, b.schedule);
            assert_eq!(a.source_cost_s.to_bits(), b.source_cost_s.to_bits());
        }
        let mc = store2.load_measure_cache(zk).unwrap();
        assert_eq!(mc.peek(42), Some(Some(1e-3)));
        assert_eq!(mc.peek(43), Some(None));
        // Kind confusion is a miss, not a wrong payload.
        assert!(store2.load_tuning(zk).is_none());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn reopen_quarantines_torn_temps_and_half_committed_payloads() {
        let root = tmp_root("quarantine");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let key = tuning_key(&g.name, &xeon, 32, 0xA45, 1.0, 0);
        let mut store = ArtifactStore::open(&root).unwrap();
        store.save_tuning(key, &res).unwrap();

        // Simulate a crash's residue by hand: a torn write-temp and a
        // payload the manifest never committed.
        std::fs::write(root.join(".tmp.manifest.json"), "{\"version\":2,\"entr").unwrap();
        std::fs::write(root.join("tuning_00000000deadbeef.json"), "{}\n").unwrap();

        let mut store2 = ArtifactStore::open(&root).unwrap();
        assert_eq!(store2.stats.quarantined, 2, "both crash residues quarantined");
        assert!(root.join("quarantine/.tmp.manifest.json").is_file());
        assert!(root.join("quarantine/tuning_00000000deadbeef.json").is_file());
        assert!(!root.join(".tmp.manifest.json").exists());
        // The committed entry is untouched: the reopen is warm.
        assert!(store2.load_tuning(key).is_some(), "committed state survives recovery");

        // A clean directory quarantines nothing and creates no dir.
        let fresh = tmp_root("quarantine_clean");
        let clean = ArtifactStore::open(&fresh).unwrap();
        assert_eq!(clean.stats.quarantined, 0);
        assert!(!fresh.join("quarantine").exists());
        std::fs::remove_dir_all(&root).ok();
        std::fs::remove_dir_all(&fresh).ok();
    }

    #[test]
    fn merge_skips_missing_source_payload_without_aborting() {
        let src = tmp_root("merge_missing_src");
        let dst = tmp_root("merge_missing_dst");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let k1 = tuning_key(&g.name, &xeon, 32, 0xA45, 1.0, 0);
        let k2 = tuning_key(&g.name, &xeon, 32, 0xA46, 1.0, 0);
        let mut source = ArtifactStore::open(&src).unwrap();
        source.save_tuning(k1, &res).unwrap();
        source.save_tuning(k2, &res).unwrap();
        // One committed payload vanishes (partial copy, disk loss). The
        // open-time recovery pass does not touch referenced entries, so
        // the manifest still names it.
        std::fs::remove_file(src.join(format!("tuning_{k1:016x}.json"))).unwrap();

        let mut dest = ArtifactStore::open(&dst).unwrap();
        let report = dest.merge_from(&src).unwrap();
        assert_eq!(report.rejected, 1, "missing payload is skip-and-count");
        assert_eq!(report.added, 1, "the healthy sibling still merges");
        assert!(dest.load_tuning(k2).is_some());
        assert!(dest.load_tuning(k1).is_none());
        std::fs::remove_dir_all(&src).ok();
        std::fs::remove_dir_all(&dst).ok();
    }
}
