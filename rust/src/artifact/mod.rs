//! Persistent, content-addressed artifact store for tuned state.
//!
//! PR 1's [`MeasureCache`] proved content-addressed reuse inside one
//! process; this module extends the same discipline across processes:
//! everything expensive a `repro` run produces — per-model
//! [`TuningResult`]s, the merged [`ScheduleStore`], and the measurement
//! cache — becomes a durable, shareable artifact under a `--cache-dir`.
//! A warm run rebuilds a full zoo with **zero tuning trials and zero
//! charged device-seconds** while every reported (standalone) number
//! stays bit-identical to the cold run at the same seed.
//!
//! ## Addressing
//!
//! Artifacts are keyed by FNV-1a over length-prefixed canonical byte
//! strings (the same discipline as `coordinator/cache.rs`): artifact
//! kind, model name(s), device-profile name, trial budget, seed, and
//! the store-format version. Any input that could change the artifact's
//! bytes is part of the key, so a stale artifact can never be served
//! for a different configuration — it simply misses.
//!
//! ## Layout and integrity
//!
//! ```text
//! <cache-dir>/
//!   manifest.json            # version + {key -> kind, file, checksum}
//!   tuning_<key>.json        # one TuningResult (codec.rs)
//!   store_<key>.jsonl        # merged ScheduleStore (canonical JSONL)
//!   mcache_<key>.json        # MeasureCache snapshot (cache.rs format)
//! ```
//!
//! Loads are integrity-checked: the manifest records the FNV-1a
//! checksum of each artifact's bytes, and a mismatch (truncated file,
//! hand edit, torn write) rejects the entry — the caller re-tunes and
//! overwrites. A manifest whose `version` differs from
//! [`ARTIFACT_FORMAT_VERSION`] is discarded wholesale (stale-version
//! invalidation): version bumps accompany any change to the canonical
//! serialization formats the checksums and keys are built from.

pub mod codec;

pub use codec::{tuning_from_json, tuning_to_json, TUNING_CODEC_VERSION};

use crate::autosched::TuningResult;
use crate::coordinator::MeasureCache;
use crate::device::DeviceProfile;
use crate::ir::workload::fnv1a;
use crate::transfer::ScheduleStore;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Version of the on-disk artifact layout. Bump whenever the manifest
/// schema, file naming, key derivation, or any persisted canonical
/// format changes; old directories then read as empty and are rebuilt.
pub const ARTIFACT_FORMAT_VERSION: u64 = 1;

/// FNV-1a over length-prefixed parts: unambiguous concatenation, same
/// canonical-bytes discipline as the measurement-cache keys.
fn keyed(parts: &[&[u8]]) -> u64 {
    let mut bytes = Vec::new();
    for p in parts {
        bytes.extend_from_slice(&(p.len() as u64).to_le_bytes());
        bytes.extend_from_slice(p);
    }
    fnv1a(&bytes)
}

/// Key of one model's tuning artifact.
pub fn tuning_key(model: &str, device: &DeviceProfile, trials: usize, seed: u64) -> u64 {
    keyed(&[
        b"tuning",
        model.as_bytes(),
        device.name.as_bytes(),
        &(trials as u64).to_le_bytes(),
        &seed.to_le_bytes(),
        &ARTIFACT_FORMAT_VERSION.to_le_bytes(),
    ])
}

/// Key of zoo-level artifacts (merged schedule store, measurement
/// cache): the sorted model-name set plus the shared configuration.
pub fn zoo_key(model_names: &[String], device: &DeviceProfile, trials: usize, seed: u64) -> u64 {
    let mut names: Vec<&str> = model_names.iter().map(|s| s.as_str()).collect();
    names.sort_unstable();
    let joined = names.join("\u{1f}");
    keyed(&[
        b"zoo",
        joined.as_bytes(),
        device.name.as_bytes(),
        &(trials as u64).to_le_bytes(),
        &seed.to_le_bytes(),
        &ARTIFACT_FORMAT_VERSION.to_le_bytes(),
    ])
}

/// Load/save counters — the artifact-level analogue of `CacheStats`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArtifactStats {
    pub hits: u64,
    pub misses: u64,
    /// Entries present in the manifest but rejected on load (checksum
    /// mismatch, unreadable file, undecodable payload).
    pub rejected: u64,
    pub writes: u64,
}

#[derive(Clone, Debug)]
struct ManifestEntry {
    kind: String,
    file: String,
    checksum: u64,
}

/// The on-disk artifact store rooted at a `--cache-dir`.
#[derive(Debug)]
pub struct ArtifactStore {
    root: PathBuf,
    entries: BTreeMap<u64, ManifestEntry>,
    pub stats: ArtifactStats,
}

impl ArtifactStore {
    /// Open (or initialize) a store. An unreadable, malformed, or
    /// stale-versioned manifest yields an *empty* store over the same
    /// directory: artifacts are a cache, so the failure mode is
    /// re-computation, never an error the caller must handle twice.
    pub fn open(root: impl Into<PathBuf>) -> anyhow::Result<ArtifactStore> {
        let root = root.into();
        std::fs::create_dir_all(&root)?;
        let mut store = ArtifactStore { root, entries: BTreeMap::new(), stats: ArtifactStats::default() };
        let manifest = store.manifest_path();
        if let Ok(text) = std::fs::read_to_string(&manifest) {
            if let Ok(j) = json::parse(text.trim_end()) {
                let version = j.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
                if version == ARTIFACT_FORMAT_VERSION {
                    if let Some(Json::Obj(map)) = j.get("entries") {
                        for (hex_key, e) in map {
                            let (Ok(key), Some(kind), Some(file), Some(checksum)) = (
                                u64::from_str_radix(hex_key, 16),
                                e.get("kind").and_then(|v| v.as_str()),
                                e.get("file").and_then(|v| v.as_str()),
                                e.get("checksum")
                                    .and_then(|v| v.as_str())
                                    .and_then(|s| u64::from_str_radix(s, 16).ok()),
                            ) else {
                                continue; // skip malformed rows, keep the rest
                            };
                            store.entries.insert(
                                key,
                                ManifestEntry {
                                    kind: kind.to_string(),
                                    file: file.to_string(),
                                    checksum,
                                },
                            );
                        }
                    }
                }
                // version mismatch: stale-version invalidation — start
                // empty; the next save rewrites the manifest at the
                // current version and overwrites artifacts in place.
            }
        }
        Ok(store)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.json")
    }

    fn write_manifest(&self) -> anyhow::Result<()> {
        let entries: BTreeMap<String, Json> = self
            .entries
            .iter()
            .map(|(k, e)| {
                (
                    format!("{k:016x}"),
                    Json::obj(vec![
                        ("kind", Json::str(&e.kind)),
                        ("file", Json::str(&e.file)),
                        ("checksum", Json::str(format!("{:016x}", e.checksum))),
                    ]),
                )
            })
            .collect();
        let j = Json::obj(vec![
            ("version", Json::num(ARTIFACT_FORMAT_VERSION as f64)),
            ("entries", Json::Obj(entries)),
        ]);
        let mut text = j.to_compact();
        text.push('\n');
        std::fs::write(self.manifest_path(), text)?;
        Ok(())
    }

    /// Read one artifact's text, integrity-checked against the
    /// manifest. `None` = miss (absent, wrong kind, checksum mismatch,
    /// or unreadable — the latter two also count as `rejected`).
    fn read_checked(&mut self, key: u64, kind: &str) -> Option<String> {
        let (file, checksum) = match self.entries.get(&key) {
            Some(entry) if entry.kind == kind => (entry.file.clone(), entry.checksum),
            _ => {
                self.stats.misses += 1;
                return None;
            }
        };
        let path = self.root.join(&file);
        match std::fs::read_to_string(&path) {
            Ok(text) if fnv1a(text.as_bytes()) == checksum => {
                self.stats.hits += 1;
                Some(text)
            }
            _ => {
                // Corrupt or vanished: drop the entry so it re-saves.
                self.entries.remove(&key);
                self.stats.rejected += 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Write one artifact + manifest entry. The payload is written
    /// before the manifest, so a torn write leaves at worst an orphaned
    /// file (never a manifest entry whose checksum cannot verify).
    fn put(&mut self, key: u64, kind: &str, text: &str) -> anyhow::Result<()> {
        let ext = if kind == "store" { "jsonl" } else { "json" };
        let file = format!("{kind}_{key:016x}.{ext}");
        std::fs::write(self.root.join(&file), text)?;
        self.entries.insert(
            key,
            ManifestEntry { kind: kind.to_string(), file, checksum: fnv1a(text.as_bytes()) },
        );
        self.write_manifest()?;
        self.stats.writes += 1;
        Ok(())
    }

    // ---- typed artifacts -------------------------------------------------

    pub fn load_tuning(&mut self, key: u64) -> Option<TuningResult> {
        let text = self.read_checked(key, "tuning")?;
        match json::parse(text.trim_end()).and_then(|j| codec::tuning_from_json(&j)) {
            Ok(res) => Some(res),
            Err(_) => {
                // Decodes are part of integrity: an undecodable payload
                // (e.g. older codec) is a rejection, not an error.
                self.entries.remove(&key);
                self.stats.rejected += 1;
                self.stats.hits -= 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn save_tuning(&mut self, key: u64, res: &TuningResult) -> anyhow::Result<()> {
        let mut text = codec::tuning_to_json(res).to_compact();
        text.push('\n');
        self.put(key, "tuning", &text)
    }

    /// Zoo-level artifacts (merged store, measurement cache) share one
    /// zoo key; fold the kind in so they occupy distinct manifest rows.
    fn kind_scoped(kind: &str, key: u64) -> u64 {
        keyed(&[kind.as_bytes(), &key.to_le_bytes()])
    }

    pub fn load_schedule_store(&mut self, key: u64) -> Option<ScheduleStore> {
        let key = Self::kind_scoped("store", key);
        let text = self.read_checked(key, "store")?;
        match ScheduleStore::from_jsonl(&text, "schedule-store artifact") {
            Ok(store) => Some(store),
            Err(_) => {
                self.entries.remove(&key);
                self.stats.rejected += 1;
                self.stats.hits -= 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn save_schedule_store(&mut self, key: u64, store: &ScheduleStore) -> anyhow::Result<()> {
        self.put(Self::kind_scoped("store", key), "store", &store.to_jsonl())
    }

    pub fn load_measure_cache(&mut self, key: u64) -> Option<MeasureCache> {
        let key = Self::kind_scoped("mcache", key);
        let text = self.read_checked(key, "mcache")?;
        match json::parse(text.trim_end()).and_then(|j| MeasureCache::from_json(&j)) {
            Ok(cache) => Some(cache),
            Err(_) => {
                self.entries.remove(&key);
                self.stats.rejected += 1;
                self.stats.hits -= 1;
                self.stats.misses += 1;
                None
            }
        }
    }

    pub fn save_measure_cache(&mut self, key: u64, cache: &MeasureCache) -> anyhow::Result<()> {
        let mut text = cache.to_json().to_compact();
        text.push('\n');
        self.put(Self::kind_scoped("mcache", key), "mcache", &text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::{KernelBuilder, ModelGraph};

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("tt_artifact_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_tuning() -> (ModelGraph, TuningResult) {
        let mut g = ModelGraph::new("ArtModel");
        g.push(KernelBuilder::dense(256, 256, 256, &[]));
        let prof = DeviceProfile::xeon_e5_2620();
        let opts = TuneOptions {
            trials: 32,
            batch_size: 16,
            population: 32,
            generations: 2,
            ..Default::default()
        };
        let res = tune_model(&g, &prof, &opts);
        (g, res)
    }

    #[test]
    fn keys_separate_every_configuration_axis() {
        let xeon = DeviceProfile::xeon_e5_2620();
        let edge = DeviceProfile::cortex_a72();
        let base = tuning_key("ResNet18", &xeon, 2000, 7);
        assert_eq!(base, tuning_key("ResNet18", &xeon, 2000, 7), "deterministic");
        assert_ne!(base, tuning_key("ResNet50", &xeon, 2000, 7));
        assert_ne!(base, tuning_key("ResNet18", &edge, 2000, 7));
        assert_ne!(base, tuning_key("ResNet18", &xeon, 2001, 7));
        assert_ne!(base, tuning_key("ResNet18", &xeon, 2000, 8));
        // Zoo keys are order-independent in the model set.
        let a = zoo_key(&["B".into(), "A".into()], &xeon, 100, 1);
        let b = zoo_key(&["A".into(), "B".into()], &xeon, 100, 1);
        assert_eq!(a, b);
        assert_ne!(a, zoo_key(&["A".into()], &xeon, 100, 1));
    }

    #[test]
    fn tuning_roundtrips_through_reopened_store() {
        let root = tmp_root("roundtrip");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let key = tuning_key(&g.name, &xeon, 32, 0xA45);

        let mut store = ArtifactStore::open(&root).unwrap();
        assert!(store.load_tuning(key).is_none());
        assert_eq!(store.stats.misses, 1);
        store.save_tuning(key, &res).unwrap();

        // "New process": reopen from disk.
        let mut store2 = ArtifactStore::open(&root).unwrap();
        assert_eq!(store2.len(), 1);
        let back = store2.load_tuning(key).unwrap();
        assert_eq!(store2.stats.hits, 1);
        assert_eq!(back.search_time_s.to_bits(), res.search_time_s.to_bits());
        assert_eq!(back.best[&0].schedule, res.best[&0].schedule);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_artifact_is_rejected_and_resaveable() {
        let root = tmp_root("corrupt");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let key = tuning_key(&g.name, &xeon, 32, 0xA45);
        let mut store = ArtifactStore::open(&root).unwrap();
        store.save_tuning(key, &res).unwrap();

        // Flip bytes in the payload: checksum must catch it.
        let file = root.join(format!("tuning_{key:016x}.json"));
        std::fs::write(&file, "{\"definitely\":\"not it\"}\n").unwrap();
        let mut store2 = ArtifactStore::open(&root).unwrap();
        assert!(store2.load_tuning(key).is_none());
        assert_eq!(store2.stats.rejected, 1);
        // Re-save repairs in place.
        store2.save_tuning(key, &res).unwrap();
        assert!(store2.load_tuning(key).is_some());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn stale_manifest_version_reads_as_empty() {
        let root = tmp_root("stale");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let key = tuning_key(&g.name, &xeon, 32, 0xA45);
        let mut store = ArtifactStore::open(&root).unwrap();
        store.save_tuning(key, &res).unwrap();

        // Rewrite the manifest claiming a future format version.
        let manifest = std::fs::read_to_string(root.join("manifest.json")).unwrap();
        std::fs::write(root.join("manifest.json"), manifest.replace("\"version\":1", "\"version\":999"))
            .unwrap();
        let store2 = ArtifactStore::open(&root).unwrap();
        assert!(store2.is_empty(), "stale version must invalidate all entries");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn garbage_manifest_reads_as_empty() {
        let root = tmp_root("garbage");
        std::fs::create_dir_all(&root).unwrap();
        std::fs::write(root.join("manifest.json"), "not json at all").unwrap();
        let store = ArtifactStore::open(&root).unwrap();
        assert!(store.is_empty());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn schedule_store_and_measure_cache_artifacts_roundtrip() {
        let root = tmp_root("zoo_level");
        let xeon = DeviceProfile::xeon_e5_2620();
        let (g, res) = small_tuning();
        let mut sched_store = ScheduleStore::new();
        sched_store.add_tuning(&g, &res);
        let mut mcache = MeasureCache::new();
        mcache.insert(42, Some(1e-3));
        mcache.insert(43, None);

        let zk = zoo_key(&[g.name.clone()], &xeon, 32, 0xA45);
        let mut store = ArtifactStore::open(&root).unwrap();
        // Both zoo-level artifacts live under the same zoo key (the
        // store derives kind-scoped manifest rows internally).
        store.save_schedule_store(zk, &sched_store).unwrap();
        store.save_measure_cache(zk, &mcache).unwrap();

        let mut store2 = ArtifactStore::open(&root).unwrap();
        let back = store2.load_schedule_store(zk).unwrap();
        assert_eq!(back.records.len(), sched_store.records.len());
        for (a, b) in back.records.iter().zip(&sched_store.records) {
            assert_eq!(a.schedule, b.schedule);
            assert_eq!(a.source_cost_s.to_bits(), b.source_cost_s.to_bits());
        }
        let mc = store2.load_measure_cache(zk).unwrap();
        assert_eq!(mc.peek(42), Some(Some(1e-3)));
        assert_eq!(mc.peek(43), Some(None));
        // Kind confusion is a miss, not a wrong payload.
        assert!(store2.load_tuning(zk).is_none());
        std::fs::remove_dir_all(&root).ok();
    }
}
