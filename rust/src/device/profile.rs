//! Device profiles: the analytic stand-ins for the paper's two testbeds.
//!
//! The paper measures on an Intel Xeon E5-2620 (server, §5.1) and a
//! Raspberry Pi 4's Arm Cortex-A72 (edge, §5.3). We cannot measure on
//! that hardware here, so each platform is described by the parameters
//! the cost simulator needs: core count, frequency, SIMD width, cache
//! hierarchy, bandwidths, and — critically for the paper's search-time
//! results — the *per-measurement* costs of auto-tuning (candidate
//! compile time, run repeats, RPC overhead for remote edge tuning).

/// One level of the cache hierarchy.
#[derive(Clone, Debug)]
pub struct CacheLevel {
    pub name: &'static str,
    pub bytes: u64,
    /// Sustained load bandwidth *from* this level, GB/s. Per-core unless
    /// `shared`.
    pub gbps: f64,
    pub shared: bool,
}

#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub cores: u64,
    pub freq_ghz: f64,
    /// SIMD register width in bits (AVX = 256, NEON = 128).
    pub simd_bits: u64,
    /// FMA/vector-ALU issue width per cycle per core.
    pub fma_per_cycle: f64,
    /// Cache levels, innermost first; DRAM is implicit after the last.
    pub caches: Vec<CacheLevel>,
    /// DRAM bandwidth, GB/s, shared across cores.
    pub dram_gbps: f64,
    /// Cache line size in bytes.
    pub line_bytes: u64,
    /// Cycles charged per dynamic loop back-edge.
    pub branch_cost_cycles: f64,
    /// Fixed cost per kernel invocation (dispatch, argument setup).
    pub launch_overhead_s: f64,
    /// Fork/join cost when a kernel uses the thread pool.
    pub parallel_overhead_s: f64,
    /// Unrolled-body instruction budget before i-cache pressure penalty.
    pub icache_unroll_budget: f64,
    // ---- tuning-time accounting (search-time ledger) -------------------
    /// Per-candidate cost of codegen + compile + load during tuning.
    pub measure_overhead_s: f64,
    /// Timed repeats per candidate measurement.
    pub measure_repeats: u64,
    /// Extra per-candidate cost when measuring over RPC (edge tuning;
    /// zero for local tuning).
    pub rpc_overhead_s: f64,
    /// Lognormal sigma of measurement noise.
    pub noise_sigma: f64,
}

impl DeviceProfile {
    pub fn simd_lanes_f32(&self) -> u64 {
        self.simd_bits / 32
    }

    /// Peak f32 FLOP/s of one core (FMA counts 2).
    pub fn peak_flops_core(&self) -> f64 {
        self.freq_ghz * 1e9 * self.fma_per_cycle * self.simd_lanes_f32() as f64 * 2.0
    }

    /// Peak f32 FLOP/s of the whole chip.
    pub fn peak_flops(&self) -> f64 {
        self.peak_flops_core() * self.cores as f64
    }

    /// The paper's server platform: 8-core Intel Xeon E5-2620 @ 2.1 GHz,
    /// AVX (8 f32 lanes), 32 KiB L1d / 256 KiB L2 per core, 20 MiB shared
    /// L3, ~42 GB/s DDR3.
    pub fn xeon_e5_2620() -> Self {
        DeviceProfile {
            name: "xeon-e5-2620",
            cores: 8,
            freq_ghz: 2.1,
            simd_bits: 256,
            fma_per_cycle: 1.0,
            caches: vec![
                CacheLevel { name: "L1", bytes: 32 << 10, gbps: 100.0, shared: false },
                CacheLevel { name: "L2", bytes: 256 << 10, gbps: 45.0, shared: false },
                CacheLevel { name: "L3", bytes: 20 << 20, gbps: 120.0, shared: true },
            ],
            dram_gbps: 42.0,
            line_bytes: 64,
            branch_cost_cycles: 1.0,
            launch_overhead_s: 2e-6,
            parallel_overhead_s: 8e-6,
            icache_unroll_budget: 4096.0,
            measure_overhead_s: 0.9,
            measure_repeats: 3,
            rpc_overhead_s: 0.0,
            noise_sigma: 0.04,
        }
    }

    /// The paper's edge platform: Raspberry Pi 4 (Arm Cortex-A72, 4 cores
    /// @ 1.5 GHz, NEON 128-bit, 32 KiB L1d, 1 MiB shared L2, LPDDR4).
    /// Tuning happens over RPC from a host (paper §5.3), so every
    /// measurement carries RPC + upload overhead; kernels also simply run
    /// slower, which multiplies the measured-seconds part of search time.
    /// Both effects exacerbate Ansor's time-to-match (10.8x vs 6.5x).
    pub fn cortex_a72() -> Self {
        DeviceProfile {
            name: "cortex-a72",
            cores: 4,
            freq_ghz: 1.5,
            simd_bits: 128,
            fma_per_cycle: 1.0,
            caches: vec![
                CacheLevel { name: "L1", bytes: 32 << 10, gbps: 24.0, shared: false },
                CacheLevel { name: "L2", bytes: 1 << 20, gbps: 16.0, shared: true },
            ],
            dram_gbps: 6.0,
            line_bytes: 64,
            branch_cost_cycles: 1.4,
            launch_overhead_s: 6e-6,
            parallel_overhead_s: 20e-6,
            icache_unroll_budget: 2048.0,
            measure_overhead_s: 1.1,
            measure_repeats: 3,
            rpc_overhead_s: 1.4,
            noise_sigma: 0.05,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "xeon-e5-2620" | "server" | "x86" => Some(Self::xeon_e5_2620()),
            "cortex-a72" | "edge" | "arm" => Some(Self::cortex_a72()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xeon_peak_is_sandy_bridge_scale() {
        let p = DeviceProfile::xeon_e5_2620();
        // 2.1 GHz * 8 lanes * 2 flops = 33.6 GF/core, ~269 GF chip.
        assert!((p.peak_flops_core() - 33.6e9).abs() < 1e6);
        assert!((p.peak_flops() - 268.8e9).abs() < 1e7);
    }

    #[test]
    fn edge_is_much_weaker() {
        let xeon = DeviceProfile::xeon_e5_2620();
        let pi = DeviceProfile::cortex_a72();
        assert!(xeon.peak_flops() / pi.peak_flops() > 5.0);
        assert!(pi.rpc_overhead_s > 0.0);
    }

    #[test]
    fn lookup_aliases() {
        assert_eq!(DeviceProfile::by_name("server").unwrap().name, "xeon-e5-2620");
        assert_eq!(DeviceProfile::by_name("edge").unwrap().name, "cortex-a72");
        assert!(DeviceProfile::by_name("gpu").is_none());
    }
}
