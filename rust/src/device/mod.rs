//! Hardware cost simulation: the measurement substrate.
//!
//! The paper measures real kernels on a Xeon E5-2620 and a Raspberry
//! Pi 4. This repo replaces those testbeds with an analytic CPU model
//! (see DESIGN.md §1 for why the substitution preserves the paper's
//! *relative* claims), and grounds the model against real execution of
//! the AOT-compiled Pallas GEMM artifacts through `crate::runtime`.

pub mod interkernel;
pub mod modeltime;
pub mod profile;
pub mod simulator;

pub use interkernel::{boundary_delta, layout_affinity};
pub use modeltime::{model_time, untuned_kernel_times, untuned_model_time};
pub use profile::{CacheLevel, DeviceProfile};
pub use simulator::{measure, measure_from_sim, simulate, simulate_with, SimBreakdown, SimScratch};
