//! Inter-kernel cache interactions (paper §5.5, Fig 8 mechanism).
//!
//! Standalone kernel timing assumes cold inputs: every input byte comes
//! from DRAM. In a full model, a kernel's input is the previous kernel's
//! output, and some of it is still cache-resident — *how much benefit
//! that yields depends on both schedules*: the producer's write order
//! dictates cache placement, the consumer's first-touch order determines
//! whether the resident lines are hit before eviction ("The data access
//! patterns of the first kernel will dictate the cache placement of the
//! output data, which will impact the read times ... in the second
//! kernel").
//!
//! Because the transfer-tuning engine selects schedules by *standalone*
//! time (as the paper's implementation does, and as Ansor itself does),
//! it cannot see this term — which is exactly why the mixed-pool
//! experiment (Fig 8) can pick standalone-faster schedules that are
//! *slower* end-to-end.

use super::profile::DeviceProfile;
use crate::ir::Kernel;
use crate::sched::Schedule;

/// Layout-affinity score in (0, 1]: how well the consumer's first-touch
/// order matches the producer's write order. Derived from the innermost
/// tile granularities of the two schedules — equal streaming granularity
/// scores 1.0, badly mismatched granularity approaches 0.
pub fn layout_affinity(producer: &Schedule, consumer: &Schedule) -> f64 {
    // Producer streams its output in chunks of its innermost spatial tile
    // (the contiguous-dim write granularity).
    let p_tile = producer
        .spatial
        .last()
        .map(|t| t.inner_product())
        .unwrap_or(1)
        .max(1) as f64;
    // Consumer first-touch granularity along the contiguous input dim:
    // the innermost spatial tile (it walks the input window with the
    // output tile) times the innermost reduction tile (the reduction
    // stride through the input). Both vary widely across auto-schedules,
    // which is what makes the interaction schedule-*choice* dependent.
    let c_spatial = consumer.spatial.last().map(|t| t.inner_product()).unwrap_or(1);
    let c_red = consumer.reduction.last().map(|t| t.inner_product()).unwrap_or(1);
    let c_tile = (c_spatial * c_red).max(1) as f64;
    let ratio = p_tile.min(c_tile) / p_tile.max(c_tile);
    // Even a perfect granularity mismatch retains some affinity (hardware
    // prefetchers), and identical granularity is not a perfect guarantee.
    0.15 + 0.85 * ratio.sqrt()
}

/// Signed boundary adjustment in seconds relative to the cold-input
/// standalone estimate. Negative = the consumer runs *faster* than its
/// standalone time (good layout affinity, producer output still cache
/// resident); positive = *slower* (the producer's write pattern defeats
/// the consumer's prefetch/access pattern — partially-resident data in
/// the wrong layout costs more than a clean cold stream).
///
/// The magnitude scales with the consumer's *memory-bound share* of its
/// standalone time (`consumer_mem_s`): a compute-bound kernel barely
/// notices its input layout, a bandwidth-bound one lives or dies by it.
pub fn boundary_delta(
    producer_kernel: &Kernel,
    producer_sched: &Schedule,
    consumer_sched: &Schedule,
    consumer_mem_s: f64,
    consumer_total_s: f64,
    profile: &DeviceProfile,
) -> f64 {
    let out_bytes = producer_kernel
        .nest
        .output_buffer()
        .total_bytes(&producer_kernel.nest.axes) as f64;
    // Fraction of the output still resident in the last-level cache when
    // the consumer starts (other tensors competed for it: use half the
    // LLC as the effective budget).
    let llc = profile.caches.last().map(|c| c.bytes as f64).unwrap_or(0.0) * 0.5;
    let resident = (llc / out_bytes).min(1.0);
    let affinity = layout_affinity(producer_sched, consumer_sched);
    // Matched layouts (affinity -> 1) convert part of the consumer's
    // memory time into cache hits; mismatched layouts (affinity -> 0.15)
    // inflate it by fighting the producer's placement. Centered near the
    // expected affinity so the term perturbs rather than dominates.
    const AFF_REF: f64 = 0.6;
    const STRENGTH: f64 = 0.45;
    let mem_share = consumer_mem_s.min(consumer_total_s * 0.8);
    mem_share * resident * STRENGTH * (AFF_REF - affinity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;
    use crate::sched::schedule::AxisTiling;

    /// Schedule with given innermost spatial / reduction tiles.
    fn sched_with_inner(k: &Kernel, spatial: u64, red: u64) -> Schedule {
        let mut s = Schedule::untuned_default(k);
        let last = s.spatial.len() - 1;
        s.spatial[last] = AxisTiling::of(&[spatial]);
        if let Some(r) = s.reduction.last_mut() {
            *r = AxisTiling::of(&[red]);
        }
        s
    }

    #[test]
    fn matched_granularity_has_higher_affinity() {
        let k = KernelBuilder::dense(256, 512, 512, &[]);
        // Producer writes in 64-wide chunks; consumer A first-touches at
        // 8 (spatial) x 8 (reduction) = 64 -> perfect match; consumer C
        // at 1x1 = 1 -> poor match.
        let p = sched_with_inner(&k, 64, 1);
        let a = sched_with_inner(&k, 8, 8);
        let c = sched_with_inner(&k, 1, 1);
        assert!(layout_affinity(&p, &a) > layout_affinity(&p, &c));
        assert!((layout_affinity(&p, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn matched_layouts_save_time() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(256, 512, 512, &[]);
        let p = sched_with_inner(&k, 64, 1);
        let cons = sched_with_inner(&k, 8, 8); // affinity 1.0 > AFF_REF
        let d = boundary_delta(&k, &p, &cons, 1e-3, 2e-3, &prof);
        assert!(d < 0.0, "delta {d}");
        // Bounded by half the memory share.
        assert!(d.abs() <= 0.5 * 1e-3 + 1e-15);
    }

    #[test]
    fn mismatched_layouts_cost_time() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(64, 64, 64, &[]); // small -> fully resident
        let a = sched_with_inner(&k, 64, 1);
        let b = sched_with_inner(&k, 1, 1);
        let d = boundary_delta(&k, &a, &b, 1e-3, 2e-3, &prof);
        assert!(d > 0.0, "mismatch should penalize: {d}");
    }

    #[test]
    fn large_outputs_are_less_resident() {
        let prof = DeviceProfile::xeon_e5_2620();
        let small = KernelBuilder::dense(64, 64, 64, &[]);
        let big = KernelBuilder::dense(2048, 2048, 2048, &[]);
        let ss = sched_with_inner(&small, 8, 1);
        let sb = sched_with_inner(&big, 8, 1);
        let d_small = boundary_delta(&small, &ss, &ss, 1e-3, 2e-3, &prof).abs();
        let d_big = boundary_delta(&big, &sb, &sb, 1e-3, 2e-3, &prof).abs();
        assert!(d_small > d_big, "{d_small} vs {d_big}");
    }

    #[test]
    fn compute_bound_consumers_barely_care() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(64, 64, 64, &[]);
        let s = sched_with_inner(&k, 8, 1);
        let d_membound = boundary_delta(&k, &s, &s, 1.9e-3, 2e-3, &prof).abs();
        let d_computebound = boundary_delta(&k, &s, &s, 1e-5, 2e-3, &prof).abs();
        assert!(d_membound > 10.0 * d_computebound);
    }
}
