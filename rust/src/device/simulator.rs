//! Analytic CPU cost simulator.
//!
//! `simulate` maps (kernel, scheduled nest, device profile) to an
//! execution-time estimate via a roofline decomposition:
//!
//!   time = launch + parallel_fork
//!        + max(compute_time, worst_cache_boundary_time)
//!        + loop_branch_overhead
//!
//! * **compute**: FLOPs over peak, scaled by SIMD-lane utilization of the
//!   vectorized loop and by parallel load balance over the cores.
//! * **memory**: per cache boundary, the bytes that must cross it.
//!   Traffic is derived from exact affine tile footprints (including
//!   conv sliding windows) with cache-line granularity, using a
//!   residency analysis: the outermost loop whose working set fits
//!   determines the tile that streams; loops outside it re-load a buffer
//!   unless the buffer is loop-invariant *and* stays resident.
//! * **overhead**: dynamic loop back-edges (removed by unroll, divided
//!   by vector width for the vectorized loop), i-cache pressure for
//!   oversized unrolled bodies, and fixed launch/fork costs.
//!
//! The model is deterministic; `measure` adds seeded lognormal jitter —
//! that is what the tuners observe, and it is why auto-scheduling in this
//! repo is stochastic-but-reproducible like Ansor's real measurements.

use super::profile::DeviceProfile;
use crate::ir::Kernel;
use crate::sched::{Ann, ScheduledNest};
use crate::util::rng::Rng;

/// Detailed cost breakdown (exposed for reports, perf work, and tests).
#[derive(Clone, Debug, Default)]
pub struct SimBreakdown {
    pub total_s: f64,
    pub compute_s: f64,
    pub mem_s: f64,
    pub overhead_s: f64,
    /// Bytes crossing each cache boundary (L2→L1, L3→L2, DRAM→L3, ...).
    pub boundary_bytes: Vec<f64>,
    pub parallel_speedup: f64,
    pub vector_utilization: f64,
}

/// Scratch buffers reused across simulations (the tuner calls `simulate`
/// millions of times; this keeps the hot loop allocation-free).
#[derive(Default)]
pub struct SimScratch {
    tile: Vec<u64>,
    footprints: Vec<f64>,
    contig: Vec<f64>,
    wset: Vec<f64>,
}

thread_local! {
    /// Per-thread scratch so the plain `simulate` entry point is also
    /// allocation-free (perf pass: +2.4x on this hot loop — see
    /// EXPERIMENTS.md §Perf).
    static TLS_SCRATCH: std::cell::RefCell<SimScratch> =
        std::cell::RefCell::new(SimScratch::default());
}

pub fn simulate(kernel: &Kernel, nest: &ScheduledNest, profile: &DeviceProfile) -> SimBreakdown {
    TLS_SCRATCH.with(|s| simulate_with(kernel, nest, profile, &mut s.borrow_mut()))
}

pub fn simulate_with(
    kernel: &Kernel,
    nest: &ScheduledNest,
    profile: &DeviceProfile,
    scratch: &mut SimScratch,
) -> SimBreakdown {
    let ln = &kernel.nest;
    let loops = &nest.loops;
    let nloops = loops.len();
    let nbufs = ln.buffers.len();
    let lanes = profile.simd_lanes_f32() as f64;

    // ---- compute term ------------------------------------------------------
    let padded_points = ln.total_points() * nest.waste;
    let flops = padded_points * ln.flops_per_point + ln.output_points() * ln.epilogue_ops;

    let vec_extent = nest.vector_extent();
    let vector_utilization = if vec_extent > 1 {
        let e = vec_extent as f64;
        e / ((e / lanes).ceil() * lanes)
    } else {
        1.0 / lanes
    };

    let par_extent = nest.parallel_extent();
    let parallel_speedup = if par_extent > 1 {
        let p = par_extent as f64;
        let rounds = (p / profile.cores as f64).ceil();
        p / rounds
    } else {
        1.0
    };
    let cores_used = (par_extent.min(profile.cores)).max(1) as f64;

    let compute_s = flops / (profile.peak_flops_core() * vector_utilization * parallel_speedup);

    // ---- memory term -------------------------------------------------------
    // tile[p][axis]: iterations of `axis` at-or-inside loop position p.
    // We need, per position, per buffer: footprint bytes + contiguous run
    // of the innermost buffer dim (for line-granularity).
    scratch.tile.clear();
    scratch.tile.resize(ln.axes.len(), 1);
    scratch.footprints.clear();
    scratch.footprints.resize((nloops + 1) * nbufs, 0.0);
    scratch.contig.clear();
    scratch.contig.resize((nloops + 1) * nbufs, 1.0);
    scratch.wset.clear();
    scratch.wset.resize(nloops + 1, 0.0);

    // Working set per position (sum over buffers), positions nloops..0.
    // Position p means "one full execution of loop p's subtree"; position
    // nloops is the innermost body (single point).
    let wset = &mut scratch.wset;
    for p in (0..=nloops).rev() {
        if p < nloops {
            let ax = loops[p].axis;
            scratch.tile[ax] = scratch.tile[ax].saturating_mul(loops[p].extent.max(1));
        }
        let mut total = 0.0;
        for (bi, buf) in ln.buffers.iter().enumerate() {
            let fp = buf.footprint_bytes(&scratch.tile) as f64;
            scratch.footprints[p * nbufs + bi] = fp;
            // Contiguous run along the buffer's last (fastest-varying) dim.
            let contig = buf
                .dims
                .last()
                .map(|d| d.range_size(&scratch.tile) as f64)
                .unwrap_or(1.0)
                * buf.elem_bytes as f64;
            scratch.contig[p * nbufs + bi] = contig;
            total += fp;
        }
        wset[p] = total;
    }
    // NOTE: wset/footprints at index p were computed with tile including
    // loops at positions >= p (we updated tile before computing). Position
    // nloops (body) uses all-ones tile.
    // Rebuild is ordered: we fill from innermost outwards, so at index p
    // the tile already includes loop p itself. That is the intended
    // "subtree of loop p" semantics.

    let line = profile.line_bytes as f64;
    let mut boundary_bytes: Vec<f64> = Vec::with_capacity(profile.caches.len());
    let mut mem_s: f64 = 0.0;
    for (ci, cache) in profile.caches.iter().enumerate() {
        let cap = cache.bytes as f64;
        // Outermost position whose full subtree fits in this cache.
        let mut p_res = nloops; // innermost body always "fits"
        for p in 0..=nloops {
            if wset[p] <= cap {
                p_res = p;
                break;
            }
        }
        let mut traffic = 0.0f64;
        for (bi, buf) in ln.buffers.iter().enumerate() {
            let fp = scratch.footprints[p_res * nbufs + bi];
            let contig = scratch.contig[p_res * nbufs + bi];
            // Line-granularity waste: short contiguous runs still move
            // whole lines.
            let line_factor = if contig >= line { 1.0 } else { (line / contig).min(16.0) };
            // Trips of loops outside the residency subtree that force a
            // reload of this buffer: loops indexing the buffer always do;
            // loop-invariant loops do only if the buffer's own footprint
            // at that outer scope exceeds the cache (it could not stay
            // resident while other data streamed).
            let mut reload = 1.0f64;
            for q in 0..p_res {
                let l = &loops[q];
                let indexes = buf.uses_axis(l.axis);
                // Output buffers under reduction without a local cache
                // buffer are read-modify-written on every reduction trip
                // (Alg. 1 line 22 is exactly the optimization that avoids
                // this).
                let rmw = buf.is_output
                    && !nest.cache_write
                    && ln.axes[l.axis].kind == crate::ir::AxisKind::Reduction;
                if indexes || rmw {
                    reload *= l.extent as f64;
                } else {
                    // Invariant loop: reuse only if this buffer stays
                    // resident across it.
                    let fp_at_q = scratch.footprints[q * nbufs + bi];
                    if fp_at_q > cap {
                        reload *= l.extent as f64;
                    }
                }
            }
            let mut t = fp * line_factor * reload;
            // Writes cross the boundary too: outputs count roughly double
            // (write-allocate + writeback) unless staged in a local cache
            // buffer.
            if buf.is_output {
                t *= if nest.cache_write { 1.0 } else { 2.0 };
            }
            // Never less than compulsory traffic.
            let compulsory = buf.total_bytes(&ln.axes) as f64;
            traffic += t.max(compulsory);
        }
        boundary_bytes.push(traffic);
        // Bandwidth of fetching INTO this level from beyond: use the next
        // level's bandwidth (or DRAM for the last cache).
        let feed_gbps = if ci + 1 < profile.caches.len() {
            let nxt = &profile.caches[ci + 1];
            nxt.gbps * if nxt.shared { 1.0 } else { cores_used }
        } else {
            profile.dram_gbps
        };
        mem_s = mem_s.max(traffic / (feed_gbps * 1e9));
    }

    // ---- loop overhead -----------------------------------------------------
    let mut branches = 0.0f64;
    let mut trips_outer = 1.0f64;
    for l in loops {
        let e = l.extent.max(1) as f64;
        let iters = match l.ann {
            Ann::Vectorize => (e / lanes).ceil(),
            _ => e,
        };
        trips_outer *= iters;
        if l.ann != Ann::Unroll {
            branches += trips_outer;
        }
    }
    let mut overhead_s = branches * profile.branch_cost_cycles / (profile.freq_ghz * 1e9 * cores_used);

    // i-cache pressure from oversized unrolled bodies.
    let unrolled: f64 = loops
        .iter()
        .filter(|l| l.ann == Ann::Unroll)
        .map(|l| l.extent.max(1) as f64)
        .product();
    let body_instrs = 4.0 + ln.epilogue_ops;
    let compute_s = if unrolled * body_instrs > profile.icache_unroll_budget {
        compute_s * 1.18
    } else {
        compute_s
    };

    overhead_s += profile.launch_overhead_s;
    if par_extent > 1 {
        overhead_s += profile.parallel_overhead_s;
    }

    // Compute and memory overlap imperfectly on an in-order memory system:
    // charge the max plus a fraction of the min.
    let main = compute_s.max(mem_s) + 0.2 * compute_s.min(mem_s);
    let total_s = main + overhead_s;

    SimBreakdown {
        total_s,
        compute_s,
        mem_s,
        overhead_s,
        boundary_bytes,
        parallel_speedup,
        vector_utilization,
    }
}

/// One noisy timed measurement (what tuners observe).
pub fn measure(
    kernel: &Kernel,
    nest: &ScheduledNest,
    profile: &DeviceProfile,
    rng: &mut Rng,
) -> f64 {
    measure_from_sim(simulate(kernel, nest, profile).total_s, profile, rng)
}

/// The noise half of [`measure`], split out so executors that fan the
/// deterministic simulation across threads can draw the seeded jitter
/// serially afterwards (in job order) and still produce bit-identical
/// measurements — the tuner's parallel candidate evaluation depends on
/// this staying the single definition of measurement noise.
pub fn measure_from_sim(sim_total_s: f64, profile: &DeviceProfile, rng: &mut Rng) -> f64 {
    sim_total_s * rng.lognormal_noise(profile.noise_sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{KernelBuilder, OpKind};
    use crate::sched::schedule::AxisTiling;
    use crate::sched::{apply, Schedule};

    fn gemm(n: u64) -> Kernel {
        KernelBuilder::dense(n, n, n, &[])
    }

    fn tuned_gemm_schedule(k: &Kernel) -> Schedule {
        Schedule {
            class_sig: k.class_signature(),
            skeleton: k.nest.skeleton(),
            spatial: vec![AxisTiling::of(&[16, 1, 8]), AxisTiling::of(&[16, 1, 8])],
            reduction: vec![AxisTiling::of(&[8])],
            parallel_levels: 1,
            vectorize: true,
            unroll_max: 64,
            cache_write: true,
        }
    }

    #[test]
    fn tuned_gemm_is_orders_of_magnitude_faster_than_naive() {
        // Paper §4.1: auto-schedules improve the 512/1024 GEMMs by
        // ~246x/308x over the unmodified computation. Our simulator must
        // reproduce that scale (>50x).
        let prof = DeviceProfile::xeon_e5_2620();
        let k = gemm(512);
        let naive = apply(&Schedule::naive(&k), &k).unwrap();
        let tuned = apply(&tuned_gemm_schedule(&k), &k).unwrap();
        let t_naive = simulate(&k, &naive, &prof).total_s;
        let t_tuned = simulate(&k, &tuned, &prof).total_s;
        let speedup = t_naive / t_tuned;
        assert!(speedup > 50.0, "speedup only {speedup:.1}x (naive {t_naive:.6}, tuned {t_tuned:.6})");
        assert!(speedup < 2000.0, "speedup implausibly high: {speedup:.0}x");
    }

    #[test]
    fn vectorization_helps() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = gemm(512);
        let mut s = tuned_gemm_schedule(&k);
        let vec = simulate(&k, &apply(&s, &k).unwrap(), &prof).total_s;
        s.vectorize = false;
        let no_vec = simulate(&k, &apply(&s, &k).unwrap(), &prof).total_s;
        assert!(no_vec / vec > 2.0, "vectorize gain {:.2}", no_vec / vec);
    }

    #[test]
    fn parallelism_helps() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = gemm(1024);
        let mut s = tuned_gemm_schedule(&k);
        let par = simulate(&k, &apply(&s, &k).unwrap(), &prof).total_s;
        s.parallel_levels = 0;
        let seq = simulate(&k, &apply(&s, &k).unwrap(), &prof).total_s;
        let gain = seq / par;
        assert!(gain > 3.0 && gain <= 8.5, "parallel gain {gain:.2}");
    }

    #[test]
    fn cache_tiling_beats_untiled_on_large_gemm() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = gemm(1024);
        let tiled = simulate(&k, &apply(&tuned_gemm_schedule(&k), &k).unwrap(), &prof).total_s;
        let flat = simulate(&k, &apply(&Schedule::untuned_default(&k), &k).unwrap(), &prof).total_s;
        assert!(flat / tiled > 1.5, "tiling gain {:.2}", flat / tiled);
    }

    #[test]
    fn edge_device_is_slower() {
        let k = gemm(512);
        let s = tuned_gemm_schedule(&k);
        let xeon = simulate(&k, &apply(&s, &k).unwrap(), &DeviceProfile::xeon_e5_2620()).total_s;
        let pi = simulate(&k, &apply(&s, &k).unwrap(), &DeviceProfile::cortex_a72()).total_s;
        assert!(pi / xeon > 3.0, "edge/server ratio {:.2}", pi / xeon);
    }

    #[test]
    fn measurement_noise_is_small_and_seeded() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = gemm(256);
        let nest = apply(&Schedule::untuned_default(&k), &k).unwrap();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = measure(&k, &nest, &prof, &mut r1);
        let b = measure(&k, &nest, &prof, &mut r2);
        assert_eq!(a, b);
        let det = simulate(&k, &nest, &prof).total_s;
        assert!((a / det - 1.0).abs() < 0.15);
    }

    #[test]
    fn conv_kernel_simulates_sanely() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]);
        let t = simulate(&k, &apply(&Schedule::untuned_default(&k), &k).unwrap(), &prof).total_s;
        // ~0.46 GFLOP kernel on a 269 GF machine with imperfect schedule:
        // between 1.5 ms and 1 s.
        assert!(t > 1.5e-3 && t < 1.0, "conv time {t}");
    }

    #[test]
    fn waste_increases_time() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = gemm(100); // 100 % 8 != 0 -> padding waste with 8-tiles
        let k_even = gemm(96);
        let s = Schedule {
            class_sig: k.class_signature(),
            skeleton: k.nest.skeleton(),
            spatial: vec![AxisTiling::of(&[8]), AxisTiling::of(&[8])],
            reduction: vec![AxisTiling::flat()],
            parallel_levels: 1,
            vectorize: true,
            unroll_max: 0,
            cache_write: false,
        };
        let t_waste = simulate(&k, &apply(&s, &k).unwrap(), &prof);
        let t_even = simulate(&k_even, &apply(&s, &k_even).unwrap(), &prof);
        // Normalize by work: padded 100->104 per axis should cost more
        // per point than the evenly divisible 96.
        let per_pt_waste = t_waste.compute_s / (100.0f64.powi(2) * 100.0);
        let per_pt_even = t_even.compute_s / (96.0f64.powi(2) * 96.0);
        assert!(per_pt_waste > per_pt_even);
    }

    #[test]
    fn breakdown_fields_populated() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = gemm(256);
        let b = simulate(&k, &apply(&Schedule::untuned_default(&k), &k).unwrap(), &prof);
        assert_eq!(b.boundary_bytes.len(), 3);
        assert!(b.total_s > 0.0 && b.compute_s > 0.0 && b.mem_s > 0.0);
        assert!(b.vector_utilization > 0.9);
    }
}
