//! End-to-end model inference time under a schedule assignment.
//!
//! Used by every experiment: full-model time = sum of per-instance kernel
//! times minus the producer→consumer boundary savings (§5.5). Kernel
//! selection (both Ansor's and transfer-tuning's) *cannot see* the
//! boundary term — they optimize standalone times, exactly like the
//! paper — but final model timings include it.

use super::interkernel::boundary_delta;
use super::profile::DeviceProfile;
use super::simulator::{simulate, SimBreakdown};
use crate::ir::ModelGraph;
use crate::sched::{apply, Schedule};

/// Full-model inference time given a per-unique-kernel schedule lookup.
/// The lookup must return an applicable schedule for every kernel
/// (callers fall back to `Schedule::untuned_default`).
pub fn model_time(
    graph: &ModelGraph,
    profile: &DeviceProfile,
    sched_for: impl Fn(usize) -> Schedule,
) -> f64 {
    let scheds: Vec<Schedule> = (0..graph.kernels.len()).map(&sched_for).collect();
    let breakdowns: Vec<SimBreakdown> = graph
        .kernels
        .iter()
        .zip(&scheds)
        .map(|(k, s)| {
            let nest = apply(s, k).unwrap_or_else(|e| {
                panic!("schedule assignment invalid for `{}`: {e}", k.class_signature())
            });
            simulate(k, &nest, profile)
        })
        .collect();

    let mut total: f64 = graph
        .instances
        .iter()
        .map(|i| breakdowns[i.kernel].total_s)
        .sum();
    // Signed producer→consumer boundary adjustments (§5.5): neither the
    // tuner nor the transfer engine sees this term — they select by
    // standalone time, exactly like the paper's implementation.
    for inst in &graph.instances {
        if let Some(pi) = inst.producer {
            let prod = &graph.instances[pi];
            let cons = &breakdowns[inst.kernel];
            let delta = boundary_delta(
                &graph.kernels[prod.kernel],
                &scheds[prod.kernel],
                &scheds[inst.kernel],
                cons.mem_s,
                cons.total_s,
                profile,
            );
            // Clamp: a boundary cannot erase (or more than double) the
            // consumer's own cost.
            total += delta.clamp(-0.9 * cons.total_s, cons.total_s);
        }
    }
    total.max(0.0)
}

/// Model time with every kernel on its untuned default schedule — the
/// paper's baseline ("compiled using TVM's standard untuned schedules").
pub fn untuned_model_time(graph: &ModelGraph, profile: &DeviceProfile) -> f64 {
    model_time(graph, profile, |k| Schedule::untuned_default(&graph.kernels[k]))
}

/// Untuned time attributed to each unique kernel (standalone, weighted by
/// use count) — the `P_c` proportions of the paper's Eq. 1 derive from
/// this.
pub fn untuned_kernel_times(graph: &ModelGraph, profile: &DeviceProfile) -> Vec<f64> {
    graph
        .kernels
        .iter()
        .enumerate()
        .map(|(i, k)| {
            let s = Schedule::untuned_default(k);
            let nest = apply(&s, k).expect("default schedule must apply");
            simulate(k, &nest, profile).total_s * graph.use_count(i) as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn untuned_times_are_sane() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::resnet::resnet18();
        let t = untuned_model_time(&g, &prof);
        // ResNet-18 untuned on an 8-core Xeon: tens of ms to a few s.
        assert!(t > 5e-3 && t < 5.0, "untuned resnet18 = {t}");
    }

    #[test]
    fn boundary_adjustments_are_bounded() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::resnet::resnet18();
        let standalone_sum: f64 = g
            .instances
            .iter()
            .map(|i| {
                let k = &g.kernels[i.kernel];
                let s = Schedule::untuned_default(k);
                simulate(k, &apply(&s, k).unwrap(), &prof).total_s
            })
            .sum();
        let with_boundaries = untuned_model_time(&g, &prof);
        // Inter-kernel effects adjust, not dominate: within +-40% of the
        // standalone sum.
        assert!(with_boundaries > 0.6 * standalone_sum, "{with_boundaries} vs {standalone_sum}");
        assert!(with_boundaries < 1.4 * standalone_sum, "{with_boundaries} vs {standalone_sum}");
        // And identical defaults have identical granularities -> affinity
        // 1.0 everywhere -> the default assignment should actually save.
        assert!(with_boundaries <= standalone_sum);
    }

    #[test]
    fn kernel_times_weighted_by_use_count() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::resnet::resnet18();
        let times = untuned_kernel_times(&g, &prof);
        assert_eq!(times.len(), g.kernels.len());
        assert!(times.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn bert_untuned_dominated_by_dense() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::bert::bert(256);
        let times = untuned_kernel_times(&g, &prof);
        let dense: f64 = g
            .kernels
            .iter()
            .zip(&times)
            .filter(|(k, _)| k.class_signature() == "dense")
            .map(|(_, t)| t)
            .sum();
        let frac = dense / times.iter().sum::<f64>();
        // Paper Table 2: class Q is 98% of BERT's untuned time.
        assert!(frac > 0.85, "dense fraction {frac}");
    }
}
