//! The transfer-tuning engine (paper §4.3, §5).
//!
//! Given a target model and a schedule store, evaluate every compatible
//! kernel/schedule pair *standalone* (in parallel on the host, with
//! sequential device seconds charged to the ledger), pick the best
//! schedule per kernel, and compile the full model with the winners.
//! Kernels whose class has no schedules in the store keep the untuned
//! default (the paper's class-F-in-ResNet18 case).
//!
//! The returned result carries everything the paper's figures need: the
//! full pair matrix (Fig 4), the search-time ledger (Fig 5b/6b/8b), and
//! the end-to-end times (Fig 5a/6a/8a).

use super::store::ScheduleStore;
use crate::coordinator::{measure_pairs, Ledger};
use crate::device::{model_time, untuned_model_time, DeviceProfile};
use crate::ir::ModelGraph;
use crate::sched::{adapt_cross_class, Schedule};

/// Engine options. The defaults reproduce the paper's implementation;
/// `cross_class` enables the §4.2 future-work extension (adapting
/// schedules between classes that share an anchor, e.g. E→F).
#[derive(Clone, Debug, Default)]
pub struct TransferOptions {
    pub cross_class: bool,
}

/// Evaluation of one kernel against every compatible store record.
#[derive(Clone, Debug)]
pub struct KernelSweep {
    /// Unique-kernel index in the target graph.
    pub kernel: usize,
    /// (store record index, outcome) for each compatible-class record;
    /// `None` runtime = invalid (Fig 4's -1).
    pub outcomes: Vec<(usize, Option<f64>)>,
    /// Untuned-default standalone time (the black bars of Fig 4).
    pub untuned_s: f64,
    /// Chosen store record (None = kept the default schedule).
    pub chosen: Option<usize>,
    /// Standalone time of the chosen schedule.
    pub chosen_s: f64,
    /// The schedule actually chosen (may be a cross-class adaptation of
    /// the record; `None` = untuned default).
    pub chosen_schedule: Option<Schedule>,
}

#[derive(Clone, Debug)]
pub struct TransferResult {
    pub target: String,
    /// Which store slice was used (model name for one-to-one, "mixed"
    /// for the pooled mode).
    pub source: String,
    pub sweeps: Vec<KernelSweep>,
    pub ledger: Ledger,
    /// End-to-end untuned baseline.
    pub untuned_model_s: f64,
    /// End-to-end time with the chosen schedules.
    pub tuned_model_s: f64,
}

impl TransferResult {
    pub fn speedup(&self) -> f64 {
        self.untuned_model_s / self.tuned_model_s
    }
    pub fn search_time_s(&self) -> f64 {
        self.ledger.seconds
    }
    pub fn pairs_evaluated(&self) -> usize {
        self.sweeps.iter().map(|s| s.outcomes.len()).sum()
    }
    pub fn invalid_pairs(&self) -> usize {
        self.sweeps
            .iter()
            .flat_map(|s| &s.outcomes)
            .filter(|(_, o)| o.is_none())
            .count()
    }
}

/// Run transfer-tuning of `store` onto `target`.
///
/// `source_label` is carried into the result for reporting; pass the
/// tuning-model name (one-to-one) or "mixed" (pool mode, §5.5).
pub fn transfer_tune(
    target: &ModelGraph,
    store: &ScheduleStore,
    profile: &DeviceProfile,
    source_label: &str,
    seed: u64,
) -> TransferResult {
    transfer_tune_with(target, store, profile, source_label, seed, &TransferOptions::default())
}

/// Full-control entry point (see [`TransferOptions`]).
pub fn transfer_tune_with(
    target: &ModelGraph,
    store: &ScheduleStore,
    profile: &DeviceProfile,
    source_label: &str,
    seed: u64,
    options: &TransferOptions,
) -> TransferResult {
    let mut ledger = Ledger::new();

    // Build the full pair list: every kernel x every same-class record
    // (plus, in cross-class mode, anchor-compatible records adapted onto
    // the target class).
    let mut adapted_pool: Vec<Schedule> = Vec::new(); // owns adapted schedules
    let mut job_specs: Vec<(usize, usize, bool)> = Vec::new(); // (kernel, record, adapted)
    let mut job_spans: Vec<(usize, Vec<usize>)> = Vec::new(); // kernel -> record indices
    for (ki, kernel) in target.kernels.iter().enumerate() {
        let sig = kernel.class_signature();
        let mut record_idxs: Vec<usize> = Vec::new();
        for (ri, r) in store.records.iter().enumerate() {
            if r.class_sig == sig {
                record_idxs.push(ri);
                job_specs.push((ki, ri, false));
            } else if options.cross_class {
                if let Some(adapted) = adapt_cross_class(&r.schedule, kernel) {
                    record_idxs.push(ri);
                    adapted_pool.push(adapted);
                    job_specs.push((ki, ri, true));
                }
            }
        }
        job_spans.push((ki, record_idxs));
    }
    // Second pass to borrow stable schedule refs.
    let mut jobs: Vec<(&crate::ir::Kernel, &Schedule)> = Vec::with_capacity(job_specs.len());
    let mut adapted_cursor = 0usize;
    for &(ki, ri, is_adapted) in &job_specs {
        let sched: &Schedule = if is_adapted {
            let s = &adapted_pool[adapted_cursor];
            adapted_cursor += 1;
            s
        } else {
            &store.records[ri].schedule
        };
        jobs.push((&target.kernels[ki], sched));
    }

    // Standalone baseline (untuned default) per kernel — measured too,
    // as the paper does for its Fig 4 "untuned" bars.
    let defaults: Vec<Schedule> = target.kernels.iter().map(Schedule::untuned_default).collect();
    let default_jobs: Vec<(&crate::ir::Kernel, &Schedule)> =
        target.kernels.iter().zip(&defaults).collect();

    let outcomes = measure_pairs(&jobs, profile, seed);
    let default_outcomes = measure_pairs(&default_jobs, profile, seed ^ 0xDEF0);

    // Charge device time in job order (sequential device semantics).
    for o in outcomes.iter().chain(default_outcomes.iter()) {
        match o.runtime() {
            Some(t) => ledger.charge_measure(profile, t),
            None => ledger.charge_compile_fail(profile),
        }
    }

    // Per-kernel selection.
    let mut sweeps: Vec<KernelSweep> = Vec::with_capacity(target.kernels.len());
    let mut cursor = 0usize;
    for (ki, record_idxs) in job_spans {
        let untuned_s = default_outcomes[ki]
            .runtime()
            .expect("default schedule always applies");
        let mut sweep = KernelSweep {
            kernel: ki,
            outcomes: Vec::with_capacity(record_idxs.len()),
            untuned_s,
            chosen: None,
            chosen_s: untuned_s,
            chosen_schedule: None,
        };
        for ri in record_idxs {
            let rt = outcomes[cursor].runtime();
            let sched = jobs[cursor].1;
            cursor += 1;
            sweep.outcomes.push((ri, rt));
            if let Some(t) = rt {
                // Selection is by *standalone* time (paper §5.5 explains
                // both TT and Ansor assume kernel independence here).
                if t < sweep.chosen_s {
                    sweep.chosen_s = t;
                    sweep.chosen = Some(ri);
                    // Keep the schedule actually measured (which may be a
                    // cross-class *adapted* variant of the record).
                    sweep.chosen_schedule = Some(sched.clone());
                }
            }
        }
        sweeps.push(sweep);
    }

    // Compile the full model with the winners and time it end-to-end
    // (deterministic, with inter-kernel boundary effects).
    let tuned_model_s = model_time(target, profile, |k| match &sweeps[k].chosen_schedule {
        Some(s) => s.clone(),
        None => defaults[k].clone(),
    });
    let untuned_model_s = untuned_model_time(target, profile);

    TransferResult {
        target: target.name.clone(),
        source: source_label.to_string(),
        sweeps,
        ledger,
        untuned_model_s,
        tuned_model_s,
    }
}

/// Convenience: one-to-one transfer from a single source model's
/// schedules (the paper's default mode).
pub fn transfer_tune_one_to_one(
    target: &ModelGraph,
    store: &ScheduleStore,
    source_model: &str,
    profile: &DeviceProfile,
    seed: u64,
) -> TransferResult {
    let slice = store.of_model(source_model);
    transfer_tune(target, &slice, profile, source_model, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::KernelBuilder;

    fn quick_opts() -> TuneOptions {
        TuneOptions { trials: 96, batch_size: 16, population: 32, generations: 2, ..Default::default() }
    }

    /// Source: two well-tuned dense kernels; target: a different-size
    /// dense kernel of the same class.
    fn dense_setup() -> (ModelGraph, ModelGraph, ScheduleStore) {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut src = ModelGraph::new("Source");
        src.push(KernelBuilder::dense(512, 512, 512, &[]));
        src.push(KernelBuilder::dense(1024, 1024, 1024, &[]));
        let res = tune_model(&src, &prof, &quick_opts());
        let mut store = ScheduleStore::new();
        store.add_tuning(&src, &res);

        let mut tgt = ModelGraph::new("Target");
        tgt.push(KernelBuilder::dense(768, 768, 768, &[]));
        tgt.push(KernelBuilder::dense(256, 256, 256, &[]));
        (src, tgt, store)
    }

    #[test]
    fn transfer_improves_target() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert!(
            res.speedup() > 1.0,
            "transfer should beat untuned default: {}",
            res.speedup()
        );
        assert!(res.search_time_s() > 0.0);
        assert_eq!(res.pairs_evaluated(), 4); // 2 kernels x 2 schedules
    }

    #[test]
    fn no_compatible_class_keeps_default() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, _, store) = dense_setup();
        let mut tgt = ModelGraph::new("ConvOnly");
        tgt.push(KernelBuilder::conv2d(1, 32, 28, 28, 32, 3, 3, 1, 1, &[]));
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert!(res.sweeps[0].outcomes.is_empty());
        assert!(res.sweeps[0].chosen.is_none());
        assert!((res.speedup() - 1.0).abs() < 0.05);
    }

    #[test]
    fn search_time_scales_with_pairs() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let small = transfer_tune(&tgt, &store.of_model("Source"), &prof, "Source", 3);
        let mut doubled = store.clone();
        doubled.merge(&store);
        let large = transfer_tune(&tgt, &doubled, &prof, "mixed", 3);
        assert!(large.pairs_evaluated() > small.pairs_evaluated());
        assert!(large.search_time_s() > small.search_time_s());
    }

    #[test]
    fn selection_never_worse_than_default_standalone() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        for s in &res.sweeps {
            assert!(s.chosen_s <= s.untuned_s + 1e-12);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let a = transfer_tune(&tgt, &store, &prof, "Source", 3);
        let b = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert_eq!(a.tuned_model_s, b.tuned_model_s);
        assert_eq!(a.ledger.seconds, b.ledger.seconds);
    }

    #[test]
    fn invalid_pairs_show_up_when_factors_exceed_extents() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, _, store) = dense_setup();
        // Tiny target: schedules tuned on 512/1024 with inner products
        // beyond 8 cannot apply.
        let mut tgt = ModelGraph::new("Tiny");
        tgt.push(KernelBuilder::dense(8, 8, 8, &[]));
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert!(res.invalid_pairs() > 0, "expected some -1 entries");
    }
}

#[cfg(test)]
mod cross_class_tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::{KernelBuilder, OpKind};

    /// ResNet18's class-F kernels have no same-class schedules in a
    /// ResNet50 store (paper §4.3); cross-class adaptation (the §4.2
    /// future-work extension) lets class-E/G schedules cover them.
    #[test]
    fn cross_class_covers_resnet18_class_f() {
        let prof = DeviceProfile::xeon_e5_2620();
        let src = crate::models::resnet::resnet50();
        let tgt = crate::models::resnet::resnet18();
        let res = tune_model(
            &src,
            &prof,
            &TuneOptions { trials: 300, batch_size: 16, population: 32, generations: 2, seed: 5, ..Default::default() },
        );
        let mut store = ScheduleStore::new();
        store.add_tuning(&src, &res);

        let plain = transfer_tune(&tgt, &store, &prof, "ResNet50", 5);
        let cross = transfer_tune_with(
            &tgt,
            &store,
            &prof,
            "ResNet50",
            5,
            &TransferOptions { cross_class: true },
        );
        // Class-F kernels get candidates only in cross-class mode.
        let f = tgt.kernels_of_class("conv2d_bias_add_relu");
        assert!(!f.is_empty());
        for &fk in &f {
            assert!(plain.sweeps[fk].outcomes.is_empty());
            assert!(!cross.sweeps[fk].outcomes.is_empty(), "F kernel {fk} uncovered");
        }
        // More candidates means search costs more; per-kernel picks stay
        // comparable (exact equality is broken by per-job measurement
        // noise, so allow the noise envelope).
        assert!(cross.pairs_evaluated() > plain.pairs_evaluated());
        for (a, b) in cross.sweeps.iter().zip(&plain.sweeps) {
            assert!(a.chosen_s <= b.chosen_s * 1.2 + 1e-12);
        }
    }

    #[test]
    fn cross_class_never_crosses_anchors() {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut src = crate::ir::ModelGraph::new("DenseSrc");
        src.push(KernelBuilder::dense(512, 512, 512, &[]));
        let res = tune_model(
            &src,
            &prof,
            &TuneOptions { trials: 48, batch_size: 16, population: 32, generations: 2, seed: 5, ..Default::default() },
        );
        let mut store = ScheduleStore::new();
        store.add_tuning(&src, &res);

        let mut tgt = crate::ir::ModelGraph::new("ConvTgt");
        tgt.push(KernelBuilder::conv2d(1, 32, 28, 28, 32, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]));
        let cross = transfer_tune_with(
            &tgt,
            &store,
            &prof,
            "DenseSrc",
            5,
            &TransferOptions { cross_class: true },
        );
        assert!(cross.sweeps[0].outcomes.is_empty(), "dense must not adapt onto conv");
    }
}
