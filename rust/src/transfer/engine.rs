//! The transfer-tuning engine (paper §4.3, §5).
//!
//! Given a target model and a schedule store, evaluate every compatible
//! kernel/schedule pair *standalone* (in parallel on the host, with
//! sequential device seconds charged to the ledger), pick the best
//! schedule per kernel, and compile the full model with the winners.
//! Kernels whose class has no schedules in the store keep the untuned
//! default (the paper's class-F-in-ResNet18 case).
//!
//! The sweep is organized by a [`SweepPlan`]: an owned, kernel-major job
//! list built up front (no borrow juggling between candidate discovery
//! and measurement), dispatched through the content-addressed
//! measurement cache (`crate::coordinator::cache`). Identical pairs —
//! the same schedule transferred onto same-class kernels of equal shape,
//! ubiquitous in pooled stores — are measured **once**, and pairs
//! resident in a caller-provided [`MeasureCache`] cost zero device
//! seconds, so repeated sweeps amortize tuning the way the paper argues
//! deployments should.
//!
//! The returned result carries everything the paper's figures need: the
//! full pair matrix (Fig 4), the search-time ledger (Fig 5b/6b/8b), and
//! the end-to-end times (Fig 5a/6a/8a).

use super::store::{ScheduleStore, StoreView};
use crate::autosched::{features, CostModel, GbdtParams, NUM_FEATURES};
use crate::coordinator::jobs::par_map_indexed;
use crate::coordinator::{
    content_from_parts, content_key, estimator_seed, measure_pairs_cached_precomputed,
    speculative_seed, CachedBatch, Ledger, MeasureCache,
};
use crate::device::{model_time, untuned_model_time, DeviceProfile};
use crate::ir::{Kernel, ModelGraph};
use crate::sched::{adapt_cross_class, apply, serialize, Schedule};
use std::collections::HashSet;

/// Engine options. The defaults reproduce the paper's implementation;
/// `cross_class` enables the §4.2 future-work extension (adapting
/// schedules between classes that share an anchor, e.g. E→F);
/// `speculative_keep` fronts the sweep with a draft-then-verify stage
/// (see [`speculative_sweep`]).
#[derive(Clone, Debug)]
pub struct TransferOptions {
    pub cross_class: bool,
    /// Draft-then-verify keep fraction. Values in (0, 1) rank each
    /// kernel's candidate span with a cost model trained on the sweep's
    /// own measurements so far (features + predict only — no simulator
    /// pass) and measure only the top fraction; 1.0 (the default)
    /// disables the draft stage and is byte-identical to the exact
    /// path. Because pruning changes which pairs are measured and
    /// charged, the keep fraction is folded into the measure-cache key
    /// space (see [`crate::coordinator::cache::speculative_seed`]) and
    /// into artifact keys.
    pub speculative_keep: f64,
    /// Learned prior for the draft stage. When trained, it replaces the
    /// sweep's per-span warmup-and-refit model: every span is ranked by
    /// the prior from the first candidate on (no warmup spans measured
    /// in full). Because that changes which pairs are measured, a
    /// trained prior's [`CostModel::content_hash`] is folded into the
    /// measure-cache seed (see
    /// [`crate::coordinator::cache::estimator_seed`]) and into artifact
    /// keys. The default (untrained) prior changes nothing: the sweep
    /// trains its own draft model exactly as before and every legacy
    /// key survives byte-for-byte.
    pub cost_prior: CostModel,
}

impl Default for TransferOptions {
    fn default() -> Self {
        TransferOptions {
            cross_class: false,
            speculative_keep: 1.0,
            cost_prior: CostModel::default(),
        }
    }
}

/// One candidate evaluation: a store record's schedule (possibly
/// cross-class adapted) applied to one target kernel. The schedule is
/// owned, which is what lets the plan be built in a single pass.
#[derive(Clone, Debug)]
pub struct SweepJob {
    /// Unique-kernel index in the target graph.
    pub kernel: usize,
    /// Store record the schedule came from.
    pub record: usize,
    /// Whether the schedule is a cross-class adaptation of the record.
    pub adapted: bool,
    /// The exact schedule to measure.
    pub schedule: Schedule,
    /// Content key of (kernel, schedule) — each record's schedule is
    /// hashed once at plan time and reused across every kernel it is
    /// tried on.
    pub content: u64,
}

/// The full standalone sweep for one transfer run: candidate jobs in
/// kernel-major order plus the per-kernel untuned baselines. Built once,
/// then dispatched through the cached executor, which dedups identical
/// pairs before any device time is spent.
#[derive(Clone, Debug, Default)]
pub struct SweepPlan {
    pub jobs: Vec<SweepJob>,
    /// Per kernel: the half-open range of `jobs` belonging to it.
    pub spans: Vec<std::ops::Range<usize>>,
    /// Per kernel: the untuned default (measured too, for Fig 4's
    /// baseline bars).
    pub defaults: Vec<Schedule>,
}

impl SweepPlan {
    /// Enumerate every compatible (kernel, record) pair — same-class
    /// records always, anchor-compatible adaptations when `cross_class`
    /// is on.
    pub fn build(target: &ModelGraph, store: &ScheduleStore, options: &TransferOptions) -> SweepPlan {
        Self::build_view(target, &StoreView::of_store(store), options)
    }

    /// [`SweepPlan::build`] over a borrowed [`StoreView`] — the
    /// zero-copy serving entry point. The plan owns its schedules
    /// (cloned per *job*, as before), but the records themselves are
    /// only read through references, so a service can plan sweeps over
    /// `Arc`'d sub-stores without cloning a single [`super::StoreRecord`].
    /// Job/record indices refer to positions in `view.records`.
    pub fn build_view(
        target: &ModelGraph,
        view: &StoreView<'_>,
        options: &TransferOptions,
    ) -> SweepPlan {
        let mut plan = SweepPlan::default();
        // Canonical schedule hashes come memoized from the records
        // themselves (computed once at record construction — see
        // `StoreRecord::new`), so planning a sweep serializes nothing.
        // Debug builds re-verify the memo: the only way it can go stale
        // is mutating the pub `schedule` field instead of calling
        // `StoreRecord::set_schedule`.
        if cfg!(debug_assertions) {
            for r in &view.records {
                debug_assert_eq!(
                    r.schedule_hash(),
                    serialize::canonical_hash(&r.schedule),
                    "StoreRecord schedule mutated in place: stale memoized hash"
                );
            }
        }
        for (ki, kernel) in target.kernels.iter().enumerate() {
            let sig = kernel.class_signature();
            let start = plan.jobs.len();
            for (ri, r) in view.records.iter().enumerate() {
                if r.class_sig == sig {
                    plan.jobs.push(SweepJob {
                        kernel: ki,
                        record: ri,
                        adapted: false,
                        schedule: r.schedule.clone(),
                        content: content_from_parts(kernel.workload_id, r.schedule_hash()),
                    });
                } else if options.cross_class {
                    if let Some(adapted) = adapt_cross_class(&r.schedule, kernel) {
                        // Adapted schedules are kernel-specific; hash
                        // each one directly.
                        let content = content_key(kernel, &adapted);
                        plan.jobs.push(SweepJob {
                            kernel: ki,
                            record: ri,
                            adapted: true,
                            schedule: adapted,
                            content,
                        });
                    }
                }
            }
            plan.spans.push(start..plan.jobs.len());
            plan.defaults.push(Schedule::untuned_default(kernel));
        }
        plan
    }

    /// Total candidate pairs (the paper's "pairs evaluated" count; the
    /// executor may measure fewer after dedup).
    pub fn candidate_pairs(&self) -> usize {
        self.jobs.len()
    }

    /// The candidate sweep as (kernel, schedule) jobs plus their
    /// precomputed content keys, ready for a cached executor.
    pub fn candidate_jobs<'a>(
        &'a self,
        target: &'a ModelGraph,
    ) -> (Vec<(&'a Kernel, &'a Schedule)>, Vec<u64>) {
        let jobs: Vec<(&Kernel, &Schedule)> =
            self.jobs.iter().map(|j| (&target.kernels[j.kernel], &j.schedule)).collect();
        let contents: Vec<u64> = self.jobs.iter().map(|j| j.content).collect();
        (jobs, contents)
    }

    /// The per-kernel untuned-default measurements as jobs + content
    /// keys (Fig 4's baseline bars; also the fallback selection).
    pub fn default_jobs<'a>(
        &'a self,
        target: &'a ModelGraph,
    ) -> (Vec<(&'a Kernel, &'a Schedule)>, Vec<u64>) {
        let jobs: Vec<(&Kernel, &Schedule)> =
            target.kernels.iter().zip(&self.defaults).collect();
        let contents: Vec<u64> = jobs.iter().map(|&(k, d)| content_key(k, d)).collect();
        (jobs, contents)
    }
}

/// Evaluation of one kernel against every compatible store record.
#[derive(Clone, Debug)]
pub struct KernelSweep {
    /// Unique-kernel index in the target graph.
    pub kernel: usize,
    /// (store record index, outcome) for each compatible-class record;
    /// `None` runtime = invalid (Fig 4's -1).
    pub outcomes: Vec<(usize, Option<f64>)>,
    /// Untuned-default standalone time (the black bars of Fig 4).
    pub untuned_s: f64,
    /// Chosen store record (None = kept the default schedule).
    pub chosen: Option<usize>,
    /// Standalone time of the chosen schedule.
    pub chosen_s: f64,
    /// The schedule actually chosen (may be a cross-class adaptation of
    /// the record; `None` = untuned default).
    pub chosen_schedule: Option<Schedule>,
}

#[derive(Clone, Debug)]
pub struct TransferResult {
    pub target: String,
    /// Which store slice was used (model name for one-to-one, "mixed"
    /// for the pooled mode).
    pub source: String,
    pub sweeps: Vec<KernelSweep>,
    /// Device seconds actually charged: cache hits are free, so with a
    /// warm cache this can be far below `cold_ledger` (or exactly zero).
    pub ledger: Ledger,
    /// Device seconds a standalone (cold-cache) run of this exact sweep
    /// would charge. Independent of what ran before on a shared cache —
    /// this is what the paper's search-time figures report, keeping
    /// them deterministic in the seed regardless of sweep order.
    pub cold_ledger: Ledger,
    /// End-to-end untuned baseline.
    pub untuned_model_s: f64,
    /// End-to-end time with the chosen schedules.
    pub tuned_model_s: f64,
}

impl TransferResult {
    pub fn speedup(&self) -> f64 {
        self.untuned_model_s / self.tuned_model_s
    }
    /// Amortized search time: what this run actually charged.
    pub fn search_time_s(&self) -> f64 {
        self.ledger.seconds
    }
    /// Standalone search time: what a cold run would have charged (the
    /// reporting-stable quantity).
    pub fn standalone_search_time_s(&self) -> f64 {
        self.cold_ledger.seconds
    }
    /// Device seconds the measurement cache saved on this run.
    pub fn amortized_saved_s(&self) -> f64 {
        self.cold_ledger.seconds - self.ledger.seconds
    }
    pub fn pairs_evaluated(&self) -> usize {
        self.sweeps.iter().map(|s| s.outcomes.len()).sum()
    }
    pub fn invalid_pairs(&self) -> usize {
        self.sweeps
            .iter()
            .flat_map(|s| &s.outcomes)
            .filter(|(_, o)| o.is_none())
            .count()
    }
}

/// Run transfer-tuning of `store` onto `target`.
///
/// `source_label` is carried into the result for reporting; pass the
/// tuning-model name (one-to-one) or "mixed" (pool mode, §5.5).
pub fn transfer_tune(
    target: &ModelGraph,
    store: &ScheduleStore,
    profile: &DeviceProfile,
    source_label: &str,
    seed: u64,
) -> TransferResult {
    transfer_tune_with(target, store, profile, source_label, seed, &TransferOptions::default())
}

/// Full-control entry point (see [`TransferOptions`]). Uses a private
/// per-call cache: identical pairs within the sweep are still measured
/// once, but nothing persists across calls.
pub fn transfer_tune_with(
    target: &ModelGraph,
    store: &ScheduleStore,
    profile: &DeviceProfile,
    source_label: &str,
    seed: u64,
    options: &TransferOptions,
) -> TransferResult {
    transfer_tune_cached(
        target,
        store,
        profile,
        source_label,
        seed,
        options,
        &mut MeasureCache::new(),
    )
}

/// Minimum measured samples before the draft model is trusted; spans
/// processed before the threshold is reached are measured in full
/// (mirroring the tuner, whose first round always runs exact).
const DRAFT_MIN_SAMPLES: usize = 8;

/// Draft-then-verify front end for a sweep: walk the plan's kernel
/// spans in order, rank each span's candidates with a GBDT cost model
/// (features + predict — no simulator pass), and hand only the top
/// `keep` fraction of valid candidates to `exec` — the flat cached
/// executor or the service layer's sharded one, so there is ONE pruning
/// implementation for both pipelines. The ranking model is either the
/// caller's trained `prior` (the learned cost model, used for every
/// span from the first candidate on) or, when the prior is untrained, a
/// model re-fit per span from the sweep's own measured outcomes so far
/// — the original draft behavior, byte-for-byte. Apply-fail candidates
/// are pruned for free: the draft stage already proved they cannot
/// compile, so they are dropped without a compile-fail charge. Returns
/// the pruned plan (surviving jobs in original order, spans recomputed)
/// plus the concatenated measured batch aligned with it.
///
/// Determinism: ranking is pure (memoized content keys, index-ordered
/// `par_map_indexed` slots, ties broken by span index), training data
/// accumulates in span order, and `exec` runs span by span in kernel
/// order — the result is a pure function of (plan, profile, keep,
/// prior, exec's seed), independent of thread count.
pub(crate) fn speculative_sweep<F>(
    target: &ModelGraph,
    plan: &SweepPlan,
    profile: &DeviceProfile,
    keep: f64,
    prior: &CostModel,
    exec: &mut F,
) -> (SweepPlan, CachedBatch)
where
    F: FnMut(&[(&Kernel, &Schedule)], &[u64]) -> CachedBatch,
{
    let mut pruned = SweepPlan {
        jobs: Vec::new(),
        spans: Vec::with_capacity(plan.spans.len()),
        defaults: plan.defaults.clone(),
    };
    let mut combined = CachedBatch { outcomes: Vec::new(), keys: Vec::new() };
    let mut xs: Vec<[f64; NUM_FEATURES]> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    let gbdt = GbdtParams::default();

    for (ki, span) in plan.spans.iter().enumerate() {
        let kernel = &target.kernels[ki];
        let span_jobs = &plan.jobs[span.clone()];
        // Pure phase (parallel, index-ordered slots): apply + features
        // for every candidate — the feature vector drives the draft
        // score now and becomes the training sample if measured.
        let feats: Vec<Option<[f64; NUM_FEATURES]>> = par_map_indexed(span_jobs, 0, |_, j| {
            apply(&j.schedule, kernel).ok().map(|nest| features(kernel, &nest, profile))
        });
        let survivors: Vec<usize> = if !prior.is_trained() && xs.len() < DRAFT_MIN_SAMPLES {
            // Warmup: no trustworthy model yet — measure the span in
            // full, exactly like the exact path. A trained prior skips
            // warmup entirely: it already carries a whole cache's worth
            // of measurements.
            (0..span_jobs.len()).collect()
        } else {
            let span_model;
            let model: &CostModel = if prior.is_trained() {
                prior
            } else {
                span_model = CostModel::train(&xs, &ys, &gbdt);
                &span_model
            };
            let scores: Vec<Option<f64>> =
                feats.iter().map(|f| f.as_ref().map(|x| model.predict(x))).collect();
            let mut order: Vec<usize> =
                (0..scores.len()).filter(|&i| scores[i].is_some()).collect();
            let n_valid = order.len();
            order.sort_by(|&a, &b| {
                let sa = scores[a].expect("valid draft");
                let sb = scores[b].expect("valid draft");
                sb.partial_cmp(&sa).expect("finite draft scores").then(a.cmp(&b))
            });
            let n_keep = if n_valid == 0 {
                0
            } else {
                ((keep * n_valid as f64).ceil() as usize).clamp(1, n_valid)
            };
            let mut kept: Vec<usize> = order.into_iter().take(n_keep).collect();
            kept.sort_unstable();
            kept
        };

        let jobs: Vec<(&Kernel, &Schedule)> =
            survivors.iter().map(|&i| (kernel, &span_jobs[i].schedule)).collect();
        let contents: Vec<u64> = survivors.iter().map(|&i| span_jobs[i].content).collect();
        let batch = exec(&jobs, &contents);

        // Accumulate training data from this span's measured survivors
        // (only when the sweep trains its own draft model — a trained
        // prior is frozen for the whole sweep).
        if !prior.is_trained() {
            for (&si, outcome) in survivors.iter().zip(&batch.outcomes) {
                if let (Some(t), Some(x)) = (outcome.runtime(), feats[si].as_ref()) {
                    xs.push(*x);
                    ys.push(-(t.max(1e-12)).ln());
                }
            }
        }

        let start = pruned.jobs.len();
        pruned.jobs.extend(survivors.iter().map(|&i| span_jobs[i].clone()));
        pruned.spans.push(start..pruned.jobs.len());
        combined.outcomes.extend(batch.outcomes);
        combined.keys.extend(batch.keys);
    }
    (pruned, combined)
}

/// Transfer-tune through a caller-owned [`MeasureCache`].
///
/// Pairs resident in the cache are served for zero ledger seconds, and
/// outcomes are bit-identical to a cache-off run at the same seed (the
/// cache-transparency invariant — see `crate::coordinator::cache`), so
/// sharing one cache across pooled-store and pairwise sweeps changes
/// only what the search costs, never what it finds.
pub fn transfer_tune_cached(
    target: &ModelGraph,
    store: &ScheduleStore,
    profile: &DeviceProfile,
    source_label: &str,
    seed: u64,
    options: &TransferOptions,
    cache: &mut MeasureCache,
) -> TransferResult {
    let mut ledger = Ledger::new();
    let plan = SweepPlan::build(target, store, options);
    // Keep-fraction key separation: a pruned run's cache entries live
    // in their own seed space, so it can never collide with (or be
    // served from) an exact run at the same seed. keep=1.0 leaves the
    // seed — and thus every legacy key — untouched. Likewise a trained
    // learned prior changes which pairs the draft stage measures, so
    // its content hash gets its own seed fold — but only when the draft
    // stage actually runs (keep < 1.0); at keep=1.0 the prior is inert
    // and the seed (and every legacy key) is untouched.
    let keep = if options.speculative_keep < 1.0 { options.speculative_keep } else { 1.0 };
    let model_hash = if keep < 1.0 { options.cost_prior.content_hash() } else { 0 };
    let seed = estimator_seed(speculative_seed(seed, keep), model_hash);

    let (plan, candidates) = if keep >= 1.0 {
        // Exact path: dispatch the whole candidate sweep through the
        // cached executor at once — dedup first, parallel measurement
        // of unique misses, ledger charged per miss (sequential device
        // semantics).
        let (candidate_jobs, candidate_contents) = plan.candidate_jobs(target);
        let candidates = measure_pairs_cached_precomputed(
            &candidate_jobs,
            &candidate_contents,
            profile,
            seed,
            cache,
            &mut ledger,
        );
        (plan, candidates)
    } else {
        let mut exec = |jobs: &[(&Kernel, &Schedule)], contents: &[u64]| {
            measure_pairs_cached_precomputed(jobs, contents, profile, seed, cache, &mut ledger)
        };
        speculative_sweep(target, &plan, profile, keep, &options.cost_prior, &mut exec)
    };

    let (default_jobs, default_contents) = plan.default_jobs(target);
    let defaults_batch = measure_pairs_cached_precomputed(
        &default_jobs,
        &default_contents,
        profile,
        seed,
        cache,
        &mut ledger,
    );

    assemble_transfer_result(
        target,
        &plan,
        candidates,
        defaults_batch,
        ledger,
        profile,
        source_label,
    )
}

/// Assemble a [`TransferResult`] from the measured candidate/default
/// batches — the shared back half of every sweep executor (the
/// single-cache engine above and the service layer's sharded executor),
/// so selection and cold-ledger semantics cannot drift between them.
pub fn assemble_transfer_result(
    target: &ModelGraph,
    plan: &SweepPlan,
    candidates: CachedBatch,
    defaults_batch: CachedBatch,
    ledger: Ledger,
    profile: &DeviceProfile,
    source_label: &str,
) -> TransferResult {
    // Cold-equivalent accounting: charge the first occurrence of every
    // unique pair, in the order a fresh-cache run would have measured
    // them. This reproduces a standalone run's ledger exactly (same
    // charges, same f64 summation order), so reported search times do
    // not depend on what previously warmed a shared cache.
    let mut cold_ledger = Ledger::new();
    let mut cold_seen: HashSet<u64> = HashSet::new();
    let charged_pairs = candidates
        .keys
        .iter()
        .zip(&candidates.outcomes)
        .chain(defaults_batch.keys.iter().zip(&defaults_batch.outcomes));
    for (key, outcome) in charged_pairs {
        if cold_seen.insert(*key) {
            match outcome.runtime() {
                Some(t) => cold_ledger.charge_measure(profile, t),
                None => cold_ledger.charge_compile_fail(profile),
            }
        }
    }
    let outcomes = candidates.outcomes;
    let default_outcomes = defaults_batch.outcomes;

    // Per-kernel selection by *standalone* time (paper §5.5 explains
    // both TT and Ansor assume kernel independence here).
    let mut sweeps: Vec<KernelSweep> = Vec::with_capacity(target.kernels.len());
    for (ki, span) in plan.spans.iter().enumerate() {
        let untuned_s = default_outcomes[ki]
            .runtime()
            .expect("default schedule always applies");
        let mut sweep = KernelSweep {
            kernel: ki,
            outcomes: Vec::with_capacity(span.len()),
            untuned_s,
            chosen: None,
            chosen_s: untuned_s,
            chosen_schedule: None,
        };
        for ji in span.clone() {
            let job = &plan.jobs[ji];
            let rt = outcomes[ji].runtime();
            sweep.outcomes.push((job.record, rt));
            if let Some(t) = rt {
                if t < sweep.chosen_s {
                    sweep.chosen_s = t;
                    sweep.chosen = Some(job.record);
                    // Keep the schedule actually measured (which may be a
                    // cross-class *adapted* variant of the record).
                    sweep.chosen_schedule = Some(job.schedule.clone());
                }
            }
        }
        sweeps.push(sweep);
    }

    // Compile the full model with the winners and time it end-to-end
    // (deterministic, with inter-kernel boundary effects).
    let tuned_model_s = model_time(target, profile, |k| match &sweeps[k].chosen_schedule {
        Some(s) => s.clone(),
        None => plan.defaults[k].clone(),
    });
    let untuned_model_s = untuned_model_time(target, profile);

    TransferResult {
        target: target.name.clone(),
        source: source_label.to_string(),
        sweeps,
        ledger,
        cold_ledger,
        untuned_model_s,
        tuned_model_s,
    }
}

/// Convenience: one-to-one transfer from a single source model's
/// schedules (the paper's default mode).
pub fn transfer_tune_one_to_one(
    target: &ModelGraph,
    store: &ScheduleStore,
    source_model: &str,
    profile: &DeviceProfile,
    seed: u64,
) -> TransferResult {
    let slice = store.of_model(source_model);
    transfer_tune(target, &slice, profile, source_model, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::KernelBuilder;

    fn quick_opts() -> TuneOptions {
        TuneOptions { trials: 96, batch_size: 16, population: 32, generations: 2, ..Default::default() }
    }

    /// Source: two well-tuned dense kernels; target: a different-size
    /// dense kernel of the same class.
    fn dense_setup() -> (ModelGraph, ModelGraph, ScheduleStore) {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut src = ModelGraph::new("Source");
        src.push(KernelBuilder::dense(512, 512, 512, &[]));
        src.push(KernelBuilder::dense(1024, 1024, 1024, &[]));
        let res = tune_model(&src, &prof, &quick_opts());
        let mut store = ScheduleStore::new();
        store.add_tuning(&src, &res);

        let mut tgt = ModelGraph::new("Target");
        tgt.push(KernelBuilder::dense(768, 768, 768, &[]));
        tgt.push(KernelBuilder::dense(256, 256, 256, &[]));
        (src, tgt, store)
    }

    #[test]
    fn transfer_improves_target() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert!(
            res.speedup() > 1.0,
            "transfer should beat untuned default: {}",
            res.speedup()
        );
        assert!(res.search_time_s() > 0.0);
        assert_eq!(res.pairs_evaluated(), 4); // 2 kernels x 2 schedules
    }

    #[test]
    fn sweep_plan_enumerates_kernel_major_spans() {
        let (_, tgt, store) = dense_setup();
        let plan = SweepPlan::build(&tgt, &store, &TransferOptions::default());
        assert_eq!(plan.candidate_pairs(), 4);
        assert_eq!(plan.spans, vec![0..2, 2..4]);
        assert_eq!(plan.defaults.len(), 2);
        for (ki, span) in plan.spans.iter().enumerate() {
            for ji in span.clone() {
                assert_eq!(plan.jobs[ji].kernel, ki);
                assert!(!plan.jobs[ji].adapted);
            }
        }
        // The per-record hash memoization must agree with hashing each
        // pair from scratch.
        for job in &plan.jobs {
            assert_eq!(
                job.content,
                content_key(&tgt.kernels[job.kernel], &job.schedule),
                "memoized content key drifted"
            );
        }
    }

    #[test]
    fn no_compatible_class_keeps_default() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, _, store) = dense_setup();
        let mut tgt = ModelGraph::new("ConvOnly");
        tgt.push(KernelBuilder::conv2d(1, 32, 28, 28, 32, 3, 3, 1, 1, &[]));
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert!(res.sweeps[0].outcomes.is_empty());
        assert!(res.sweeps[0].chosen.is_none());
        assert!((res.speedup() - 1.0).abs() < 0.05);
    }

    /// Duplicated records are the common case in pooled stores (Fig 8).
    /// The plan dedups them before dispatch: the pair matrix doubles but
    /// the device pays nothing extra. (This replaces the pre-cache
    /// assertion that more records always cost more search time — that
    /// is exactly the waste the measurement cache exists to remove.)
    #[test]
    fn duplicate_records_cost_no_extra_search_time() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let single = transfer_tune(&tgt, &store, &prof, "Source", 3);
        let mut doubled = store.clone();
        doubled.merge(&store);
        let merged = transfer_tune(&tgt, &doubled, &prof, "mixed", 3);
        assert_eq!(merged.pairs_evaluated(), 2 * single.pairs_evaluated());
        assert_eq!(
            merged.search_time_s(),
            single.search_time_s(),
            "identical pairs must be measured once"
        );
        assert_eq!(merged.tuned_model_s, single.tuned_model_s);
    }

    #[test]
    fn search_time_scales_with_distinct_pairs() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let small = transfer_tune(&tgt, &store, &prof, "Source", 3);
        // Grow the store with *distinct* schedules (different unroll
        // budgets keep them applicable but content-distinct).
        let mut grown = store.clone();
        let mut extra = store.clone();
        for r in &mut extra.records {
            let mut s = r.schedule.clone();
            s.unroll_max = s.unroll_max.wrapping_add(3);
            r.set_schedule(s);
        }
        grown.merge(&extra);
        let large = transfer_tune(&tgt, &grown, &prof, "mixed", 3);
        assert!(large.pairs_evaluated() > small.pairs_evaluated());
        assert!(large.search_time_s() > small.search_time_s());
    }

    #[test]
    fn selection_never_worse_than_default_standalone() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        for s in &res.sweeps {
            assert!(s.chosen_s <= s.untuned_s + 1e-12);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let a = transfer_tune(&tgt, &store, &prof, "Source", 3);
        let b = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert_eq!(a.tuned_model_s, b.tuned_model_s);
        assert_eq!(a.ledger.seconds, b.ledger.seconds);
    }

    #[test]
    fn warm_cache_is_transparent_and_free() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let off = transfer_tune(&tgt, &store, &prof, "Source", 3);

        let mut cache = crate::coordinator::MeasureCache::new();
        let opts = TransferOptions::default();
        let cold =
            transfer_tune_cached(&tgt, &store, &prof, "Source", 3, &opts, &mut cache);
        assert_eq!(cold.tuned_model_s, off.tuned_model_s, "cache-on == cache-off");
        assert_eq!(cold.ledger.seconds, off.ledger.seconds);

        let warm =
            transfer_tune_cached(&tgt, &store, &prof, "Source", 3, &opts, &mut cache);
        assert_eq!(warm.tuned_model_s, off.tuned_model_s, "warm == cold bit-for-bit");
        assert_eq!(warm.ledger.seconds, 0.0, "every pair is a hit");
        assert_eq!(warm.ledger.measurements, 0);
        // The standalone (cold-equivalent) search time is reporting-
        // stable: identical whether the cache was warm or cold, and
        // equal to what the run actually charged when cold.
        assert_eq!(warm.standalone_search_time_s(), cold.standalone_search_time_s());
        assert_eq!(cold.standalone_search_time_s(), cold.search_time_s());
        assert_eq!(warm.amortized_saved_s(), warm.standalone_search_time_s());
    }

    /// Grow `store` to `n` content-distinct records per original record
    /// (different unroll budgets keep them applicable but distinct), so
    /// a span is big enough for the draft stage to leave warmup.
    fn widen_store(store: &ScheduleStore, copies: usize) -> ScheduleStore {
        let mut grown = store.clone();
        for c in 1..copies {
            let mut extra = store.clone();
            for r in &mut extra.records {
                let mut s = r.schedule.clone();
                for _ in 0..c {
                    s.unroll_max = s.unroll_max.wrapping_add(3);
                }
                r.set_schedule(s);
            }
            grown.merge(&extra);
        }
        grown
    }

    #[test]
    fn speculative_transfer_prunes_pairs_and_stays_deterministic() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let wide = widen_store(&store, 8); // 16 records -> 16-candidate spans
        let exact = transfer_tune(&tgt, &wide, &prof, "mixed", 3);
        let opts = TransferOptions { speculative_keep: 0.25, ..Default::default() };
        let a = transfer_tune_with(&tgt, &wide, &prof, "mixed", 3, &opts);
        let b = transfer_tune_with(&tgt, &wide, &prof, "mixed", 3, &opts);
        assert_eq!(a.tuned_model_s.to_bits(), b.tuned_model_s.to_bits(), "keep is deterministic");
        assert_eq!(a.ledger.seconds.to_bits(), b.ledger.seconds.to_bits());
        // The first span warms the model up in full; later spans prune,
        // so the pruned pair matrix is a strict subset.
        assert!(
            a.pairs_evaluated() < exact.pairs_evaluated(),
            "draft stage never pruned: {} vs {}",
            a.pairs_evaluated(),
            exact.pairs_evaluated()
        );
        assert!(a.standalone_search_time_s() < exact.standalone_search_time_s());
        // Selection still never loses to the untuned default.
        for s in &a.sweeps {
            assert!(s.chosen_s <= s.untuned_s + 1e-12);
        }
    }

    #[test]
    fn speculative_runs_use_a_separate_cache_key_space() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let mut cache = crate::coordinator::MeasureCache::new();
        let exact = transfer_tune_cached(
            &tgt, &store, &prof, "Source", 3, &TransferOptions::default(), &mut cache,
        );
        assert!(exact.ledger.seconds > 0.0);
        // Same seed, pruned keep: must MISS the exact run's entries.
        let opts = TransferOptions { speculative_keep: 0.5, ..Default::default() };
        let spec = transfer_tune_cached(&tgt, &store, &prof, "Source", 3, &opts, &mut cache);
        assert!(
            spec.ledger.seconds > 0.0,
            "pruned run must miss, never collide with exact-path entries"
        );
        // Same keep again: fully warm, bit-identical reply.
        let warm = transfer_tune_cached(&tgt, &store, &prof, "Source", 3, &opts, &mut cache);
        assert_eq!(warm.ledger.seconds, 0.0, "same-keep rerun is fully warm");
        assert_eq!(warm.tuned_model_s.to_bits(), spec.tuned_model_s.to_bits());
    }

    /// A trained prior fit on synthetic pairs — the invariants under
    /// test are keying and determinism, not prediction quality.
    fn synth_prior(seed: u64) -> CostModel {
        use crate::autosched::{fit_pairs, TrainingPair};
        let mut rng = crate::util::rng::Rng::new(seed);
        let pairs: Vec<TrainingPair> = (0..96)
            .map(|i| {
                let mut x = [0.0; NUM_FEATURES];
                for v in x.iter_mut() {
                    *v = rng.f64() * 8.0;
                }
                TrainingPair {
                    content: (i as u64).wrapping_mul(0x9E37_79B9) ^ seed,
                    y: x[2] - 0.5 * x[9],
                    x,
                }
            })
            .collect();
        let m = fit_pairs(&pairs);
        assert!(m.is_trained());
        m
    }

    #[test]
    fn trained_prior_is_deterministic_keyed_and_inert_at_keep_one() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, tgt, store) = dense_setup();
        let wide = widen_store(&store, 8);
        let prior = synth_prior(41);

        // keep=1.0: the prior is inert — byte-identical to the exact
        // path, same cache entries.
        let exact = transfer_tune(&tgt, &wide, &prof, "mixed", 3);
        let inert = transfer_tune_with(
            &tgt,
            &wide,
            &prof,
            "mixed",
            3,
            &TransferOptions { cost_prior: prior.clone(), ..Default::default() },
        );
        assert_eq!(inert.tuned_model_s.to_bits(), exact.tuned_model_s.to_bits());
        assert_eq!(inert.ledger.seconds.to_bits(), exact.ledger.seconds.to_bits());

        // keep<1.0: deterministic, and keyed apart from the untrained-
        // prior draft run at the same seed and keep.
        let opts = TransferOptions {
            speculative_keep: 0.25,
            cost_prior: prior.clone(),
            ..Default::default()
        };
        let a = transfer_tune_with(&tgt, &wide, &prof, "mixed", 3, &opts);
        let b = transfer_tune_with(&tgt, &wide, &prof, "mixed", 3, &opts);
        assert_eq!(a.tuned_model_s.to_bits(), b.tuned_model_s.to_bits());
        assert_eq!(a.ledger.seconds.to_bits(), b.ledger.seconds.to_bits());
        // The prior skips warmup, so even the first span is pruned.
        assert!(a.pairs_evaluated() < exact.pairs_evaluated());

        let mut cache = crate::coordinator::MeasureCache::new();
        let primed = transfer_tune_cached(&tgt, &wide, &prof, "mixed", 3, &opts, &mut cache);
        assert!(primed.ledger.seconds > 0.0);
        let plain_draft = TransferOptions { speculative_keep: 0.25, ..Default::default() };
        let crossed =
            transfer_tune_cached(&tgt, &wide, &prof, "mixed", 3, &plain_draft, &mut cache);
        assert!(
            crossed.ledger.seconds > 0.0,
            "trained-prior entries must never serve an untrained-prior run"
        );
        // Same prior again: fully warm.
        let warm = transfer_tune_cached(&tgt, &wide, &prof, "mixed", 3, &opts, &mut cache);
        assert_eq!(warm.ledger.seconds, 0.0);
        assert_eq!(warm.tuned_model_s.to_bits(), primed.tuned_model_s.to_bits());
    }

    #[test]
    fn invalid_pairs_show_up_when_factors_exceed_extents() {
        let prof = DeviceProfile::xeon_e5_2620();
        let (_, _, store) = dense_setup();
        // Tiny target: schedules tuned on 512/1024 with inner products
        // beyond 8 cannot apply.
        let mut tgt = ModelGraph::new("Tiny");
        tgt.push(KernelBuilder::dense(8, 8, 8, &[]));
        let res = transfer_tune(&tgt, &store, &prof, "Source", 3);
        assert!(res.invalid_pairs() > 0, "expected some -1 entries");
    }
}

#[cfg(test)]
mod cross_class_tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::ir::{KernelBuilder, OpKind};

    /// ResNet18's class-F kernels have no same-class schedules in a
    /// ResNet50 store (paper §4.3); cross-class adaptation (the §4.2
    /// future-work extension) lets class-E/G schedules cover them.
    #[test]
    fn cross_class_covers_resnet18_class_f() {
        let prof = DeviceProfile::xeon_e5_2620();
        let src = crate::models::resnet::resnet50();
        let tgt = crate::models::resnet::resnet18();
        let res = tune_model(
            &src,
            &prof,
            &TuneOptions { trials: 300, batch_size: 16, population: 32, generations: 2, seed: 5, ..Default::default() },
        );
        let mut store = ScheduleStore::new();
        store.add_tuning(&src, &res);

        let plain = transfer_tune(&tgt, &store, &prof, "ResNet50", 5);
        let cross = transfer_tune_with(
            &tgt,
            &store,
            &prof,
            "ResNet50",
            5,
            &TransferOptions { cross_class: true, ..Default::default() },
        );
        // Class-F kernels get candidates only in cross-class mode.
        let f = tgt.kernels_of_class("conv2d_bias_add_relu");
        assert!(!f.is_empty());
        for &fk in &f {
            assert!(plain.sweeps[fk].outcomes.is_empty());
            assert!(!cross.sweeps[fk].outcomes.is_empty(), "F kernel {fk} uncovered");
        }
        // More candidates means search costs more; and because pair
        // noise is content-derived, the shared same-class candidates
        // measure identically in both runs, so a superset of candidates
        // can only improve (or tie) each kernel's pick.
        assert!(cross.pairs_evaluated() > plain.pairs_evaluated());
        for (a, b) in cross.sweeps.iter().zip(&plain.sweeps) {
            assert!(a.chosen_s <= b.chosen_s + 1e-12);
        }
    }

    #[test]
    fn cross_class_never_crosses_anchors() {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut src = crate::ir::ModelGraph::new("DenseSrc");
        src.push(KernelBuilder::dense(512, 512, 512, &[]));
        let res = tune_model(
            &src,
            &prof,
            &TuneOptions { trials: 48, batch_size: 16, population: 32, generations: 2, seed: 5, ..Default::default() },
        );
        let mut store = ScheduleStore::new();
        store.add_tuning(&src, &res);

        let mut tgt = crate::ir::ModelGraph::new("ConvTgt");
        tgt.push(KernelBuilder::conv2d(1, 32, 28, 28, 32, 3, 3, 1, 1, &[OpKind::BiasAdd, OpKind::Relu]));
        let cross = transfer_tune_with(
            &tgt,
            &store,
            &prof,
            "DenseSrc",
            5,
            &TransferOptions { cross_class: true, ..Default::default() },
        );
        assert!(cross.sweeps[0].outcomes.is_empty(), "dense must not adapt onto conv");
    }
}
