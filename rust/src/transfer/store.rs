//! The schedule store: persisted auto-schedules indexed by kernel class.
//!
//! An Ansor tuning log keyed by workload id only helps *identical*
//! kernels. The store relaxes the key to the class signature (paper
//! §4.2) and keeps schedules in shape-relative form, so any record of a
//! class can be tried on any kernel of that class. Records remember
//! their provenance (source model + source kernel shapes + measured
//! cost) for reporting and for the mixed-pool experiments.

use crate::autosched::TuningResult;
use crate::ir::ModelGraph;
use crate::sched::{serialize, Schedule};
use crate::util::json::{self, Json};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global count of [`StoreRecord`] deep clones since process start.
///
/// Cloning a record copies its schedule and provenance strings — cheap
/// in isolation, but PR 2's serving layer cloned a store slice *per
/// session*, which this counter exists to keep dead: the session hot
/// path now composes [`StoreView`]s over `Arc`'d sub-stores and must
/// clone **zero** records. `benches/hotpath.rs` and the service tests
/// assert the delta across sessions is 0.
static STORE_RECORD_CLONES: AtomicU64 = AtomicU64::new(0);

/// Read the clone counter (see [`STORE_RECORD_CLONES`]'s invariant).
pub fn store_record_clones() -> u64 {
    STORE_RECORD_CLONES.load(Ordering::Relaxed)
}

#[derive(Debug)]
pub struct StoreRecord {
    /// Model the schedule was tuned on (e.g. "ResNet50").
    pub source_model: String,
    /// Class signature (e.g. "conv2d_bias_relu").
    pub class_sig: String,
    /// Source kernel's display shapes (provenance / Fig 4 labels).
    pub source_input_shape: Vec<u64>,
    /// Measured standalone cost on the source kernel, seconds.
    pub source_cost_s: f64,
    pub schedule: Schedule,
    /// [`serialize::canonical_hash`] of `schedule`, memoized at
    /// construction: one serialization per record *lifetime* instead of
    /// one per sweep plan (sessions build a plan per request — this is
    /// the `open_session` hot path). Private so every construction path
    /// goes through [`StoreRecord::new`]; replace the schedule via
    /// [`StoreRecord::set_schedule`] (direct mutation of the pub
    /// `schedule` field would stale the memo — sweep planners
    /// debug-assert against that).
    sched_hash: u64,
}

impl Clone for StoreRecord {
    fn clone(&self) -> StoreRecord {
        // Counted so the serving layer can prove its hot path is
        // zero-copy (see `store_record_clones`).
        STORE_RECORD_CLONES.fetch_add(1, Ordering::Relaxed);
        StoreRecord {
            source_model: self.source_model.clone(),
            class_sig: self.class_sig.clone(),
            source_input_shape: self.source_input_shape.clone(),
            source_cost_s: self.source_cost_s,
            schedule: self.schedule.clone(),
            sched_hash: self.sched_hash,
        }
    }
}

impl StoreRecord {
    /// Construct a record, memoizing the schedule's canonical hash (the
    /// only place it is ever computed).
    pub fn new(
        source_model: impl Into<String>,
        class_sig: impl Into<String>,
        source_input_shape: Vec<u64>,
        source_cost_s: f64,
        schedule: Schedule,
    ) -> StoreRecord {
        let sched_hash = serialize::canonical_hash(&schedule);
        StoreRecord {
            source_model: source_model.into(),
            class_sig: class_sig.into(),
            source_input_shape,
            source_cost_s,
            schedule,
            sched_hash,
        }
    }

    /// The memoized [`serialize::canonical_hash`] of this record's
    /// schedule — what sweep planners fold into cache content keys
    /// without re-serializing the schedule per plan.
    pub fn schedule_hash(&self) -> u64 {
        self.sched_hash
    }

    /// Replace the schedule, refreshing the memoized hash.
    pub fn set_schedule(&mut self, schedule: Schedule) {
        self.sched_hash = serialize::canonical_hash(&schedule);
        self.schedule = schedule;
    }

    /// Short label like "E3 (ResNet50)" used in Fig 4.
    pub fn label(&self, letter: &str, ordinal: usize) -> String {
        format!("{letter}{ordinal} ({})", self.source_model)
    }
}

/// A borrowed, zero-copy view over store records — what sweep planners
/// consume ([`SweepPlan::build_view`](crate::transfer::SweepPlan)).
///
/// Views let the serving layer compose per-source `Arc` sub-stores into
/// one sweepable record list without cloning a single [`StoreRecord`]:
/// a view is a `Vec` of references, so building one per session costs a
/// pointer array, never a schedule copy. Record indices reported by a
/// sweep (`KernelSweep::outcomes`, `chosen`) index into `records`.
#[derive(Clone, Debug, Default)]
pub struct StoreView<'a> {
    pub records: Vec<&'a StoreRecord>,
}

impl<'a> StoreView<'a> {
    /// View over every record of one store, in store order.
    pub fn of_store(store: &'a ScheduleStore) -> StoreView<'a> {
        StoreView { records: store.records.iter().collect() }
    }

    /// Concatenate several stores into one view, in iteration order.
    /// Concatenating per-source sub-stores in source-name order
    /// reproduces the merged store's total record order exactly
    /// (`source_model` is the leading sort key of
    /// [`ScheduleStore::add_tuning`]).
    pub fn concat<I: IntoIterator<Item = &'a ScheduleStore>>(stores: I) -> StoreView<'a> {
        StoreView { records: stores.into_iter().flat_map(|s| s.records.iter()).collect() }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }
}

#[derive(Clone, Debug, Default)]
pub struct ScheduleStore {
    pub records: Vec<StoreRecord>,
}

impl ScheduleStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Ingest the best schedules of a tuning run.
    pub fn add_tuning(&mut self, graph: &ModelGraph, result: &TuningResult) {
        for (&kidx, best) in &result.best {
            let k = &graph.kernels[kidx];
            self.records.push(StoreRecord::new(
                graph.name.clone(),
                k.class_signature(),
                k.input_shape.clone(),
                best.cost_s,
                best.schedule.clone(),
            ));
        }
        // Deterministic order regardless of HashMap iteration. The
        // canonical schedule serialization breaks exact (model, class,
        // shape, cost) ties so the order is total — a warm-started zoo
        // rebuilding this store from persisted tunings must reproduce
        // it byte-for-byte in any process. The memoized canonical hash
        // short-circuits the overwhelmingly common tie (identical
        // schedules, e.g. duplicated pool records) to Equal without
        // serializing; distinct schedules still compare by their
        // serialization, so the order is byte-for-byte the one the
        // golden JSONL fixture pins.
        self.records.sort_by(|a, b| {
            (&a.source_model, &a.class_sig, &a.source_input_shape)
                .cmp(&(&b.source_model, &b.class_sig, &b.source_input_shape))
                .then_with(|| a.source_cost_s.total_cmp(&b.source_cost_s))
                .then_with(|| {
                    if a.sched_hash == b.sched_hash {
                        // Hash equality stands in for serialization
                        // equality — the same trust the measurement
                        // cache already places in the canonical hash
                        // (a collision there serves a wrong runtime).
                        // Debug builds keep the totality claim honest.
                        debug_assert_eq!(
                            serialize::to_string(&a.schedule),
                            serialize::to_string(&b.schedule),
                            "canonical-hash collision between distinct schedules"
                        );
                        std::cmp::Ordering::Equal
                    } else {
                        serialize::to_string(&a.schedule).cmp(&serialize::to_string(&b.schedule))
                    }
                })
        });
    }

    /// Records of one class (transfer candidates for a kernel).
    pub fn of_class(&self, sig: &str) -> Vec<&StoreRecord> {
        self.records.iter().filter(|r| r.class_sig == sig).collect()
    }

    /// Records restricted to one source model ("one-to-one" mode).
    pub fn of_model(&self, model: &str) -> ScheduleStore {
        ScheduleStore {
            records: self.records.iter().filter(|r| r.source_model == model).cloned().collect(),
        }
    }

    /// Number of schedules available for a class from a given model —
    /// the |W_Tc| of the paper's Eq. 1.
    pub fn class_count(&self, model: &str, sig: &str) -> usize {
        self.records
            .iter()
            .filter(|r| r.source_model == model && r.class_sig == sig)
            .count()
    }

    pub fn source_models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.records.iter().map(|r| r.source_model.clone()).collect();
        v.sort();
        v.dedup();
        v
    }

    pub fn merge(&mut self, other: &ScheduleStore) {
        self.records.extend(other.records.iter().cloned());
    }

    // ---- persistence (JSON lines, Ansor-log style) ----------------------

    /// Serialize to the canonical JSONL text (one record per line,
    /// sorted-key compact JSON). This exact byte format is pinned by the
    /// golden fixture `rust/tests/golden/schedule_store.jsonl` — a
    /// deliberate change must regenerate the fixture and bump the
    /// artifact-store format version (`crate::artifact`).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            let j = Json::obj(vec![
                ("model", Json::str(&r.source_model)),
                ("class", Json::str(&r.class_sig)),
                (
                    "input_shape",
                    Json::arr(r.source_input_shape.iter().map(|&x| Json::num(x as f64))),
                ),
                ("cost_s", Json::num(r.source_cost_s)),
                ("schedule", serialize::to_json(&r.schedule)),
            ]);
            out.push_str(&j.to_compact());
            out.push('\n');
        }
        out
    }

    /// Parse the JSONL text produced by [`ScheduleStore::to_jsonl`].
    /// Errors carry the 1-based line number (prefixed with `context` —
    /// a path or artifact label) because store files are hand-editable.
    pub fn from_jsonl(text: &str, context: &str) -> anyhow::Result<ScheduleStore> {
        let mut records = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = json::parse(line)
                .map_err(|e| anyhow::anyhow!("{context}:{}: {e}", lineno + 1))?;
            records.push(StoreRecord::new(
                j.req("model")?.as_str().unwrap_or_default().to_string(),
                j.req("class")?.as_str().unwrap_or_default().to_string(),
                j.req("input_shape")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|v| v.as_f64().map(|x| x as u64))
                    .collect(),
                j.req("cost_s")?.as_f64().unwrap_or(0.0),
                serialize::from_json(j.req("schedule")?)?,
            ));
        }
        Ok(ScheduleStore { records })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_jsonl())?;
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<ScheduleStore> {
        let text = std::fs::read_to_string(path)?;
        Self::from_jsonl(&text, &path.display().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::device::DeviceProfile;
    use crate::ir::KernelBuilder;

    fn small_store() -> (ModelGraph, ScheduleStore) {
        let mut g = ModelGraph::new("SrcModel");
        g.push(KernelBuilder::dense(256, 256, 256, &[]));
        g.push(KernelBuilder::dense(512, 512, 512, &[]));
        let prof = DeviceProfile::xeon_e5_2620();
        let res = tune_model(
            &g,
            &prof,
            &TuneOptions { trials: 48, batch_size: 16, population: 32, generations: 2, ..Default::default() },
        );
        let mut store = ScheduleStore::new();
        store.add_tuning(&g, &res);
        (g, store)
    }

    #[test]
    fn ingests_tuning_results_by_class() {
        let (_, store) = small_store();
        assert_eq!(store.of_class("dense").len(), 2);
        assert!(store.of_class("conv2d").is_empty());
        assert_eq!(store.class_count("SrcModel", "dense"), 2);
    }

    #[test]
    fn roundtrips_through_disk() {
        let (_, store) = small_store();
        let path = std::env::temp_dir().join("tt_store_test.jsonl");
        store.save(&path).unwrap();
        let back = ScheduleStore::load(&path).unwrap();
        assert_eq!(back.records.len(), store.records.len());
        for (a, b) in back.records.iter().zip(&store.records) {
            assert_eq!(a.schedule, b.schedule);
            assert_eq!(a.class_sig, b.class_sig);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn views_borrow_without_cloning_records() {
        // NOTE: the clone counter is process-global and unit tests run
        // in parallel, so this test asserts *aliasing* (which implies
        // zero copies) rather than counter deltas; the exact
        // zero-clone-per-session proof lives in `benches/hotpath.rs`,
        // which owns its whole process.
        let (_, store) = small_store();
        let view = StoreView::of_store(&store);
        assert_eq!(view.len(), store.records.len());
        assert!(!view.is_empty());
        for (v, r) in view.records.iter().zip(&store.records) {
            assert!(std::ptr::eq(*v, r), "view must alias the store's records");
        }
        let cat = StoreView::concat([&store, &store]);
        assert_eq!(cat.len(), 2 * store.records.len());
        assert!(std::ptr::eq(cat.records[0], &store.records[0]));
        // The counter observes real clones (monotone, so >= is safe
        // even with concurrent tests).
        let before = store_record_clones();
        let _dup = store.records[0].clone();
        assert!(store_record_clones() >= before + 1, "counter must count real clones");
    }

    #[test]
    fn schedule_hash_is_memoized_and_refreshed() {
        let (_, store) = small_store();
        for r in &store.records {
            assert_eq!(
                r.schedule_hash(),
                serialize::canonical_hash(&r.schedule),
                "memoized hash must equal a fresh canonical hash"
            );
        }
        let mut r = store.records[0].clone();
        let mut s = r.schedule.clone();
        s.unroll_max = s.unroll_max.wrapping_add(8);
        r.set_schedule(s);
        assert_eq!(
            r.schedule_hash(),
            serialize::canonical_hash(&r.schedule),
            "set_schedule must refresh the memo"
        );
        assert_ne!(r.schedule_hash(), store.records[0].schedule_hash());
    }

    #[test]
    fn merge_and_filter_by_model() {
        let (_, a) = small_store();
        let mut b = a.clone();
        for r in &mut b.records {
            r.source_model = "Other".into();
        }
        let mut pool = a.clone();
        pool.merge(&b);
        assert_eq!(pool.source_models(), vec!["Other".to_string(), "SrcModel".to_string()]);
        assert_eq!(pool.of_model("Other").records.len(), a.records.len());
    }
}
