//! Schedule-pool sampling — the paper's §4.4.2/§5.5 proposed extension.
//!
//! "In situations with many kernel/schedule pairs, we could reduce the
//! search time by sampling a subset of schedules, either randomly or
//! using some other selection heuristic."
//!
//! Two strategies are implemented:
//!
//! * [`sample_random`]: uniform subset per class (the paper's baseline
//!   suggestion);
//! * [`sample_by_source_quality`]: keep each class's k records whose
//!   *source* kernels saw the largest improvement during tuning — the
//!   "schedules that are less likely to improve performance" filter.

use super::store::ScheduleStore;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Uniformly sample at most `k` schedules per kernel class.
pub fn sample_random(store: &ScheduleStore, k: usize, seed: u64) -> ScheduleStore {
    let mut rng = Rng::new(seed);
    let mut by_class: HashMap<&str, Vec<usize>> = HashMap::new();
    for (i, r) in store.records.iter().enumerate() {
        by_class.entry(r.class_sig.as_str()).or_default().push(i);
    }
    let mut keep: Vec<usize> = Vec::new();
    let mut classes: Vec<&&str> = by_class.keys().collect::<Vec<_>>();
    classes.sort(); // deterministic iteration order
    for class in classes {
        let mut idxs = by_class[*class].clone();
        rng.shuffle(&mut idxs);
        idxs.truncate(k);
        keep.extend(idxs);
    }
    keep.sort_unstable();
    ScheduleStore { records: keep.into_iter().map(|i| store.records[i].clone()).collect() }
}

/// Keep the `k` records per class with the *fastest source-side cost per
/// flop-scale* — a proxy for schedule quality that needs no new
/// measurements (source cost is already in the store).
pub fn sample_by_source_quality(store: &ScheduleStore, k: usize) -> ScheduleStore {
    let mut by_class: HashMap<&str, Vec<usize>> = HashMap::new();
    for (i, r) in store.records.iter().enumerate() {
        by_class.entry(r.class_sig.as_str()).or_default().push(i);
    }
    let mut keep: Vec<usize> = Vec::new();
    for idxs in by_class.values() {
        let mut scored: Vec<(f64, usize)> = idxs
            .iter()
            .map(|&i| {
                let r = &store.records[i];
                // Normalize source cost by the source kernel's data scale
                // so big kernels are not unfairly "slow".
                let scale: f64 = r.source_input_shape.iter().map(|&x| x as f64).product::<f64>().max(1.0);
                (r.source_cost_s / scale, i)
            })
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        keep.extend(scored.into_iter().take(k).map(|(_, i)| i));
    }
    keep.sort_unstable();
    ScheduleStore { records: keep.into_iter().map(|i| store.records[i].clone()).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Schedule;
    use crate::transfer::store::StoreRecord;

    fn store_with(n_per_class: usize) -> ScheduleStore {
        let k = crate::ir::KernelBuilder::dense(64, 64, 64, &[]);
        let conv = crate::ir::KernelBuilder::conv2d(1, 8, 8, 8, 8, 3, 3, 1, 1, &[]);
        let mut s = ScheduleStore::new();
        for i in 0..n_per_class {
            s.records.push(StoreRecord::new(
                format!("M{i}"),
                "dense",
                vec![64, 64],
                1e-3 * (i + 1) as f64,
                Schedule::untuned_default(&k),
            ));
            s.records.push(StoreRecord::new(
                format!("M{i}"),
                "conv2d",
                vec![1, 8, 8, 8],
                1e-3 * (n_per_class - i) as f64,
                Schedule::untuned_default(&conv),
            ));
        }
        s
    }

    #[test]
    fn random_sampling_caps_per_class() {
        let s = store_with(10);
        let sub = sample_random(&s, 3, 42);
        assert_eq!(sub.of_class("dense").len(), 3);
        assert_eq!(sub.of_class("conv2d").len(), 3);
    }

    #[test]
    fn random_sampling_is_deterministic() {
        let s = store_with(10);
        let a = sample_random(&s, 3, 42);
        let b = sample_random(&s, 3, 42);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.source_model, y.source_model);
        }
    }

    #[test]
    fn quality_sampling_keeps_fastest_sources() {
        let s = store_with(10);
        let sub = sample_by_source_quality(&s, 2);
        let dense: Vec<_> = sub.of_class("dense").iter().map(|r| r.source_cost_s).collect();
        assert_eq!(dense.len(), 2);
        // Fastest two dense sources are 1ms and 2ms.
        assert!(dense.iter().all(|&c| c <= 2e-3 + 1e-12));
    }

    #[test]
    fn sampling_more_than_available_keeps_all() {
        let s = store_with(2);
        assert_eq!(sample_random(&s, 10, 1).records.len(), s.records.len());
        assert_eq!(sample_by_source_quality(&s, 10).records.len(), s.records.len());
    }
}
