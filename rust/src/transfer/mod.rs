//! Transfer-tuning: the paper's contribution.
//!
//! Reuse auto-schedules across kernels of the same *class* (same fused
//! op sequence, any data shape): build a [`ScheduleStore`] from pre-tuned
//! models, pick a tuning model with the Eq. 1 [`heuristic`], sweep every
//! compatible kernel/schedule pair standalone, and compile the target
//! with the per-kernel winners — minutes of search instead of hours of
//! auto-scheduling.

pub mod engine;
pub mod heuristic;
pub mod pairwise;
pub mod sampling;
pub mod store;

pub use engine::{
    assemble_transfer_result, transfer_tune, transfer_tune_cached, transfer_tune_one_to_one,
    transfer_tune_with, KernelSweep, SweepJob, SweepPlan, TransferOptions, TransferResult,
};
pub use heuristic::{
    class_proportions, eq1_score, rank_tuning_models, rank_tuning_models_indexed,
    SourceClassIndex,
};
pub use pairwise::{refine_pairwise, RefinedResult};
pub use sampling::{sample_by_source_quality, sample_random};
pub use store::{store_record_clones, ScheduleStore, StoreRecord, StoreView};
