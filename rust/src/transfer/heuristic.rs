//! Model-selection heuristic (paper §4.4.1, Eq. 1).
//!
//! For a target model M with kernel classes C, choose the tuning model T
//! maximizing
//!
//! ```text
//!     sum_{c in C}  P_c^2 * sqrt(|W_Tc|)
//! ```
//!
//! where `P_c` is class c's proportion of M's *untuned* inference time
//! and `W_Tc` the set of class-c schedules available from T. Squaring
//! the proportion and square-rooting the count are the paper's damping
//! against schedule-rich models dominating.

use super::store::ScheduleStore;
use crate::device::{untuned_kernel_times, DeviceProfile};
use crate::ir::ModelGraph;
use std::collections::{BTreeMap, HashMap};

/// Eq. 1's source-side inputs, pre-aggregated: per tuning model, the
/// class-signature → |W_Tc| table. Building it is one pass over the
/// records; scoring a target against it is a lookup + fold over the
/// *target's* classes — no per-candidate scan of the whole store. The
/// serving layer precomputes one of these per snapshot at publish time
/// ([`crate::service::ScheduleService`]), which is what turns
/// `open_session`'s ranking from O(sources × classes × records) into
/// O(sources × target classes).
#[derive(Clone, Debug, Default)]
pub struct SourceClassIndex {
    /// Source model → (class signature → schedule count). `BTreeMap`
    /// so sources iterate in name order — the same order
    /// [`ScheduleStore::source_models`] produces, keeping indexed
    /// ranking bit-identical to the store-scanning path.
    counts: BTreeMap<String, HashMap<String, usize>>,
}

impl SourceClassIndex {
    /// Index a merged store (one pass).
    pub fn of_store(store: &ScheduleStore) -> SourceClassIndex {
        let mut counts: BTreeMap<String, HashMap<String, usize>> = BTreeMap::new();
        for r in &store.records {
            *counts
                .entry(r.source_model.clone())
                .or_default()
                .entry(r.class_sig.clone())
                .or_insert(0) += 1;
        }
        SourceClassIndex { counts }
    }

    /// Index a set of per-source sub-stores (the serving layer's
    /// snapshot shape). Equivalent to [`SourceClassIndex::of_store`]
    /// over the merged store when each sub-store holds exactly one
    /// source's records — including the edge that keeps them
    /// equivalent: a sub-store with **zero** records is not indexed at
    /// all, because a record-less source is invisible to the scanning
    /// path (`source_models` only sees records).
    pub fn of_sources<'a, I>(sources: I) -> SourceClassIndex
    where
        I: IntoIterator<Item = (&'a str, &'a ScheduleStore)>,
    {
        let mut counts: BTreeMap<String, HashMap<String, usize>> = BTreeMap::new();
        for (name, store) in sources {
            if store.records.is_empty() {
                continue;
            }
            let entry = counts.entry(name.to_string()).or_default();
            for r in &store.records {
                *entry.entry(r.class_sig.clone()).or_insert(0) += 1;
            }
        }
        SourceClassIndex { counts }
    }

    /// |W_Tc|: schedules of class `sig` available from `model`.
    pub fn class_count(&self, model: &str, sig: &str) -> usize {
        self.counts
            .get(model)
            .and_then(|c| c.get(sig))
            .copied()
            .unwrap_or(0)
    }

    /// Indexed source-model names, in name order.
    pub fn sources(&self) -> impl Iterator<Item = &str> {
        self.counts.keys().map(|s| s.as_str())
    }

    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    pub fn len(&self) -> usize {
        self.counts.len()
    }
}

/// Per-class proportions of untuned inference time (the `P_c`).
pub fn class_proportions(graph: &ModelGraph, profile: &DeviceProfile) -> Vec<(String, f64)> {
    let times = untuned_kernel_times(graph, profile);
    let total: f64 = times.iter().sum();
    graph
        .class_signatures()
        .into_iter()
        .map(|sig| {
            let t: f64 = graph.kernels_of_class(&sig).iter().map(|&i| times[i]).sum();
            (sig, t / total)
        })
        .collect()
}

/// The Eq. 1 fold shared by the scanning and indexed scoring paths:
/// one implementation, one f64 summation order, so the two paths are
/// bit-identical by construction.
fn eq1_fold(proportions: &[(String, f64)], count_of: impl Fn(&str) -> usize) -> f64 {
    proportions
        .iter()
        .map(|(sig, p)| {
            let w = count_of(sig) as f64;
            p * p * w.sqrt()
        })
        .sum()
}

/// Eq. 1 score of tuning-model candidate `t_model` for a target whose
/// per-class untuned-time proportions are `proportions` (from
/// [`class_proportions`]). The target graph itself does not appear in
/// Eq. 1 — only its class proportions do — so it is not a parameter.
pub fn eq1_score(
    proportions: &[(String, f64)],
    store: &ScheduleStore,
    t_model: &str,
) -> f64 {
    eq1_fold(proportions, |sig| store.class_count(t_model, sig))
}

/// Rank candidate tuning models for `target`, best first. The target
/// itself is excluded (transferring a model onto itself is native
/// tuning, not transfer-tuning).
///
/// Delegates to [`rank_tuning_models_indexed`] over a throwaway
/// [`SourceClassIndex`] so the scanning and pre-indexed paths share one
/// scoring implementation and cannot drift. Callers that rank
/// repeatedly against the same store (the serving layer) hold a
/// persistent index instead.
pub fn rank_tuning_models(
    target: &ModelGraph,
    store: &ScheduleStore,
    profile: &DeviceProfile,
) -> Vec<(String, f64)> {
    rank_tuning_models_indexed(target, &SourceClassIndex::of_store(store), profile)
}

/// [`rank_tuning_models`] against a prebuilt [`SourceClassIndex`]: the
/// target-side class proportions are computed here; everything
/// source-side is a table lookup. Bit-identical output to the scanning
/// path — same candidate order (sorted source names), same f64
/// summation order over the target's class proportions, same
/// tie-breaking comparator.
pub fn rank_tuning_models_indexed(
    target: &ModelGraph,
    index: &SourceClassIndex,
    profile: &DeviceProfile,
) -> Vec<(String, f64)> {
    let props = class_proportions(target, profile);
    let mut scored: Vec<(String, f64)> = index
        .counts
        .iter()
        .filter(|(m, _)| m.as_str() != target.name)
        .map(|(m, counts)| {
            let s = eq1_fold(&props, |sig| counts.get(sig).copied().unwrap_or(0));
            (m.clone(), s)
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Schedule;
    use crate::transfer::store::StoreRecord;
    use crate::{ir::KernelBuilder, models};

    fn fake_record(model: &str, sig: &str, kernel_like: &crate::ir::Kernel) -> StoreRecord {
        StoreRecord::new(model, sig, vec![1], 1e-3, Schedule::untuned_default(kernel_like))
    }

    #[test]
    fn proportions_sum_to_one() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::resnet::resnet18();
        let p = class_proportions(&g, &prof);
        let total: f64 = p.iter().map(|(_, x)| x).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert_eq!(p.len(), 6);
    }

    #[test]
    fn conv_classes_dominate_resnet() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::resnet::resnet18();
        let p = class_proportions(&g, &prof);
        let conv: f64 = p
            .iter()
            .filter(|(s, _)| s.starts_with("conv2d"))
            .map(|(_, x)| x)
            .sum();
        assert!(conv > 0.7, "conv proportion {conv}");
    }

    #[test]
    fn eq1_prefers_matching_classes() {
        let prof = DeviceProfile::xeon_e5_2620();
        let target = models::resnet::resnet18();
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[crate::ir::OpKind::BiasAdd, crate::ir::OpKind::Relu]);
        let dense = KernelBuilder::dense(256, 768, 768, &[]);
        let mut store = ScheduleStore::new();
        // "ConvModel" offers 9 class-E schedules; "DenseModel" offers 9
        // class-Q schedules irrelevant to ResNet.
        for _ in 0..9 {
            store.records.push(fake_record("ConvModel", "conv2d_bias_relu", &conv));
            store.records.push(fake_record("DenseModel", "dense", &dense));
        }
        let ranked = rank_tuning_models(&target, &store, &prof);
        assert_eq!(ranked[0].0, "ConvModel");
        assert!(ranked[0].1 > ranked[1].1);
    }

    #[test]
    fn sqrt_damps_schedule_count() {
        let prof = DeviceProfile::xeon_e5_2620();
        let target = models::resnet::resnet18();
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[crate::ir::OpKind::BiasAdd, crate::ir::OpKind::Relu]);
        let mut store = ScheduleStore::new();
        for _ in 0..4 {
            store.records.push(fake_record("A", "conv2d_bias_relu", &conv));
        }
        for _ in 0..16 {
            store.records.push(fake_record("B", "conv2d_bias_relu", &conv));
        }
        let props = class_proportions(&target, &prof);
        let sa = eq1_score(&props, &store, "A");
        let sb = eq1_score(&props, &store, "B");
        // 4x the schedules only doubles the score (sqrt damping).
        assert!((sb / sa - 2.0).abs() < 1e-9);
    }

    #[test]
    fn indexed_ranking_is_bit_identical_to_scanning() {
        let prof = DeviceProfile::xeon_e5_2620();
        let target = models::resnet::resnet18();
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[crate::ir::OpKind::BiasAdd, crate::ir::OpKind::Relu]);
        let dense = KernelBuilder::dense(256, 768, 768, &[]);
        let mut store = ScheduleStore::new();
        for i in 0..7 {
            store.records.push(fake_record("ConvModel", "conv2d_bias_relu", &conv));
            if i % 2 == 0 {
                store.records.push(fake_record("DenseModel", "dense", &dense));
            }
            store.records.push(fake_record("MixModel", "conv2d_bias_relu", &conv));
            store.records.push(fake_record("MixModel", "dense", &dense));
        }
        let scanned = rank_tuning_models(&target, &store, &prof);
        let index = SourceClassIndex::of_store(&store);
        assert_eq!(index.len(), 3);
        assert_eq!(index.class_count("MixModel", "dense"), 7);
        assert_eq!(index.class_count("MixModel", "nope"), 0);
        let indexed = rank_tuning_models_indexed(&target, &index, &prof);
        assert_eq!(scanned.len(), indexed.len());
        for ((ma, sa), (mb, sb)) in scanned.iter().zip(&indexed) {
            assert_eq!(ma, mb);
            assert_eq!(sa.to_bits(), sb.to_bits(), "Eq. 1 scores must be bit-identical");
        }
        // A record-less sub-store is invisible to the scanning path, so
        // the index must not register it either.
        let empty = ScheduleStore::new();
        let ghost = SourceClassIndex::of_sources([("Ghost", &empty)]);
        assert!(ghost.is_empty(), "empty sub-stores must not become ranking candidates");
    }

    #[test]
    fn target_excluded_from_ranking() {
        let prof = DeviceProfile::xeon_e5_2620();
        let target = models::resnet::resnet18();
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[crate::ir::OpKind::BiasAdd, crate::ir::OpKind::Relu]);
        let mut store = ScheduleStore::new();
        store.records.push(fake_record("ResNet18", "conv2d_bias_relu", &conv));
        store.records.push(fake_record("Other", "conv2d_bias_relu", &conv));
        let ranked = rank_tuning_models(&target, &store, &prof);
        assert!(ranked.iter().all(|(m, _)| m != "ResNet18"));
    }
}
