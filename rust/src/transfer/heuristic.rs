//! Model-selection heuristic (paper §4.4.1, Eq. 1).
//!
//! For a target model M with kernel classes C, choose the tuning model T
//! maximizing
//!
//! ```text
//!     sum_{c in C}  P_c^2 * sqrt(|W_Tc|)
//! ```
//!
//! where `P_c` is class c's proportion of M's *untuned* inference time
//! and `W_Tc` the set of class-c schedules available from T. Squaring
//! the proportion and square-rooting the count are the paper's damping
//! against schedule-rich models dominating.

use super::store::ScheduleStore;
use crate::device::{untuned_kernel_times, DeviceProfile};
use crate::ir::ModelGraph;

/// Per-class proportions of untuned inference time (the `P_c`).
pub fn class_proportions(graph: &ModelGraph, profile: &DeviceProfile) -> Vec<(String, f64)> {
    let times = untuned_kernel_times(graph, profile);
    let total: f64 = times.iter().sum();
    graph
        .class_signatures()
        .into_iter()
        .map(|sig| {
            let t: f64 = graph.kernels_of_class(&sig).iter().map(|&i| times[i]).sum();
            (sig, t / total)
        })
        .collect()
}

/// Eq. 1 score of tuning-model candidate `t_model` for a target whose
/// per-class untuned-time proportions are `proportions` (from
/// [`class_proportions`]). The target graph itself does not appear in
/// Eq. 1 — only its class proportions do — so it is not a parameter.
pub fn eq1_score(
    proportions: &[(String, f64)],
    store: &ScheduleStore,
    t_model: &str,
) -> f64 {
    proportions
        .iter()
        .map(|(sig, p)| {
            let w = store.class_count(t_model, sig) as f64;
            p * p * w.sqrt()
        })
        .sum()
}

/// Rank candidate tuning models for `target`, best first. The target
/// itself is excluded (transferring a model onto itself is native
/// tuning, not transfer-tuning).
pub fn rank_tuning_models(
    target: &ModelGraph,
    store: &ScheduleStore,
    profile: &DeviceProfile,
) -> Vec<(String, f64)> {
    let props = class_proportions(target, profile);
    let mut scored: Vec<(String, f64)> = store
        .source_models()
        .into_iter()
        .filter(|m| *m != target.name)
        .map(|m| {
            let s = eq1_score(&props, store, &m);
            (m, s)
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::Schedule;
    use crate::transfer::store::StoreRecord;
    use crate::{ir::KernelBuilder, models};

    fn fake_record(model: &str, sig: &str, kernel_like: &crate::ir::Kernel) -> StoreRecord {
        StoreRecord {
            source_model: model.into(),
            class_sig: sig.into(),
            source_input_shape: vec![1],
            source_cost_s: 1e-3,
            schedule: Schedule::untuned_default(kernel_like),
        }
    }

    #[test]
    fn proportions_sum_to_one() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::resnet::resnet18();
        let p = class_proportions(&g, &prof);
        let total: f64 = p.iter().map(|(_, x)| x).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert_eq!(p.len(), 6);
    }

    #[test]
    fn conv_classes_dominate_resnet() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = models::resnet::resnet18();
        let p = class_proportions(&g, &prof);
        let conv: f64 = p
            .iter()
            .filter(|(s, _)| s.starts_with("conv2d"))
            .map(|(_, x)| x)
            .sum();
        assert!(conv > 0.7, "conv proportion {conv}");
    }

    #[test]
    fn eq1_prefers_matching_classes() {
        let prof = DeviceProfile::xeon_e5_2620();
        let target = models::resnet::resnet18();
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[crate::ir::OpKind::BiasAdd, crate::ir::OpKind::Relu]);
        let dense = KernelBuilder::dense(256, 768, 768, &[]);
        let mut store = ScheduleStore::new();
        // "ConvModel" offers 9 class-E schedules; "DenseModel" offers 9
        // class-Q schedules irrelevant to ResNet.
        for _ in 0..9 {
            store.records.push(fake_record("ConvModel", "conv2d_bias_relu", &conv));
            store.records.push(fake_record("DenseModel", "dense", &dense));
        }
        let ranked = rank_tuning_models(&target, &store, &prof);
        assert_eq!(ranked[0].0, "ConvModel");
        assert!(ranked[0].1 > ranked[1].1);
    }

    #[test]
    fn sqrt_damps_schedule_count() {
        let prof = DeviceProfile::xeon_e5_2620();
        let target = models::resnet::resnet18();
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[crate::ir::OpKind::BiasAdd, crate::ir::OpKind::Relu]);
        let mut store = ScheduleStore::new();
        for _ in 0..4 {
            store.records.push(fake_record("A", "conv2d_bias_relu", &conv));
        }
        for _ in 0..16 {
            store.records.push(fake_record("B", "conv2d_bias_relu", &conv));
        }
        let props = class_proportions(&target, &prof);
        let sa = eq1_score(&props, &store, "A");
        let sb = eq1_score(&props, &store, "B");
        // 4x the schedules only doubles the score (sqrt damping).
        assert!((sb / sa - 2.0).abs() < 1e-9);
    }

    #[test]
    fn target_excluded_from_ranking() {
        let prof = DeviceProfile::xeon_e5_2620();
        let target = models::resnet::resnet18();
        let conv = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[crate::ir::OpKind::BiasAdd, crate::ir::OpKind::Relu]);
        let mut store = ScheduleStore::new();
        store.records.push(fake_record("ResNet18", "conv2d_bias_relu", &conv));
        store.records.push(fake_record("Other", "conv2d_bias_relu", &conv));
        let ranked = rank_tuning_models(&target, &store, &prof);
        assert!(ranked.iter().all(|(m, _)| m != "ResNet18"));
    }
}
