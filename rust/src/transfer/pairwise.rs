//! Pairwise-aware selection refinement (paper §5.5, proposed as future
//! work):
//!
//! > "awareness and exploitation of this [inter-kernel] dynamic may
//! > enable further optimizations ... approaches could include
//! > per-kernel profiling when running the full program, and evaluating
//! > kernels pairwise."
//!
//! After the standalone sweep picks per-kernel winners, this pass walks
//! the model in execution order and, at each producer→consumer boundary,
//! re-evaluates the consumer's *near-best* candidates (within a
//! tolerance of the standalone winner) **in context** — standalone time
//! plus the boundary interaction with the producer's already-fixed
//! schedule. Each in-context evaluation is charged to the ledger as a
//! pairwise measurement, as the paper's proposal would require on real
//! hardware.

use super::engine::TransferResult;
use super::store::ScheduleStore;
use crate::coordinator::Ledger;
use crate::device::{boundary_delta, model_time, simulate, DeviceProfile};
use crate::ir::ModelGraph;
use crate::sched::{apply, Schedule};

/// Result of a pairwise refinement pass.
#[derive(Clone, Debug)]
pub struct RefinedResult {
    /// Final per-unique-kernel schedules (None = untuned default).
    pub schedules: Vec<Option<Schedule>>,
    /// End-to-end time after refinement.
    pub refined_model_s: f64,
    /// End-to-end time before refinement (the standalone selection).
    pub baseline_model_s: f64,
    /// Number of kernels whose pick changed.
    pub changed: usize,
    /// Additional search-time cost of the pairwise evaluations.
    pub extra_ledger: Ledger,
}

impl RefinedResult {
    pub fn improvement(&self) -> f64 {
        self.baseline_model_s / self.refined_model_s
    }
}

/// Refine a standalone-selected [`TransferResult`].
///
/// `tolerance` bounds which candidates are reconsidered: those whose
/// standalone time is within `(1 + tolerance)` of the kernel's best
/// (default 0.15 — the paper observes the standalone ranking is a good
/// proxy, so only near-ties are worth re-examining).
pub fn refine_pairwise(
    target: &ModelGraph,
    store: &ScheduleStore,
    result: &TransferResult,
    profile: &DeviceProfile,
    tolerance: f64,
) -> RefinedResult {
    let mut extra_ledger = Ledger::new();

    // Current per-kernel assignment from the standalone selection.
    let mut chosen: Vec<Option<Schedule>> = result
        .sweeps
        .iter()
        .map(|s| s.chosen_schedule.clone())
        .collect();
    let defaults: Vec<Schedule> = target.kernels.iter().map(Schedule::untuned_default).collect();
    let sched_of = |chosen: &[Option<Schedule>], k: usize| -> Schedule {
        chosen[k].clone().unwrap_or_else(|| defaults[k].clone())
    };

    let baseline_model_s = model_time(target, profile, |k| sched_of(&chosen, k));

    // Walk instances in execution order, refining each consumer against
    // its (already fixed) producer.
    let mut changed = 0usize;
    for inst in &target.instances {
        let Some(pi) = inst.producer else { continue };
        let prod_inst = &target.instances[pi];
        let ck = inst.kernel;
        let kernel = &target.kernels[ck];
        let sweep = &result.sweeps[ck];

        // Candidate set: near-best standalone outcomes + the default.
        let best_s = sweep.chosen_s;
        let mut candidates: Vec<(f64, Schedule)> = vec![(sweep.untuned_s, defaults[ck].clone())];
        for (ri, outcome) in &sweep.outcomes {
            if let Some(t) = outcome {
                if *t <= best_s * (1.0 + tolerance) {
                    candidates.push((*t, store.records[*ri].schedule.clone()));
                }
            }
        }
        if let Some(s) = &chosen[ck] {
            candidates.push((best_s, s.clone()));
        }

        // Score each candidate in context: deterministic standalone time
        // + boundary delta against the producer's schedule. Each scoring
        // is a pairwise measurement on the device.
        let prod_kernel = &target.kernels[prod_inst.kernel];
        let prod_sched = sched_of(&chosen, prod_inst.kernel);
        let mut best: Option<(f64, Schedule)> = None;
        for (_, cand) in candidates {
            let Ok(nest) = apply(&cand, kernel) else { continue };
            let b = simulate(kernel, &nest, profile);
            let delta = boundary_delta(prod_kernel, &prod_sched, &cand, b.mem_s, b.total_s, profile);
            let in_context = b.total_s + delta.clamp(-0.9 * b.total_s, b.total_s);
            extra_ledger.charge_measure(profile, b.total_s);
            if best.as_ref().map(|(t, _)| in_context < *t).unwrap_or(true) {
                best = Some((in_context, cand));
            }
        }
        if let Some((_, winner)) = best {
            let winner_is_default = winner == defaults[ck];
            let new = if winner_is_default { None } else { Some(winner) };
            if new != chosen[ck] {
                changed += 1;
            }
            chosen[ck] = new;
        }
    }

    let refined_model_s = model_time(target, profile, |k| sched_of(&chosen, k));
    RefinedResult {
        schedules: chosen,
        refined_model_s,
        baseline_model_s,
        changed,
        extra_ledger,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autosched::{tune_model, TuneOptions};
    use crate::transfer::transfer_tune;

    fn setup() -> (ModelGraph, ScheduleStore, TransferResult, DeviceProfile) {
        let prof = DeviceProfile::xeon_e5_2620();
        let src = crate::models::resnet::resnet50();
        let tgt = crate::models::resnet::resnet18();
        let res = tune_model(
            &src,
            &prof,
            &TuneOptions { trials: 400, batch_size: 16, population: 32, generations: 2, seed: 9, ..Default::default() },
        );
        let mut store = ScheduleStore::new();
        store.add_tuning(&src, &res);
        let tt = transfer_tune(&tgt, &store, &prof, "ResNet50", 9);
        (tgt, store, tt, prof)
    }

    #[test]
    fn refinement_never_hurts_end_to_end() {
        let (tgt, store, tt, prof) = setup();
        let refined = refine_pairwise(&tgt, &store, &tt, &prof, 0.15);
        assert!(
            refined.refined_model_s <= refined.baseline_model_s * 1.001,
            "refinement regressed: {} -> {}",
            refined.baseline_model_s,
            refined.refined_model_s
        );
        assert!(refined.extra_ledger.measurements > 0);
    }

    #[test]
    fn zero_tolerance_still_considers_default_and_winner() {
        let (tgt, store, tt, prof) = setup();
        let refined = refine_pairwise(&tgt, &store, &tt, &prof, 0.0);
        assert!(refined.refined_model_s > 0.0);
        assert_eq!(refined.schedules.len(), tgt.kernels.len());
    }

    #[test]
    fn wider_tolerance_evaluates_more_pairs() {
        let (tgt, store, tt, prof) = setup();
        let narrow = refine_pairwise(&tgt, &store, &tt, &prof, 0.05);
        let wide = refine_pairwise(&tgt, &store, &tt, &prof, 0.5);
        assert!(wide.extra_ledger.measurements >= narrow.extra_ledger.measurements);
    }
}
