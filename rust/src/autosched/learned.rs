//! Learned cost estimation over the measurement cache.
//!
//! "Learning to Optimize Tensor Programs" (Chen et al.) showed that a
//! cost model trained on *real measurements* is what closes the gap
//! between search-free ranking and full auto-tuning. The repo already
//! accumulates exactly that training set for free: every sweep deposits
//! content-addressed (features, runtime) pairs into the `MeasureCache`.
//! This module turns those pairs into a fitted [`CostModel`] under a
//! strict determinism contract:
//!
//! * **Fixed fold order** — training pairs are sorted by content key
//!   and deduplicated before fitting, so the fit is independent of
//!   cache iteration order, insertion order, and `--jobs`.
//! * **Threshold-bucketed refits** — the fit consumes exactly the first
//!   `REFIT_THRESHOLDS[k]` pairs for the largest threshold the pair
//!   count reaches. Two caches in the same bucket produce bit-identical
//!   models, so warming a cache within a bucket never silently changes
//!   keys; refits happen at deterministic cache sizes, never wall-clock.
//! * **Identity = content hash** — a fitted model's
//!   [`CostModel::content_hash`] enters `artifact::tuning_key`/
//!   `zoo_key` and the sweep seed (`coordinator::estimator_seed`) the
//!   same way `speculative_keep` does; the untrained model hashes to 0
//!   and appends nothing, keeping legacy keys byte-stable.

use super::costmodel::{CostModel, GbdtParams};
use super::features::NUM_FEATURES;

/// Measured-pair counts at which the model is (re)fit. Below the first
/// threshold the model stays untrained (a handful of samples would
/// overfit and destabilize keys on every insert); between thresholds
/// the fit is frozen at the last one crossed.
pub const REFIT_THRESHOLDS: [usize; 5] = [64, 256, 1024, 4096, 16384];

/// The largest refit threshold `n_pairs` has reached, or `None` when
/// the corpus is still too small to train on.
pub fn refit_threshold(n_pairs: usize) -> Option<usize> {
    REFIT_THRESHOLDS.iter().rev().find(|&&t| n_pairs >= t).copied()
}

/// Which estimator a run scores candidates with.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CostModelKind {
    /// The per-task model trained from scratch within each tuning run
    /// (and the draft model re-fit per span during speculative sweeps).
    /// Artifact keys carry no model ingredient.
    #[default]
    Static,
    /// A GBDT prior fitted from the measure cache, shipped as a
    /// versioned artifact and keyed into everything it influences.
    Learned,
}

impl CostModelKind {
    pub fn parse(s: &str) -> Option<CostModelKind> {
        match s {
            "static" => Some(CostModelKind::Static),
            "learned" => Some(CostModelKind::Learned),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            CostModelKind::Static => "static",
            CostModelKind::Learned => "learned",
        }
    }
}

/// What every consumer of a cost estimate needs — the tuner's round
/// scoring, the speculative draft stage, and served sessions all rank
/// through this trait, so static and learned models are
/// interchangeable.
pub trait CostEstimator {
    /// Predicted log-throughput (higher = better schedule).
    fn predict(&self, x: &[f64; NUM_FEATURES]) -> f64;
    /// Whether predictions carry any information (an unfitted estimator
    /// predicts a constant and callers fall back to exploration).
    fn is_fitted(&self) -> bool;
    /// Stable identity for key derivation; 0 iff unfitted.
    fn content_hash(&self) -> u64;
}

impl CostEstimator for CostModel {
    fn predict(&self, x: &[f64; NUM_FEATURES]) -> f64 {
        CostModel::predict(self, x)
    }

    fn is_fitted(&self) -> bool {
        self.is_trained()
    }

    fn content_hash(&self) -> u64 {
        CostModel::content_hash(self)
    }
}

/// One training example exported from the cache: the pair's content key
/// (the dedup/sort identity), its feature vector, and the target
/// `-ln(runtime)` (log-throughput, so higher = better — the same target
/// the in-run tuner fits).
#[derive(Clone, Debug)]
pub struct TrainingPair {
    pub content: u64,
    pub x: [f64; NUM_FEATURES],
    pub y: f64,
}

/// The log-throughput training target for a measured runtime.
pub fn training_target(runtime_s: f64) -> f64 {
    -(runtime_s.max(1e-12)).ln()
}

/// Deterministic fit: sort by content key, collapse duplicates (first
/// occurrence wins — they are identical measurements anyway), truncate
/// to the refit threshold bucket, and train. Returns the untrained
/// model below the first threshold.
pub fn fit_pairs(pairs: &[TrainingPair]) -> CostModel {
    let mut sorted: Vec<&TrainingPair> = pairs.iter().collect();
    sorted.sort_by_key(|p| p.content);
    sorted.dedup_by_key(|p| p.content);
    let Some(take) = refit_threshold(sorted.len()) else {
        return CostModel::default();
    };
    sorted.truncate(take);
    let xs: Vec<[f64; NUM_FEATURES]> = sorted.iter().map(|p| p.x).collect();
    let ys: Vec<f64> = sorted.iter().map(|p| p.y).collect();
    CostModel::train(&xs, &ys, &GbdtParams::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth_pairs(n: usize, seed: u64) -> Vec<TrainingPair> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let mut x = [0.0; NUM_FEATURES];
                for v in x.iter_mut() {
                    *v = rng.f64() * 10.0;
                }
                let y = 2.0 * x[3] - x[7] + rng.normal() * 0.1;
                TrainingPair { content: (i as u64).wrapping_mul(0x9E37_79B9) ^ seed, x, y }
            })
            .collect()
    }

    #[test]
    fn refit_thresholds_bucket_correctly() {
        assert_eq!(refit_threshold(0), None);
        assert_eq!(refit_threshold(63), None);
        assert_eq!(refit_threshold(64), Some(64));
        assert_eq!(refit_threshold(255), Some(64));
        assert_eq!(refit_threshold(256), Some(256));
        assert_eq!(refit_threshold(100_000), Some(16384));
    }

    #[test]
    fn fit_is_order_independent_and_bucket_frozen() {
        let pairs = synth_pairs(300, 11);
        let mut shuffled = pairs.clone();
        shuffled.reverse();
        let a = fit_pairs(&pairs);
        let b = fit_pairs(&shuffled);
        assert_eq!(a.content_hash(), b.content_hash(), "fold order is fixed by content key");
        assert_ne!(a.content_hash(), 0);

        // Growing within a bucket must not change the model: the fit
        // consumes the smallest 256 content keys either way.
        let mut by_key = pairs.clone();
        by_key.sort_by_key(|p| p.content);
        let at_256 = fit_pairs(&by_key[..256]);
        let at_300 = fit_pairs(&by_key);
        assert_eq!(at_256.content_hash(), at_300.content_hash(), "frozen within a bucket");
    }

    #[test]
    fn below_first_threshold_stays_untrained() {
        let pairs = synth_pairs(63, 3);
        let m = fit_pairs(&pairs);
        assert!(!m.is_trained());
        assert_eq!(m.content_hash(), 0);
    }

    #[test]
    fn duplicates_collapse_before_thresholding() {
        // 64 unique pairs duplicated 3x: still one bucket of 64.
        let base = synth_pairs(64, 9);
        let mut tripled = base.clone();
        tripled.extend(base.iter().cloned());
        tripled.extend(base.iter().cloned());
        assert_eq!(fit_pairs(&tripled).content_hash(), fit_pairs(&base).content_hash());
    }

    #[test]
    fn kind_parses_and_prints() {
        assert_eq!(CostModelKind::parse("static"), Some(CostModelKind::Static));
        assert_eq!(CostModelKind::parse("learned"), Some(CostModelKind::Learned));
        assert_eq!(CostModelKind::parse("xgboost"), None);
        assert_eq!(CostModelKind::Learned.as_str(), "learned");
        assert_eq!(CostModelKind::default(), CostModelKind::Static);
    }
}
