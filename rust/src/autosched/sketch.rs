//! Sketch generation + mutation/crossover operators: Ansor's search space.
//!
//! Ansor derives a small set of structural *sketches* per kernel (on CPU,
//! the multi-level "SSRSRS" tile structure, optional cache-write stage)
//! and then samples/evolves the numeric decisions: split factors,
//! annotation choices, unroll budgets. We mirror that: the sketch level
//! count is fixed per anchor kind, everything numeric is sampled.

use crate::ir::{AnchorKind, Kernel};
use crate::sched::{AxisTiling, Schedule};
use crate::util::rng::Rng;

/// (spatial inner-factor count, reduction inner-factor count) per anchor.
/// Heavy MAC kernels get the full 4-level spatial / 2-level reduction
/// structure of the paper's Algorithm 1; cheap kernels get a 2-level
/// spatial split (parallel outer + vector inner).
pub fn sketch_shape(anchor: AnchorKind) -> (usize, usize) {
    match anchor {
        AnchorKind::Conv2d | AnchorKind::Depthwise | AnchorKind::Dense | AnchorKind::BatchMatMul => (3, 1),
        AnchorKind::Pool2d | AnchorKind::GlobalPool | AnchorKind::RowReduce => (1, 0),
        AnchorKind::Eltwise => (1, 0),
    }
}

const UNROLL_CHOICES: &[u64] = &[0, 16, 64, 512];

/// Candidate tile factors: powers of two (transfer well across the
/// power-of-two channel counts of real DNNs) plus true divisors of the
/// extent (fit perfectly but may not transfer).
fn sample_factor(extent: u64, max: u64, rng: &mut Rng) -> u64 {
    let cap = extent.min(max).max(1);
    if rng.bool(0.7) {
        // Power of two <= cap.
        let max_pow = 63 - cap.leading_zeros() as u64;
        1u64 << rng.range(0, max_pow as usize)
    } else {
        // Random divisor of the extent <= cap.
        let divs: Vec<u64> = (1..=cap).filter(|d| extent % d == 0).collect();
        *rng.choose(&divs)
    }
}

fn sample_tiling(extent: u64, n_factors: usize, rng: &mut Rng) -> AxisTiling {
    let mut factors = Vec::with_capacity(n_factors);
    let mut budget = extent.max(1);
    for i in 0..n_factors {
        // Innermost factor (sampled last) gets the biggest range; outer
        // inner-factors stay small (they become register/L1 tile shape).
        let max = if i + 1 == n_factors { 64 } else { 4 };
        let f = sample_factor(budget, max, rng).min(budget);
        factors.push(f);
        budget = (budget / f).max(1);
    }
    AxisTiling { factors }
}

/// Sample a random complete schedule for `kernel`.
pub fn random_schedule(kernel: &Kernel, rng: &mut Rng) -> Schedule {
    let (ns, nr) = sketch_shape(kernel.anchor);
    let spatial = kernel
        .nest
        .spatial_axes()
        .map(|(_, a)| sample_tiling(a.extent, ns, rng))
        .collect();
    let reduction = kernel
        .nest
        .reduction_axes()
        .map(|(_, a)| sample_tiling(a.extent, nr, rng))
        .collect();
    Schedule {
        class_sig: kernel.class_signature(),
        skeleton: kernel.nest.skeleton(),
        spatial,
        reduction,
        parallel_levels: if rng.bool(0.25) && ns >= 2 { 2 } else { 1 },
        vectorize: rng.bool(0.85),
        unroll_max: *rng.choose(UNROLL_CHOICES),
        cache_write: rng.bool(0.4),
    }
}

/// Mutate one decision of a schedule (Ansor's evolutionary mutation).
pub fn mutate(sched: &Schedule, kernel: &Kernel, rng: &mut Rng) -> Schedule {
    let mut s = sched.clone();
    let n_spatial = s.spatial.len();
    let n_red = s.reduction.len();
    match rng.usize(6) {
        0 if n_spatial > 0 => {
            // Resample one spatial tile factor.
            let ai = rng.usize(n_spatial);
            let extent = kernel.nest.spatial_axes().nth(ai).map(|(_, a)| a.extent).unwrap_or(1);
            if !s.spatial[ai].factors.is_empty() {
                let fi = rng.usize(s.spatial[ai].factors.len());
                let max = if fi + 1 == s.spatial[ai].factors.len() { 64 } else { 4 };
                s.spatial[ai].factors[fi] = sample_factor(extent, max, rng);
            }
        }
        1 if n_red > 0 => {
            let ai = rng.usize(n_red);
            let extent = kernel.nest.reduction_axes().nth(ai).map(|(_, a)| a.extent).unwrap_or(1);
            if !s.reduction[ai].factors.is_empty() {
                let fi = rng.usize(s.reduction[ai].factors.len());
                s.reduction[ai].factors[fi] = sample_factor(extent, 64, rng);
            }
        }
        2 => s.vectorize = !s.vectorize,
        3 => s.unroll_max = *rng.choose(UNROLL_CHOICES),
        4 => s.cache_write = !s.cache_write,
        _ => {
            let (ns, _) = sketch_shape(kernel.anchor);
            s.parallel_levels = if s.parallel_levels == 1 && ns >= 2 { 2 } else { 1 };
        }
    }
    s
}

/// Uniform per-axis crossover of two schedules of the same sketch.
pub fn crossover(a: &Schedule, b: &Schedule, rng: &mut Rng) -> Schedule {
    let mut s = a.clone();
    for (i, t) in s.spatial.iter_mut().enumerate() {
        if rng.bool(0.5) {
            *t = b.spatial[i].clone();
        }
    }
    for (i, t) in s.reduction.iter_mut().enumerate() {
        if rng.bool(0.5) {
            *t = b.reduction[i].clone();
        }
    }
    if rng.bool(0.5) {
        s.vectorize = b.vectorize;
    }
    if rng.bool(0.5) {
        s.unroll_max = b.unroll_max;
    }
    if rng.bool(0.5) {
        s.cache_write = b.cache_write;
    }
    if rng.bool(0.5) {
        s.parallel_levels = b.parallel_levels;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;
    use crate::sched::apply;

    #[test]
    fn random_schedules_mostly_apply() {
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let mut rng = Rng::new(42);
        let ok = (0..200)
            .filter(|_| apply(&random_schedule(&k, &mut rng), &k).is_ok())
            .count();
        // Factors are sampled within the extent budget, so nearly all
        // sketches must be valid on their own kernel.
        assert!(ok >= 195, "only {ok}/200 valid");
    }

    #[test]
    fn conv_kernels_get_full_tile_structure() {
        let k = KernelBuilder::conv2d(1, 64, 56, 56, 64, 3, 3, 1, 1, &[]);
        let mut rng = Rng::new(1);
        let s = random_schedule(&k, &mut rng);
        assert_eq!(s.spatial_levels(), 4);
        assert_eq!(s.reduction_levels(), 2);
    }

    #[test]
    fn pool_kernels_get_light_structure() {
        let k = KernelBuilder::pool2d(crate::ir::OpKind::MaxPool2d, 1, 64, 56, 56, 2, 2, 2);
        let mut rng = Rng::new(1);
        let s = random_schedule(&k, &mut rng);
        assert_eq!(s.spatial_levels(), 2);
        assert_eq!(s.reduction_levels(), 1);
    }

    #[test]
    fn mutation_changes_exactly_some_field() {
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let mut rng = Rng::new(7);
        let s = random_schedule(&k, &mut rng);
        let mut changed = 0;
        for _ in 0..50 {
            if mutate(&s, &k, &mut rng) != s {
                changed += 1;
            }
        }
        assert!(changed > 30, "mutation too often a no-op: {changed}/50");
    }

    #[test]
    fn crossover_mixes_parents() {
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let mut rng = Rng::new(9);
        let a = random_schedule(&k, &mut rng);
        let b = random_schedule(&k, &mut rng);
        let c = crossover(&a, &b, &mut rng);
        assert_eq!(c.class_sig, a.class_sig);
        assert_eq!(c.spatial.len(), a.spatial.len());
    }

    #[test]
    fn factors_deterministic_per_seed() {
        let k = KernelBuilder::dense(256, 256, 256, &[]);
        let s1 = random_schedule(&k, &mut Rng::new(3));
        let s2 = random_schedule(&k, &mut Rng::new(3));
        assert_eq!(s1, s2);
    }
}
