//! The Ansor-like auto-scheduler baseline (Zheng et al., OSDI 2020).
//!
//! This is the system the paper *compares against* (and the producer of
//! the auto-schedules that transfer-tuning reuses): sketch generation
//! over the CPU multi-level tiling space, evolutionary search guided by
//! a learned (GBDT) cost model, and a gradient task scheduler slicing
//! the trial budget across kernels. Every measurement charges simulated
//! tuning seconds to a ledger, which is what all the paper's
//! search-time comparisons consume.

pub mod costmodel;
pub mod features;
pub mod learned;
pub mod sketch;
pub mod tuner;

pub use costmodel::{CostModel, GbdtParams, COSTMODEL_CODEC_VERSION};
pub use features::{features, NUM_FEATURES};
pub use learned::{
    fit_pairs, refit_threshold, training_target, CostEstimator, CostModelKind, TrainingPair,
    REFIT_THRESHOLDS,
};
pub use sketch::{crossover, mutate, random_schedule, sketch_shape};
pub use tuner::{tune_model, HistoryPoint, KernelBest, TuneOptions, TuningResult};
