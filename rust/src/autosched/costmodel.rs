//! Learned cost model: gradient-boosted regression trees.
//!
//! Ansor uses XGBoost over loop-nest features; we implement a compact
//! GBDT from scratch (offline environment). The model predicts
//! log-throughput from [`super::features`] vectors and is retrained from
//! scratch on the measured samples after every measurement batch, just
//! like Ansor's per-round update. What matters for search quality is
//! *ranking* fidelity (Spearman), which the tests check.

use super::features::NUM_FEATURES;
use crate::ir::workload::fnv1a;
use crate::util::json::Json;

/// Version stamp carried by every serialized model; readers reject
/// other versions (the artifact store treats that as a miss + re-fit,
/// never a crash).
pub const COSTMODEL_CODEC_VERSION: u64 = 1;

#[derive(Clone, Debug)]
struct Node {
    /// Split feature, or usize::MAX for a leaf.
    feature: usize,
    threshold: f64,
    /// Children indices (valid when not leaf).
    left: usize,
    right: usize,
    /// Leaf value (valid when leaf).
    value: f64,
}

#[derive(Clone, Debug, Default)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64; NUM_FEATURES]) -> f64 {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.feature == usize::MAX {
                return n.value;
            }
            i = if x[n.feature] <= n.threshold { n.left } else { n.right };
        }
    }
}

#[derive(Clone, Debug)]
pub struct GbdtParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f64,
    pub min_samples_leaf: usize,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams { n_trees: 30, max_depth: 4, learning_rate: 0.3, min_samples_leaf: 4 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct CostModel {
    trees: Vec<Tree>,
    base: f64,
    lr: f64,
    pub n_trained_samples: usize,
}

impl CostModel {
    /// Untrained model: predicts the prior (0) for everything. The tuner
    /// treats an untrained model as "explore randomly".
    pub fn is_trained(&self) -> bool {
        !self.trees.is_empty()
    }

    /// Fit on (features, target) pairs. Targets are log-throughput
    /// (higher = better schedule).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): feature orders are pre-sorted
    /// ONCE per training call; tree nodes walk the presorted lists with a
    /// membership mask instead of re-sorting — O(n·F) per node instead of
    /// O(n log n · F).
    pub fn train(xs: &[[f64; NUM_FEATURES]], ys: &[f64], params: &GbdtParams) -> CostModel {
        assert_eq!(xs.len(), ys.len());
        if xs.is_empty() {
            return CostModel::default();
        }
        let n = xs.len();
        let base = ys.iter().sum::<f64>() / n as f64;
        let mut residuals: Vec<f64> = ys.iter().map(|y| y - base).collect();

        // Presort sample indices along every feature (shared by all trees
        // and all nodes).
        let mut orders: Vec<Vec<u32>> = Vec::with_capacity(NUM_FEATURES);
        for f in 0..NUM_FEATURES {
            let mut ord: Vec<u32> = (0..n as u32).collect();
            ord.sort_by(|&a, &b| {
                xs[a as usize][f].partial_cmp(&xs[b as usize][f]).unwrap()
            });
            orders.push(ord);
        }

        let mut trees = Vec::with_capacity(params.n_trees);
        let mut member = vec![true; n];
        for _ in 0..params.n_trees {
            let mut tree = Tree::default();
            member.fill(true);
            build_node(
                &mut tree,
                xs,
                &residuals,
                &orders,
                &mut member,
                n,
                params.max_depth,
                params.min_samples_leaf,
            );
            // Update residuals.
            for (i, x) in xs.iter().enumerate() {
                residuals[i] -= params.learning_rate * tree.predict(x);
            }
            trees.push(tree);
        }
        CostModel { trees, base, lr: params.learning_rate, n_trained_samples: n }
    }

    pub fn predict(&self, x: &[f64; NUM_FEATURES]) -> f64 {
        let mut y = self.base;
        for t in &self.trees {
            y += self.lr * t.predict(x);
        }
        y
    }

    // ---- persistence & identity ------------------------------------------
    //
    // Trees and their nodes are private, so the canonical byte form of a
    // fitted model lives here, next to the structures it encodes.

    /// Canonical JSON form. Every float goes through [`Json::num`],
    /// which round-trips `f64` bit-exactly, so save → load → save is a
    /// fixed point and [`Self::content_hash`] is stable across
    /// processes. Leaves encode their split feature as `-1` (a JSON
    /// number cannot carry `usize::MAX` losslessly).
    pub fn to_json(&self) -> Json {
        let trees = self.trees.iter().map(|t| {
            Json::arr(t.nodes.iter().map(|n| {
                let feat = if n.feature == usize::MAX { -1.0 } else { n.feature as f64 };
                Json::arr([
                    Json::num(feat),
                    Json::num(n.threshold),
                    Json::num(n.left as f64),
                    Json::num(n.right as f64),
                    Json::num(n.value),
                ])
            }))
        });
        Json::obj(vec![
            ("base", Json::num(self.base)),
            ("lr", Json::num(self.lr)),
            ("samples", Json::num(self.n_trained_samples as f64)),
            ("trees", Json::arr(trees)),
            ("version", Json::num(COSTMODEL_CODEC_VERSION as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<CostModel> {
        let version = j.req("version")?.as_f64().unwrap_or(0.0) as u64;
        anyhow::ensure!(
            version == COSTMODEL_CODEC_VERSION,
            "unsupported cost-model version {version}"
        );
        let base = j.req("base")?.as_f64().ok_or_else(|| anyhow::anyhow!("bad base"))?;
        let lr = j.req("lr")?.as_f64().ok_or_else(|| anyhow::anyhow!("bad lr"))?;
        let samples = j
            .req("samples")?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("bad samples count"))?;
        let mut trees = Vec::new();
        for (ti, tj) in j.req("trees")?.as_arr().unwrap_or(&[]).iter().enumerate() {
            let nodes_j = tj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("tree {ti}: expected node array"))?;
            let mut nodes = Vec::with_capacity(nodes_j.len());
            for (ni, nj) in nodes_j.iter().enumerate() {
                let f = nj
                    .as_arr()
                    .filter(|a| a.len() == 5)
                    .and_then(|a| {
                        let vals: Vec<f64> = a.iter().filter_map(|v| v.as_f64()).collect();
                        (vals.len() == 5).then_some(vals)
                    })
                    .ok_or_else(|| {
                        anyhow::anyhow!("tree {ti} node {ni}: expected 5 numbers")
                    })?;
                let feature = if f[0] < 0.0 { usize::MAX } else { f[0] as usize };
                let (left, right) = (f[2] as usize, f[3] as usize);
                if feature != usize::MAX {
                    // Children were pushed after their parent during the
                    // build, so forward-only indices are the termination
                    // guarantee for `Tree::predict` — reject anything
                    // else rather than risk an infinite walk on corrupt
                    // input.
                    anyhow::ensure!(
                        feature < NUM_FEATURES
                            && left > ni
                            && right > ni
                            && left < nodes_j.len()
                            && right < nodes_j.len(),
                        "tree {ti} node {ni}: malformed split"
                    );
                }
                nodes.push(Node { feature, threshold: f[1], left, right, value: f[4] });
            }
            anyhow::ensure!(!nodes.is_empty(), "tree {ti}: empty");
            trees.push(Tree { nodes });
        }
        Ok(CostModel { trees, base, lr, n_trained_samples: samples })
    }

    /// Stable identity of a fitted model: FNV-1a over the canonical
    /// serialized form. The untrained model is defined to hash to `0`,
    /// the "append nothing" sentinel of
    /// [`crate::coordinator::cache::estimator_seed`] and the artifact
    /// key builders — so a default model leaves every legacy key
    /// byte-identical, and any two differently-fitted models (different
    /// trees, base, or sample count) hash apart.
    pub fn content_hash(&self) -> u64 {
        if !self.is_trained() {
            return 0;
        }
        let h = fnv1a(self.to_json().to_compact().as_bytes());
        if h == 0 {
            1 // keep "0 = untrained" unambiguous even if FNV lands on 0
        } else {
            h
        }
    }
}

/// Greedy exact split search over presorted feature orders, squared-error
/// criterion. `member[i]` marks which samples belong to this node; the
/// function restores `member` to its entry state before returning (so the
/// caller's sibling recursion sees the right mask).
#[allow(clippy::too_many_arguments)]
fn build_node(
    tree: &mut Tree,
    xs: &[[f64; NUM_FEATURES]],
    residuals: &[f64],
    orders: &[Vec<u32>],
    member: &mut [bool],
    count: usize,
    depth: usize,
    min_leaf: usize,
) -> usize {
    let sum: f64 = orders[0]
        .iter()
        .filter(|&&i| member[i as usize])
        .map(|&i| residuals[i as usize])
        .sum();
    let mean = sum / count.max(1) as f64;
    if depth == 0 || count < 2 * min_leaf {
        tree.nodes.push(Node { feature: usize::MAX, threshold: 0.0, left: 0, right: 0, value: mean });
        return tree.nodes.len() - 1;
    }

    // Find best (feature, threshold) by walking each presorted order.
    let n = count as f64;
    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, gain)
    for (feat, order) in orders.iter().enumerate() {
        let mut left_sum = 0.0;
        let mut nl = 0usize;
        let mut prev: Option<u32> = None;
        for &i in order {
            if !member[i as usize] {
                continue;
            }
            // A split boundary sits between `prev` and `i`.
            if let Some(p) = prev {
                let (pv, iv) = (xs[p as usize][feat], xs[i as usize][feat]);
                if pv < iv && nl >= min_leaf && count - nl >= min_leaf {
                    let right_sum = sum - left_sum;
                    let nr = n - nl as f64;
                    let gain = left_sum * left_sum / nl as f64 + right_sum * right_sum / nr
                        - sum * sum / n;
                    if best.map(|(_, _, g)| gain > g).unwrap_or(gain > 1e-12) {
                        best = Some((feat, 0.5 * (pv + iv), gain));
                    }
                }
            }
            left_sum += residuals[i as usize];
            nl += 1;
            prev = Some(i);
        }
    }

    let Some((feature, threshold, _)) = best else {
        tree.nodes.push(Node { feature: usize::MAX, threshold: 0.0, left: 0, right: 0, value: mean });
        return tree.nodes.len() - 1;
    };

    // Reserve our slot first so children indices are stable.
    tree.nodes.push(Node { feature, threshold, left: 0, right: 0, value: 0.0 });
    let me = tree.nodes.len() - 1;

    // Partition by masking: left recursion sees only left members, then
    // the mask flips to the right side, and is finally restored.
    let node_members: Vec<u32> = orders[0]
        .iter()
        .copied()
        .filter(|&i| member[i as usize])
        .collect();
    let mut left_count = 0usize;
    for &i in &node_members {
        if xs[i as usize][feature] <= threshold {
            left_count += 1;
        } else {
            member[i as usize] = false;
        }
    }
    let l = build_node(tree, xs, residuals, orders, member, left_count, depth - 1, min_leaf);
    for &i in &node_members {
        member[i as usize] = xs[i as usize][feature] > threshold;
    }
    let r = build_node(
        tree,
        xs,
        residuals,
        orders,
        member,
        count - left_count,
        depth - 1,
        min_leaf,
    );
    // Restore the full node membership for the caller.
    for &i in &node_members {
        member[i as usize] = true;
    }
    tree.nodes[me].left = l;
    tree.nodes[me].right = r;
    me
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::spearman;

    fn synth(n: usize, seed: u64) -> (Vec<[f64; NUM_FEATURES]>, Vec<f64>) {
        // Nonlinear synthetic target over a few features + noise.
        let mut rng = Rng::new(seed);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let mut x = [0.0; NUM_FEATURES];
            for v in x.iter_mut() {
                *v = rng.f64() * 10.0;
            }
            let y = 3.0 * x[2] + (x[6] - 5.0).abs() * -2.0 + x[4] * x[2] * 0.3 + rng.normal() * 0.5;
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn learns_nonlinear_ranking() {
        let (xs, ys) = synth(400, 1);
        let model = CostModel::train(&xs, &ys, &GbdtParams::default());
        let (xt, yt) = synth(200, 2);
        let preds: Vec<f64> = xt.iter().map(|x| model.predict(x)).collect();
        let rho = spearman(&preds, &yt);
        assert!(rho > 0.8, "spearman {rho}");
    }

    #[test]
    fn empty_training_is_safe() {
        let m = CostModel::train(&[], &[], &GbdtParams::default());
        assert!(!m.is_trained());
        assert_eq!(m.predict(&[0.0; NUM_FEATURES]), 0.0);
    }

    #[test]
    fn constant_target_predicts_constant() {
        let xs: Vec<[f64; NUM_FEATURES]> = (0..50).map(|i| [i as f64; NUM_FEATURES]).collect();
        let ys = vec![7.0; 50];
        let m = CostModel::train(&xs, &ys, &GbdtParams::default());
        assert!((m.predict(&[25.0; NUM_FEATURES]) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn serialization_roundtrips_bit_exactly_and_hash_is_stable() {
        let (xs, ys) = synth(200, 5);
        let model = CostModel::train(&xs, &ys, &GbdtParams::default());
        let text = model.to_json().to_compact();
        let back = CostModel::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n_trained_samples, model.n_trained_samples);
        for x in xs.iter().take(32) {
            assert_eq!(model.predict(x).to_bits(), back.predict(x).to_bits());
        }
        assert_eq!(back.to_json().to_compact(), text, "save→load→save is a fixed point");
        assert_eq!(back.content_hash(), model.content_hash());
        assert_ne!(model.content_hash(), 0, "fitted models hash nonzero");
    }

    #[test]
    fn untrained_model_hashes_to_zero_and_fits_differ() {
        assert_eq!(CostModel::default().content_hash(), 0);
        let (xs, ys) = synth(150, 7);
        let a = CostModel::train(&xs, &ys, &GbdtParams::default());
        let (xs2, ys2) = synth(150, 8);
        let b = CostModel::train(&xs2, &ys2, &GbdtParams::default());
        assert_ne!(a.content_hash(), b.content_hash(), "different fits, different identity");
    }

    #[test]
    fn malformed_models_are_rejected() {
        let parse = |s: &str| CostModel::from_json(&crate::util::json::parse(s).unwrap());
        assert!(parse(r#"{"base":0,"lr":0.3,"samples":1,"trees":[],"version":9}"#).is_err());
        // A split pointing backwards would loop predict forever.
        assert!(parse(
            r#"{"base":0,"lr":0.3,"samples":1,"trees":[[[0,1.0,0,0,0.0]]],"version":1}"#
        )
        .is_err());
        assert!(parse(r#"{"base":0,"lr":0.3,"samples":1,"trees":[[]],"version":1}"#).is_err());
    }

    #[test]
    fn improves_with_more_trees() {
        let (xs, ys) = synth(300, 3);
        let weak = CostModel::train(&xs, &ys, &GbdtParams { n_trees: 2, ..Default::default() });
        let strong = CostModel::train(&xs, &ys, &GbdtParams { n_trees: 40, ..Default::default() });
        let mse = |m: &CostModel| -> f64 {
            xs.iter().zip(&ys).map(|(x, y)| (m.predict(x) - y).powi(2)).sum::<f64>() / ys.len() as f64
        };
        assert!(mse(&strong) < mse(&weak));
    }
}
