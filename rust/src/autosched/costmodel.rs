//! Learned cost model: gradient-boosted regression trees.
//!
//! Ansor uses XGBoost over loop-nest features; we implement a compact
//! GBDT from scratch (offline environment). The model predicts
//! log-throughput from [`super::features`] vectors and is retrained from
//! scratch on the measured samples after every measurement batch, just
//! like Ansor's per-round update. What matters for search quality is
//! *ranking* fidelity (Spearman), which the tests check.

use super::features::NUM_FEATURES;

#[derive(Clone, Debug)]
struct Node {
    /// Split feature, or usize::MAX for a leaf.
    feature: usize,
    threshold: f64,
    /// Children indices (valid when not leaf).
    left: usize,
    right: usize,
    /// Leaf value (valid when leaf).
    value: f64,
}

#[derive(Clone, Debug, Default)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64; NUM_FEATURES]) -> f64 {
        let mut i = 0usize;
        loop {
            let n = &self.nodes[i];
            if n.feature == usize::MAX {
                return n.value;
            }
            i = if x[n.feature] <= n.threshold { n.left } else { n.right };
        }
    }
}

#[derive(Clone, Debug)]
pub struct GbdtParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub learning_rate: f64,
    pub min_samples_leaf: usize,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams { n_trees: 30, max_depth: 4, learning_rate: 0.3, min_samples_leaf: 4 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct CostModel {
    trees: Vec<Tree>,
    base: f64,
    lr: f64,
    pub n_trained_samples: usize,
}

impl CostModel {
    /// Untrained model: predicts the prior (0) for everything. The tuner
    /// treats an untrained model as "explore randomly".
    pub fn is_trained(&self) -> bool {
        !self.trees.is_empty()
    }

    /// Fit on (features, target) pairs. Targets are log-throughput
    /// (higher = better schedule).
    ///
    /// Perf note (EXPERIMENTS.md §Perf): feature orders are pre-sorted
    /// ONCE per training call; tree nodes walk the presorted lists with a
    /// membership mask instead of re-sorting — O(n·F) per node instead of
    /// O(n log n · F).
    pub fn train(xs: &[[f64; NUM_FEATURES]], ys: &[f64], params: &GbdtParams) -> CostModel {
        assert_eq!(xs.len(), ys.len());
        if xs.is_empty() {
            return CostModel::default();
        }
        let n = xs.len();
        let base = ys.iter().sum::<f64>() / n as f64;
        let mut residuals: Vec<f64> = ys.iter().map(|y| y - base).collect();

        // Presort sample indices along every feature (shared by all trees
        // and all nodes).
        let mut orders: Vec<Vec<u32>> = Vec::with_capacity(NUM_FEATURES);
        for f in 0..NUM_FEATURES {
            let mut ord: Vec<u32> = (0..n as u32).collect();
            ord.sort_by(|&a, &b| {
                xs[a as usize][f].partial_cmp(&xs[b as usize][f]).unwrap()
            });
            orders.push(ord);
        }

        let mut trees = Vec::with_capacity(params.n_trees);
        let mut member = vec![true; n];
        for _ in 0..params.n_trees {
            let mut tree = Tree::default();
            member.fill(true);
            build_node(
                &mut tree,
                xs,
                &residuals,
                &orders,
                &mut member,
                n,
                params.max_depth,
                params.min_samples_leaf,
            );
            // Update residuals.
            for (i, x) in xs.iter().enumerate() {
                residuals[i] -= params.learning_rate * tree.predict(x);
            }
            trees.push(tree);
        }
        CostModel { trees, base, lr: params.learning_rate, n_trained_samples: n }
    }

    pub fn predict(&self, x: &[f64; NUM_FEATURES]) -> f64 {
        let mut y = self.base;
        for t in &self.trees {
            y += self.lr * t.predict(x);
        }
        y
    }
}

/// Greedy exact split search over presorted feature orders, squared-error
/// criterion. `member[i]` marks which samples belong to this node; the
/// function restores `member` to its entry state before returning (so the
/// caller's sibling recursion sees the right mask).
#[allow(clippy::too_many_arguments)]
fn build_node(
    tree: &mut Tree,
    xs: &[[f64; NUM_FEATURES]],
    residuals: &[f64],
    orders: &[Vec<u32>],
    member: &mut [bool],
    count: usize,
    depth: usize,
    min_leaf: usize,
) -> usize {
    let sum: f64 = orders[0]
        .iter()
        .filter(|&&i| member[i as usize])
        .map(|&i| residuals[i as usize])
        .sum();
    let mean = sum / count.max(1) as f64;
    if depth == 0 || count < 2 * min_leaf {
        tree.nodes.push(Node { feature: usize::MAX, threshold: 0.0, left: 0, right: 0, value: mean });
        return tree.nodes.len() - 1;
    }

    // Find best (feature, threshold) by walking each presorted order.
    let n = count as f64;
    let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, gain)
    for (feat, order) in orders.iter().enumerate() {
        let mut left_sum = 0.0;
        let mut nl = 0usize;
        let mut prev: Option<u32> = None;
        for &i in order {
            if !member[i as usize] {
                continue;
            }
            // A split boundary sits between `prev` and `i`.
            if let Some(p) = prev {
                let (pv, iv) = (xs[p as usize][feat], xs[i as usize][feat]);
                if pv < iv && nl >= min_leaf && count - nl >= min_leaf {
                    let right_sum = sum - left_sum;
                    let nr = n - nl as f64;
                    let gain = left_sum * left_sum / nl as f64 + right_sum * right_sum / nr
                        - sum * sum / n;
                    if best.map(|(_, _, g)| gain > g).unwrap_or(gain > 1e-12) {
                        best = Some((feat, 0.5 * (pv + iv), gain));
                    }
                }
            }
            left_sum += residuals[i as usize];
            nl += 1;
            prev = Some(i);
        }
    }

    let Some((feature, threshold, _)) = best else {
        tree.nodes.push(Node { feature: usize::MAX, threshold: 0.0, left: 0, right: 0, value: mean });
        return tree.nodes.len() - 1;
    };

    // Reserve our slot first so children indices are stable.
    tree.nodes.push(Node { feature, threshold, left: 0, right: 0, value: 0.0 });
    let me = tree.nodes.len() - 1;

    // Partition by masking: left recursion sees only left members, then
    // the mask flips to the right side, and is finally restored.
    let node_members: Vec<u32> = orders[0]
        .iter()
        .copied()
        .filter(|&i| member[i as usize])
        .collect();
    let mut left_count = 0usize;
    for &i in &node_members {
        if xs[i as usize][feature] <= threshold {
            left_count += 1;
        } else {
            member[i as usize] = false;
        }
    }
    let l = build_node(tree, xs, residuals, orders, member, left_count, depth - 1, min_leaf);
    for &i in &node_members {
        member[i as usize] = xs[i as usize][feature] > threshold;
    }
    let r = build_node(
        tree,
        xs,
        residuals,
        orders,
        member,
        count - left_count,
        depth - 1,
        min_leaf,
    );
    // Restore the full node membership for the caller.
    for &i in &node_members {
        member[i as usize] = true;
    }
    tree.nodes[me].left = l;
    tree.nodes[me].right = r;
    me
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::spearman;

    fn synth(n: usize, seed: u64) -> (Vec<[f64; NUM_FEATURES]>, Vec<f64>) {
        // Nonlinear synthetic target over a few features + noise.
        let mut rng = Rng::new(seed);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let mut x = [0.0; NUM_FEATURES];
            for v in x.iter_mut() {
                *v = rng.f64() * 10.0;
            }
            let y = 3.0 * x[2] + (x[6] - 5.0).abs() * -2.0 + x[4] * x[2] * 0.3 + rng.normal() * 0.5;
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    #[test]
    fn learns_nonlinear_ranking() {
        let (xs, ys) = synth(400, 1);
        let model = CostModel::train(&xs, &ys, &GbdtParams::default());
        let (xt, yt) = synth(200, 2);
        let preds: Vec<f64> = xt.iter().map(|x| model.predict(x)).collect();
        let rho = spearman(&preds, &yt);
        assert!(rho > 0.8, "spearman {rho}");
    }

    #[test]
    fn empty_training_is_safe() {
        let m = CostModel::train(&[], &[], &GbdtParams::default());
        assert!(!m.is_trained());
        assert_eq!(m.predict(&[0.0; NUM_FEATURES]), 0.0);
    }

    #[test]
    fn constant_target_predicts_constant() {
        let xs: Vec<[f64; NUM_FEATURES]> = (0..50).map(|i| [i as f64; NUM_FEATURES]).collect();
        let ys = vec![7.0; 50];
        let m = CostModel::train(&xs, &ys, &GbdtParams::default());
        assert!((m.predict(&[25.0; NUM_FEATURES]) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn improves_with_more_trees() {
        let (xs, ys) = synth(300, 3);
        let weak = CostModel::train(&xs, &ys, &GbdtParams { n_trees: 2, ..Default::default() });
        let strong = CostModel::train(&xs, &ys, &GbdtParams { n_trees: 40, ..Default::default() });
        let mse = |m: &CostModel| -> f64 {
            xs.iter().zip(&ys).map(|(x, y)| (m.predict(x) - y).powi(2)).sum::<f64>() / ys.len() as f64
        };
        assert!(mse(&strong) < mse(&weak));
    }
}
