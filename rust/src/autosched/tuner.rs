//! The Ansor-like auto-scheduling loop.
//!
//! Per Zheng et al. (OSDI 2020), mirrored here:
//!
//! 1. A **task scheduler** slices the trial budget across the model's
//!    unique kernels, allocating each measurement batch to the task with
//!    the highest expected end-to-end gain (use-count × current cost ×
//!    recent improvement rate).
//! 2. Per task, **evolutionary search** over the sketch space proposes
//!    candidates: a population seeded with the best measured schedules
//!    plus random sketches, evolved by mutation/crossover under the
//!    learned cost model, with an ε fraction of pure exploration.
//! 3. Candidates are **measured** (noisy simulator timings) and the
//!    **cost model retrained** after every batch.
//!
//! Every measurement charges real tuning seconds to the search-time
//! ledger: candidate compile/codegen overhead + repeats × kernel runtime
//! (+ RPC overhead when tuning an edge device remotely) — this ledger is
//! what all of the paper's search-time plots are built from.

use super::costmodel::{CostModel, GbdtParams};
use super::features::{features, NUM_FEATURES};
use super::sketch::{crossover, mutate, random_schedule};
use crate::coordinator::jobs::par_map_indexed;
use crate::device::{measure_from_sim, model_time, simulate, untuned_kernel_times, DeviceProfile};
use crate::ir::{Kernel, ModelGraph};
use crate::sched::{apply, serialize, Schedule};
use crate::util::rng::Rng;
use crate::util::stats::spearman;
use std::collections::{HashMap, HashSet};

#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Total measurement trials across all kernels (Ansor recommends
    /// 20 000 for a full DNN; paper Fig 1 uses that).
    pub trials: usize,
    /// Measurements per round (Ansor default 64).
    pub batch_size: usize,
    /// Evolutionary population size.
    pub population: usize,
    /// Evolution generations per round.
    pub generations: usize,
    /// Fraction of each batch reserved for random exploration.
    pub eps_random: f64,
    pub seed: u64,
    /// Cost-model training window (most recent samples per task).
    pub train_window: usize,
    /// Simulated seconds charged per cost-model retrain round.
    pub train_cost_s: f64,
    /// Host threads for each round's candidate evaluation (sketch
    /// application, feature extraction, cost-model prediction, the
    /// deterministic simulator pass). 0 = inherit the `--jobs`/`TT_JOBS`
    /// setting, else auto-detect. Wall-clock only: the seeded draws all
    /// stay serial, so results are bit-identical at any value (see
    /// `crate::coordinator::jobs`).
    pub jobs: usize,
    /// Draft-then-verify keep fraction. With a trained cost model, each
    /// measurement batch is first ranked by the model alone (features +
    /// `CostModel::predict`, no simulator pass) and only the top
    /// `speculative_keep` fraction of valid candidates reaches the
    /// simulate/measure stage. 1.0 (the default) disables the draft
    /// stage entirely and is byte-identical to the exact path. Values
    /// in (0, 1) change which candidates are measured — and thus every
    /// downstream RNG draw — so the keep fraction is part of every
    /// artifact and measure-cache key (see `crate::artifact`).
    pub speculative_keep: f64,
    /// Learned prior seeding every task's cost model. The untrained
    /// default reproduces the historical from-scratch behavior exactly;
    /// a trained prior makes even the first rounds model-guided (no
    /// random-score warmup) and changes every downstream seeded draw,
    /// which is why its content hash is folded into tuning artifact
    /// keys (see [`crate::artifact::tuning_key`]). Each task still
    /// retrains on its own measurements after every round — the prior
    /// is a starting point, not a frozen scorer.
    pub prior: CostModel,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            trials: 20_000,
            batch_size: 64,
            population: 128,
            generations: 4,
            eps_random: 0.1,
            seed: 0xA45,
            train_window: 512,
            train_cost_s: 1.5,
            jobs: 0,
            speculative_keep: 1.0,
            prior: CostModel::default(),
        }
    }
}

/// Best schedule found for one kernel.
#[derive(Clone, Debug)]
pub struct KernelBest {
    pub schedule: Schedule,
    /// Deterministic (noise-free) standalone cost in seconds.
    pub cost_s: f64,
}

/// One point of the tuning trajectory (after each measurement round).
#[derive(Clone, Debug)]
pub struct HistoryPoint {
    pub trials: usize,
    pub search_time_s: f64,
    /// End-to-end model time using the best schedules found so far
    /// (untuned default for not-yet-tuned kernels).
    pub model_time_s: f64,
    /// Spearman rank correlation between the round's pre-measurement
    /// model predictions and its measured log-throughputs — how well
    /// the cost model (prior or retrained) actually ranked this batch.
    /// 0.0 when the round had no trained model or fewer than two
    /// measured candidates. Diagnostic only: NOT persisted by the
    /// artifact codec (round-trips as 0.0) and not part of any key.
    pub rank_corr: f64,
}

#[derive(Clone, Debug)]
pub struct TuningResult {
    pub model: String,
    /// Per unique-kernel index of the graph.
    pub best: HashMap<usize, KernelBest>,
    pub search_time_s: f64,
    pub trials_used: usize,
    pub history: Vec<HistoryPoint>,
}

impl TuningResult {
    /// Model time achievable within a search-time budget (the paper's
    /// "Ansor given the same search time", Fig 5a): the best end-to-end
    /// time of any history point whose ledger fits the budget.
    pub fn model_time_at_budget(&self, budget_s: f64, untuned_s: f64) -> f64 {
        self.history
            .iter()
            .filter(|h| h.search_time_s <= budget_s)
            .map(|h| h.model_time_s)
            .fold(untuned_s, f64::min)
    }

    /// Search time Ansor needs to reach a target model time (Fig 5b);
    /// `None` if it never got there within its budget.
    pub fn time_to_reach(&self, target_model_time_s: f64) -> Option<f64> {
        self.history
            .iter()
            .find(|h| h.model_time_s <= target_model_time_s)
            .map(|h| h.search_time_s)
    }

    pub fn final_model_time(&self, graph: &ModelGraph, profile: &DeviceProfile) -> f64 {
        model_time(graph, profile, |k| {
            self.best
                .get(&k)
                .map(|b| b.schedule.clone())
                .unwrap_or_else(|| Schedule::untuned_default(&graph.kernels[k]))
        })
    }
}

struct TaskState {
    kernel: usize,
    weight: f64, // use count
    rng: Rng,
    xs: Vec<[f64; NUM_FEATURES]>,
    ys: Vec<f64>, // -ln(measured cost): "log throughput"
    measured: HashSet<String>,
    top: Vec<(f64, Schedule)>, // best (cost, schedule) seeds, ascending cost
    model: CostModel,
    best_cost: f64,
    untuned_cost: f64,
    slope: f64,
    rounds: usize,
    /// Set when the kernel's (finite) schedule space is fully measured —
    /// cheap kernels like softmax/pool exhaust their sketch space long
    /// before the trial budget does.
    exhausted: bool,
}

impl TaskState {
    fn record_top(&mut self, cost: f64, sched: Schedule) {
        self.top.push((cost, sched));
        self.top.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        self.top.truncate(16);
    }
}

/// Score one candidate batch for evolutionary selection.
///
/// The pure work — sketch application, feature extraction, cost-model
/// prediction — fans out across the scoped pool into index-ordered
/// slots; the untrained-model exploration scores then draw from the
/// task RNG **serially, in batch order**, exactly the draws a fully
/// serial evaluation makes. That split is what keeps `tune_model`
/// bit-identical at any `jobs` setting.
fn score_batch(
    population: Vec<Schedule>,
    kernel: &Kernel,
    profile: &DeviceProfile,
    model: &CostModel,
    rng: &mut Rng,
    jobs: usize,
) -> Vec<(f64, Schedule)> {
    let trained = model.is_trained();
    // Pure phase (parallel): validity, plus the model score when trained.
    let pure: Vec<Option<f64>> = par_map_indexed(&population, jobs, |_, s| match apply(s, kernel) {
        Err(_) => None,
        Ok(nest) => Some(if trained {
            model.predict(&features(kernel, &nest, profile))
        } else {
            0.0
        }),
    });
    // Serial phase (index order): the seeded exploration draws.
    population
        .into_iter()
        .zip(pure)
        .map(|(s, p)| {
            let score = match p {
                None => f64::NEG_INFINITY,
                Some(predicted) => {
                    if trained {
                        predicted
                    } else {
                        rng.f64()
                    }
                }
            };
            (score, s)
        })
        .collect()
}

/// Run the auto-scheduler over a whole model graph.
pub fn tune_model(graph: &ModelGraph, profile: &DeviceProfile, opts: &TuneOptions) -> TuningResult {
    let mut root_rng = Rng::new(opts.seed ^ crate::ir::workload::fnv1a(graph.name.as_bytes()));
    let untuned = untuned_kernel_times(graph, profile);

    let mut tasks: Vec<TaskState> = graph
        .kernels
        .iter()
        .enumerate()
        .map(|(i, _)| TaskState {
            kernel: i,
            weight: graph.use_count(i) as f64,
            rng: root_rng.fork(i as u64),
            xs: Vec::new(),
            ys: Vec::new(),
            measured: HashSet::new(),
            top: Vec::new(),
            model: opts.prior.clone(),
            best_cost: f64::INFINITY,
            untuned_cost: untuned[i] / graph.use_count(i).max(1) as f64,
            slope: 1.0,
            rounds: 0,
            exhausted: false,
        })
        .collect();

    let mut ledger = 0.0f64;
    let mut trials_used = 0usize;
    let mut history: Vec<HistoryPoint> = Vec::new();
    let gbdt = GbdtParams::default();

    let model_time_now = |tasks: &[TaskState]| -> f64 {
        model_time(graph, profile, |k| {
            let t = &tasks[k];
            if t.best_cost.is_finite() {
                t.top[0].1.clone()
            } else {
                Schedule::untuned_default(&graph.kernels[k])
            }
        })
    };

    /// What the measurement stage decided for one batch slot: rejected
    /// by the compiler, rejected by the draft scorer, or simulated and
    /// ready for its (serial) measurement draw.
    enum Prep {
        Invalid,
        Pruned,
        Measured(f64, [f64; NUM_FEATURES]),
    }

    let mut round_robin = 0usize;
    while trials_used < opts.trials {
        // ---- task selection (gradient allocation with warmup) ----------
        if tasks.iter().all(|t| t.exhausted) {
            break; // every kernel's schedule space fully measured
        }
        let ti = loop {
            if round_robin < tasks.len() {
                let t = round_robin;
                round_robin += 1;
                if tasks[t].exhausted {
                    continue;
                }
                break t;
            }
            let mut best_t = None;
            let mut best_gain = f64::NEG_INFINITY;
            for (i, t) in tasks.iter().enumerate() {
                if t.exhausted {
                    continue;
                }
                let cost = if t.best_cost.is_finite() { t.best_cost } else { t.untuned_cost };
                let gain = t.weight * cost * t.slope.max(0.02);
                if gain > best_gain {
                    best_gain = gain;
                    best_t = Some(i);
                }
            }
            break best_t.expect("checked above: some task not exhausted");
        };

        let n = opts.batch_size.min(opts.trials - trials_used);
        let task = &mut tasks[ti];
        let kernel = &graph.kernels[task.kernel];

        // ---- candidate proposal: evolutionary search -------------------
        let mut population: Vec<Schedule> = task.top.iter().map(|(_, s)| s.clone()).collect();
        while population.len() < opts.population {
            population.push(random_schedule(kernel, &mut task.rng));
        }
        for _gen in 0..opts.generations {
            let mut scored = score_batch(
                std::mem::take(&mut population),
                kernel,
                profile,
                &task.model,
                &mut task.rng,
                opts.jobs,
            );
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            scored.truncate(opts.population / 2);
            let elites: Vec<Schedule> = scored.into_iter().map(|(_, s)| s).collect();
            population = elites.clone();
            while population.len() < opts.population {
                let a = task.rng.choose(&elites).clone();
                let child = if task.rng.bool(0.3) && elites.len() > 1 {
                    let b = task.rng.choose(&elites);
                    crossover(&a, b, &mut task.rng)
                } else {
                    a
                };
                population.push(mutate(&child, kernel, &mut task.rng));
            }
        }

        // ---- batch selection: top-predicted + eps random, unmeasured ---
        let mut scored = score_batch(
            std::mem::take(&mut population),
            kernel,
            profile,
            &task.model,
            &mut task.rng,
            opts.jobs,
        );
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let n_random = ((n as f64) * opts.eps_random).ceil() as usize;
        let mut batch: Vec<Schedule> = Vec::with_capacity(n);
        for (_, s) in scored {
            if batch.len() + n_random >= n {
                break;
            }
            let key = serialize::to_string(&s);
            if task.measured.insert(key) {
                batch.push(s);
            }
        }
        // Top up with random exploration — bounded attempts: cheap
        // kernels (pool/softmax) have finite sketch spaces that a big
        // trial budget exhausts completely.
        let mut attempts = 0usize;
        while batch.len() < n && attempts < 200 * n {
            attempts += 1;
            let s = random_schedule(kernel, &mut task.rng);
            let key = serialize::to_string(&s);
            if task.measured.insert(key) {
                batch.push(s);
            }
        }
        if batch.is_empty() {
            task.exhausted = true;
            continue;
        }

        // ---- measurement + ledger --------------------------------------
        // Parallel phase: sketch application, the deterministic
        // simulator pass, and feature extraction fan out into
        // index-ordered slots. Serial phase: the seeded measurement
        // jitter and every mutable update run in batch order — exactly
        // the RNG draws a serial loop makes, so the round is
        // bit-identical at any thread count.
        //
        // With `speculative_keep < 1.0` and a trained model, a draft
        // stage fronts the verify stage: candidates are ranked by the
        // model alone (features + predict, no simulate) and only the
        // top keep-fraction reaches the simulator and the ledger.
        // Pruned candidates still consume trial budget (they were
        // proposed and stay in `measured`, never to be retried) but
        // charge nothing and draw nothing — skipped draws shift every
        // later seeded draw, which is why the keep fraction is part of
        // every artifact and measure-cache key.
        let prev_best = if task.best_cost.is_finite() { task.best_cost } else { task.untuned_cost };
        // Per-round model diagnostics: the round's pre-measurement
        // predictions vs its measured log-throughputs (rank_corr in the
        // history point). Read-only — no draws, no ledger, no key
        // impact.
        let mut round_preds: Vec<f64> = Vec::new();
        let mut round_meas: Vec<f64> = Vec::new();
        let speculative = opts.speculative_keep < 1.0 && task.model.is_trained();
        let preps: Vec<Prep> = if !speculative {
            // Exact path (keep = 1.0, or model not yet trained): every
            // valid candidate is simulated — byte-identical to the
            // pre-speculative pipeline.
            par_map_indexed(&batch, opts.jobs, |_, s| {
                apply(s, kernel).ok().map(|nest| {
                    (simulate(kernel, &nest, profile).total_s, features(kernel, &nest, profile))
                })
            })
            .into_iter()
            .map(|p| match p {
                None => Prep::Invalid,
                Some((sim_s, feats)) => Prep::Measured(sim_s, feats),
            })
            .collect()
        } else {
            // Draft: apply + features + predict only (pure, parallel,
            // index-ordered slots — no simulator pass).
            let model = &task.model;
            let drafts = par_map_indexed(&batch, opts.jobs, |_, s| {
                apply(s, kernel).ok().map(|nest| {
                    let feats = features(kernel, &nest, profile);
                    let score = model.predict(&feats);
                    (nest, feats, score)
                })
            });
            // Rank valid drafts by (score desc, index asc — the
            // deterministic tie-break) and keep the top fraction,
            // always at least one when any candidate is valid.
            let mut order: Vec<usize> =
                (0..drafts.len()).filter(|&i| drafts[i].is_some()).collect();
            let n_valid = order.len();
            order.sort_by(|&a, &b| {
                let sa = drafts[a].as_ref().expect("valid draft").2;
                let sb = drafts[b].as_ref().expect("valid draft").2;
                sb.partial_cmp(&sa).expect("finite draft scores").then(a.cmp(&b))
            });
            let n_keep = if n_valid == 0 {
                0
            } else {
                ((opts.speculative_keep * n_valid as f64).ceil() as usize).clamp(1, n_valid)
            };
            let survivors: Vec<usize> = {
                let mut kept: Vec<usize> = order.into_iter().take(n_keep).collect();
                kept.sort_unstable();
                kept
            };
            // Verify: the simulator pass, survivors only, reusing each
            // draft's applied nest.
            let nests: Vec<_> =
                survivors.iter().map(|&i| drafts[i].as_ref().expect("valid draft")).collect();
            let sims: Vec<f64> =
                par_map_indexed(&nests, opts.jobs, |_, d| simulate(kernel, &d.0, profile).total_s);
            let mut sim_of: HashMap<usize, f64> =
                survivors.into_iter().zip(sims).collect();
            drafts
                .into_iter()
                .enumerate()
                .map(|(i, d)| match d {
                    None => Prep::Invalid,
                    Some((_nest, feats, _score)) => match sim_of.remove(&i) {
                        Some(sim_s) => Prep::Measured(sim_s, feats),
                        None => Prep::Pruned,
                    },
                })
                .collect()
        };
        for (s, prep) in batch.into_iter().zip(preps) {
            trials_used += 1;
            match prep {
                Prep::Invalid => {
                    // Invalid candidates still cost codegen time before
                    // the compiler rejects them.
                    ledger += 0.3 * profile.measure_overhead_s + profile.rpc_overhead_s * 0.3;
                }
                Prep::Pruned => {
                    // Draft-rejected: the trial is spent but the device
                    // never runs it — no charge, no measurement draw,
                    // no training sample.
                }
                Prep::Measured(sim_s, feats) => {
                    let cost = measure_from_sim(sim_s, profile, &mut task.rng);
                    ledger += profile.measure_overhead_s
                        + profile.rpc_overhead_s
                        + profile.measure_repeats as f64 * cost;
                    if task.model.is_trained() {
                        round_preds.push(task.model.predict(&feats));
                        round_meas.push(-(cost.max(1e-12)).ln());
                    }
                    task.xs.push(feats);
                    task.ys.push(-(cost.max(1e-12)).ln());
                    if cost < task.best_cost {
                        task.best_cost = cost;
                    }
                    task.record_top(cost, s);
                }
            }
        }

        // ---- retrain cost model ----------------------------------------
        let lo = task.xs.len().saturating_sub(opts.train_window);
        task.model = CostModel::train(&task.xs[lo..], &task.ys[lo..], &gbdt);
        ledger += opts.train_cost_s;
        task.rounds += 1;

        // Improvement slope (EMA of relative gain per round).
        let new_best = if task.best_cost.is_finite() { task.best_cost } else { prev_best };
        let rel_gain = ((prev_best - new_best) / prev_best).max(0.0);
        task.slope = 0.5 * task.slope + 0.5 * rel_gain;

        let rank_corr = if round_preds.len() >= 2 {
            let r = spearman(&round_preds, &round_meas);
            if r.is_finite() { r } else { 0.0 }
        } else {
            0.0
        };
        history.push(HistoryPoint {
            trials: trials_used,
            search_time_s: ledger,
            model_time_s: model_time_now(&tasks),
            rank_corr,
        });
    }

    let best: HashMap<usize, KernelBest> = tasks
        .iter()
        .filter(|t| !t.top.is_empty())
        .map(|t| {
            // Re-evaluate the best schedule deterministically.
            let sched = t.top[0].1.clone();
            let nest = apply(&sched, &graph.kernels[t.kernel]).expect("best schedule must apply");
            let cost = crate::device::simulate(&graph.kernels[t.kernel], &nest, profile).total_s;
            (t.kernel, KernelBest { schedule: sched, cost_s: cost })
        })
        .collect();

    TuningResult {
        model: graph.name.clone(),
        best,
        search_time_s: ledger,
        trials_used,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::untuned_model_time;
    use crate::ir::KernelBuilder;

    fn tiny_opts(trials: usize) -> TuneOptions {
        TuneOptions {
            trials,
            batch_size: 16,
            population: 32,
            generations: 2,
            seed: 7,
            ..Default::default()
        }
    }

    fn gemm_graph() -> ModelGraph {
        let mut g = ModelGraph::new("gemm-bench");
        g.push(KernelBuilder::dense(512, 512, 512, &[]));
        g
    }

    #[test]
    fn tuning_improves_over_untuned() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let untuned = untuned_model_time(&g, &prof);
        let res = tune_model(&g, &prof, &tiny_opts(128));
        let tuned = res.final_model_time(&g, &prof);
        assert!(
            tuned < untuned,
            "tuning failed to improve: {tuned} vs untuned {untuned}"
        );
    }

    #[test]
    fn more_trials_do_not_hurt() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let small = tune_model(&g, &prof, &tiny_opts(32));
        let large = tune_model(&g, &prof, &tiny_opts(256));
        assert!(
            large.final_model_time(&g, &prof) <= small.final_model_time(&g, &prof) * 1.05,
            "best-so-far must be monotone-ish"
        );
    }

    #[test]
    fn ledger_is_positive_and_monotone() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let res = tune_model(&g, &prof, &tiny_opts(64));
        assert!(res.search_time_s > 0.0);
        let mut prev = 0.0;
        for h in &res.history {
            assert!(h.search_time_s >= prev);
            prev = h.search_time_s;
        }
        assert_eq!(res.trials_used, 64);
    }

    #[test]
    fn deterministic_per_seed() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let a = tune_model(&g, &prof, &tiny_opts(48));
        let b = tune_model(&g, &prof, &tiny_opts(48));
        assert_eq!(a.search_time_s, b.search_time_s);
        assert_eq!(
            a.final_model_time(&g, &prof),
            b.final_model_time(&g, &prof)
        );
    }

    #[test]
    fn bit_identical_at_any_job_count() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let reference = tune_model(&g, &prof, &TuneOptions { jobs: 1, ..tiny_opts(64) });
        for jobs in [2, 8] {
            let par = tune_model(&g, &prof, &TuneOptions { jobs, ..tiny_opts(64) });
            assert_eq!(
                par.search_time_s.to_bits(),
                reference.search_time_s.to_bits(),
                "ledger drifted at jobs={jobs}"
            );
            assert_eq!(par.trials_used, reference.trials_used);
            assert_eq!(
                par.final_model_time(&g, &prof).to_bits(),
                reference.final_model_time(&g, &prof).to_bits(),
                "best schedules drifted at jobs={jobs}"
            );
        }
    }

    #[test]
    fn speculative_keep_prunes_charges_but_spends_the_whole_budget() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let exact = tune_model(&g, &prof, &tiny_opts(64));
        let spec = tune_model(
            &g,
            &prof,
            &TuneOptions { speculative_keep: 0.25, ..tiny_opts(64) },
        );
        // Pruned candidates still consume trial budget...
        assert_eq!(spec.trials_used, exact.trials_used);
        // ...but never reach the device, so the charged ledger shrinks.
        assert!(
            spec.search_time_s < exact.search_time_s,
            "draft stage never pruned: {} vs {}",
            spec.search_time_s,
            exact.search_time_s
        );
        // Quality parity: the draft scorer may reorder exploration but
        // must not wreck the final schedule.
        let e = exact.final_model_time(&g, &prof);
        let s = spec.final_model_time(&g, &prof);
        assert!(s <= e * 2.0, "speculative quality collapsed: {s} vs exact {e}");
    }

    #[test]
    fn speculative_keep_bit_identical_at_any_job_count() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let spec_opts = |jobs| TuneOptions { speculative_keep: 0.5, jobs, ..tiny_opts(64) };
        let reference = tune_model(&g, &prof, &spec_opts(1));
        for jobs in [2, 8] {
            let par = tune_model(&g, &prof, &spec_opts(jobs));
            assert_eq!(
                par.search_time_s.to_bits(),
                reference.search_time_s.to_bits(),
                "speculative ledger drifted at jobs={jobs}"
            );
            assert_eq!(par.trials_used, reference.trials_used);
            assert_eq!(
                par.final_model_time(&g, &prof).to_bits(),
                reference.final_model_time(&g, &prof).to_bits(),
                "speculative best schedules drifted at jobs={jobs}"
            );
        }
    }

    #[test]
    fn speculative_keep_one_is_the_exact_path() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let exact = tune_model(&g, &prof, &tiny_opts(48));
        let kept = tune_model(
            &g,
            &prof,
            &TuneOptions { speculative_keep: 1.0, ..tiny_opts(48) },
        );
        assert_eq!(exact.search_time_s.to_bits(), kept.search_time_s.to_bits());
        assert_eq!(exact.trials_used, kept.trials_used);
        assert_eq!(
            exact.final_model_time(&g, &prof).to_bits(),
            kept.final_model_time(&g, &prof).to_bits()
        );
    }

    /// A genuinely informative prior: fit on simulated timings of the
    /// kernel's own random schedules, so its predictions vary across
    /// the candidates the tuner proposes.
    fn synth_prior(kernel: &Kernel, prof: &DeviceProfile) -> CostModel {
        let mut rng = Rng::new(99);
        let mut xs: Vec<[f64; NUM_FEATURES]> = Vec::new();
        let mut ys: Vec<f64> = Vec::new();
        while xs.len() < 64 {
            let s = random_schedule(kernel, &mut rng);
            if let Ok(nest) = apply(&s, kernel) {
                xs.push(features(kernel, &nest, prof));
                ys.push(-(simulate(kernel, &nest, prof).total_s.max(1e-12)).ln());
            }
        }
        let m = CostModel::train(&xs, &ys, &GbdtParams::default());
        assert!(m.is_trained());
        m
    }

    #[test]
    fn trained_prior_changes_the_trajectory_deterministically() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let prior = synth_prior(&g.kernels[0], &prof);
        let a = tune_model(&g, &prof, &TuneOptions { prior: prior.clone(), ..tiny_opts(48) });
        let b = tune_model(&g, &prof, &TuneOptions { prior: prior.clone(), ..tiny_opts(48) });
        assert_eq!(a.search_time_s.to_bits(), b.search_time_s.to_bits());
        assert_eq!(
            a.final_model_time(&g, &prof).to_bits(),
            b.final_model_time(&g, &prof).to_bits()
        );
        // The prior replaces the untrained model's random exploration
        // scores from round one, so the whole trajectory moves — which
        // is exactly why a trained prior re-keys tuning artifacts.
        let plain = tune_model(&g, &prof, &tiny_opts(48));
        assert_ne!(a.search_time_s.to_bits(), plain.search_time_s.to_bits());
        // An untrained prior IS the default path, byte-for-byte.
        let inert = tune_model(
            &g,
            &prof,
            &TuneOptions { prior: CostModel::default(), ..tiny_opts(48) },
        );
        assert_eq!(inert.search_time_s.to_bits(), plain.search_time_s.to_bits());
    }

    #[test]
    fn history_tracks_rank_correlation_once_the_model_trains() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let res = tune_model(&g, &prof, &tiny_opts(96));
        // Round one runs under the untrained model: no correlation.
        assert_eq!(res.history[0].rank_corr, 0.0);
        assert!(res.history.iter().all(|h| h.rank_corr.abs() <= 1.0 + 1e-9));
        assert!(
            res.history.iter().any(|h| h.rank_corr != 0.0),
            "no round ever recorded a model-vs-measurement correlation"
        );
        // With a trained prior, even round one is scored by a model.
        let primed = tune_model(
            &g,
            &prof,
            &TuneOptions { prior: synth_prior(&g.kernels[0], &prof), ..tiny_opts(96) },
        );
        assert_ne!(primed.history[0].rank_corr, 0.0);
    }

    #[test]
    fn budget_lookup_matches_history() {
        let prof = DeviceProfile::xeon_e5_2620();
        let g = gemm_graph();
        let res = tune_model(&g, &prof, &tiny_opts(64));
        let untuned = untuned_model_time(&g, &prof);
        // Zero budget -> untuned.
        assert_eq!(res.model_time_at_budget(0.0, untuned), untuned);
        // Full budget -> best history point.
        let best_hist = res.history.iter().map(|h| h.model_time_s).fold(f64::INFINITY, f64::min);
        assert_eq!(res.model_time_at_budget(f64::INFINITY, untuned), best_hist.min(untuned));
    }

    #[test]
    fn rpc_overhead_inflates_edge_search_time() {
        let g = gemm_graph();
        let xeon = tune_model(&g, &DeviceProfile::xeon_e5_2620(), &tiny_opts(32));
        let edge = tune_model(&g, &DeviceProfile::cortex_a72(), &tiny_opts(32));
        assert!(edge.search_time_s > xeon.search_time_s);
    }

    #[test]
    fn multi_kernel_graph_allocates_to_expensive_tasks() {
        let prof = DeviceProfile::xeon_e5_2620();
        let mut g = ModelGraph::new("mixed");
        g.push(KernelBuilder::dense(512, 512, 512, &[]));
        g.push(KernelBuilder::pool2d(crate::ir::OpKind::MaxPool2d, 1, 64, 56, 56, 2, 2, 2));
        let res = tune_model(&g, &prof, &tiny_opts(160));
        // The dense kernel must end up tuned (it dominates cost).
        assert!(res.best.contains_key(&0));
    }
}
