//! Structural feature extraction for the learned cost model.
//!
//! Ansor featurizes the lowered loop nest per innermost statement
//! (touched bytes per cache level, vectorization, parallelism, ...). We
//! extract the analogous quantities from the scheduled nest. Everything
//! here is *structural* — the cost model never sees the simulator's
//! traffic analysis, it must learn the mapping from these features to
//! measured time, imperfectly, like a real learned cost model.

use crate::device::DeviceProfile;
use crate::ir::Kernel;
use crate::sched::{Ann, ScheduledNest};

pub const NUM_FEATURES: usize = 18;

fn log2p(x: f64) -> f64 {
    (x.max(1e-12)).log2()
}

/// Extract the feature vector for one scheduled kernel.
pub fn features(kernel: &Kernel, nest: &ScheduledNest, profile: &DeviceProfile) -> [f64; NUM_FEATURES] {
    let ln = &kernel.nest;
    let mut f = [0.0f64; NUM_FEATURES];

    let flops = ln.flops();
    f[0] = log2p(flops);
    f[1] = log2p(ln.output_points());

    // Vector / parallel structure.
    let lanes = profile.simd_lanes_f32() as f64;
    let ve = nest.vector_extent() as f64;
    f[2] = if ve > 1.0 { ve / ((ve / lanes).ceil() * lanes) } else { 0.0 };
    f[3] = log2p(ve);
    let pe = nest.parallel_extent() as f64;
    f[4] = log2p(pe / profile.cores as f64);
    f[5] = if pe > 1.0 {
        pe / ((pe / profile.cores as f64).ceil() * profile.cores as f64)
    } else {
        0.0
    };

    // Tile working sets at two inner scopes vs the cache sizes.
    // Reconstruct per-axis inner extents from the innermost `take` loops.
    let mut tile = vec![1u64; ln.axes.len()];
    let mut ws_inner = 0.0; // working set inside the innermost 3 loops
    let mut ws_mid = 0.0; // inside the innermost 6 loops
    for (i, l) in nest.loops.iter().rev().enumerate() {
        tile[l.axis] = tile[l.axis].saturating_mul(l.extent.max(1));
        if i + 1 == 3.min(nest.loops.len()) {
            ws_inner = ln.buffers.iter().map(|b| b.footprint_bytes(&tile) as f64).sum();
        }
        if i + 1 == 6.min(nest.loops.len()) {
            ws_mid = ln.buffers.iter().map(|b| b.footprint_bytes(&tile) as f64).sum();
        }
    }
    let full_ws: f64 = ln.total_data_bytes() as f64;
    if ws_inner == 0.0 {
        ws_inner = full_ws;
    }
    if ws_mid == 0.0 {
        ws_mid = full_ws;
    }
    let l1 = profile.caches.first().map(|c| c.bytes as f64).unwrap_or(32e3);
    let llc = profile.caches.last().map(|c| c.bytes as f64).unwrap_or(1e6);
    f[6] = log2p(ws_inner / l1);
    f[7] = log2p(ws_mid / llc);
    f[8] = log2p(full_ws);

    // Arithmetic intensity (flops per byte touched once).
    f[9] = log2p(flops / full_ws.max(1.0));

    // Unroll volume.
    let unrolled: f64 = nest
        .loops
        .iter()
        .filter(|l| l.ann == Ann::Unroll)
        .map(|l| l.extent.max(1) as f64)
        .product();
    f[10] = log2p(unrolled);
    f[11] = if nest.cache_write { 1.0 } else { 0.0 };
    f[12] = nest.waste;
    f[13] = nest.loops.len() as f64;

    // Innermost contiguity of each non-output buffer's last dim (mean of
    // logs) — proxy for cache-line utilization.
    let mut contig_sum = 0.0;
    let mut nb = 0.0;
    for b in &ln.buffers {
        if let Some(d) = b.dims.last() {
            contig_sum += log2p(d.range_size(&tile) as f64);
            nb += 1.0;
        }
    }
    f[14] = if nb > 0.0 { contig_sum / nb } else { 0.0 };

    // Reduction structure: extent of reduction work inside the innermost
    // spatial tile, and whether reductions sit outside the vector loop.
    let red_inner: f64 = kernel
        .nest
        .reduction_axes()
        .map(|(i, _)| tile[i] as f64)
        .product();
    f[15] = log2p(red_inner);
    f[16] = nest
        .loops
        .iter()
        .position(|l| l.ann == Ann::Vectorize)
        .map(|p| (nest.loops.len() - 1 - p) as f64)
        .unwrap_or(-1.0);
    f[17] = log2p(ln.epilogue_ops + 1.0);

    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::KernelBuilder;
    use crate::sched::{apply, Schedule};

    #[test]
    fn features_finite_and_distinct() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let naive = apply(&Schedule::naive(&k), &k).unwrap();
        let tuned = apply(&Schedule::untuned_default(&k), &k).unwrap();
        let fa = features(&k, &naive, &prof);
        let fb = features(&k, &tuned, &prof);
        assert!(fa.iter().all(|x| x.is_finite()));
        assert!(fb.iter().all(|x| x.is_finite()));
        assert_ne!(fa, fb);
    }

    #[test]
    fn vector_feature_tracks_annotation() {
        let prof = DeviceProfile::xeon_e5_2620();
        let k = KernelBuilder::dense(512, 512, 512, &[]);
        let tuned = apply(&Schedule::untuned_default(&k), &k).unwrap();
        let f = features(&k, &tuned, &prof);
        assert!(f[2] > 0.9, "vector utilization feature {}", f[2]);
    }
}
