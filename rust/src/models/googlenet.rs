//! GoogLeNet / Inception-V1 (Szegedy et al., CVPR 2015).
//!
//! Table 2 row M7: B(10) max-pools, C(1) global pool, D(1) classifier,
//! E(49) conv kernels — 95% of untuned inference time. The 9 inception
//! modules each contribute six conv kernels (1x1, 3x3-reduce, 3x3,
//! 5x5-reduce, 5x5, pool-proj) plus a 3x3/1 max-pool; dedup brings the
//! conv count to ~49 unique.

use crate::ir::{KernelBuilder, ModelGraph, OpKind};

const BIAS_RELU: &[OpKind] = &[OpKind::BiasAdd, OpKind::Relu];

struct Inception {
    hw: u64,
    in_c: u64,
    c1: u64,
    c3r: u64,
    c3: u64,
    c5r: u64,
    c5: u64,
    pp: u64,
}

fn inception(g: &mut ModelGraph, m: &Inception) {
    // Branch 1: 1x1.
    g.push(KernelBuilder::conv2d(1, m.in_c, m.hw, m.hw, m.c1, 1, 1, 1, 0, BIAS_RELU));
    // Branch 2: 1x1 reduce + 3x3.
    g.push(KernelBuilder::conv2d(1, m.in_c, m.hw, m.hw, m.c3r, 1, 1, 1, 0, BIAS_RELU));
    g.push(KernelBuilder::conv2d(1, m.c3r, m.hw, m.hw, m.c3, 3, 3, 1, 1, BIAS_RELU));
    // Branch 3: 1x1 reduce + 5x5.
    g.push(KernelBuilder::conv2d(1, m.in_c, m.hw, m.hw, m.c5r, 1, 1, 1, 0, BIAS_RELU));
    g.push(KernelBuilder::conv2d(1, m.c5r, m.hw, m.hw, m.c5, 5, 5, 1, 2, BIAS_RELU));
    // Branch 4: 3x3/1 max-pool + 1x1 projection.
    g.push(KernelBuilder::pool2d(OpKind::MaxPool2d, 1, m.in_c, m.hw + 2, m.hw + 2, 3, 3, 1));
    g.push(KernelBuilder::conv2d(1, m.in_c, m.hw, m.hw, m.pp, 1, 1, 1, 0, BIAS_RELU));
}

pub fn googlenet() -> ModelGraph {
    let mut g = ModelGraph::new("GoogLeNet");
    // Stem.
    g.push(KernelBuilder::conv2d(1, 3, 224, 224, 64, 7, 7, 2, 3, BIAS_RELU));
    g.push(KernelBuilder::pool2d(OpKind::MaxPool2d, 1, 64, 112, 112, 3, 3, 2));
    g.push(KernelBuilder::conv2d(1, 64, 56, 56, 64, 1, 1, 1, 0, BIAS_RELU));
    g.push(KernelBuilder::conv2d(1, 64, 56, 56, 192, 3, 3, 1, 1, BIAS_RELU));
    g.push(KernelBuilder::pool2d(OpKind::MaxPool2d, 1, 192, 56, 56, 3, 3, 2));

    // Inception 3a/3b @28, 4a-4e @14, 5a/5b @7 (channel configs from the
    // paper's Table 1 of GoogLeNet).
    let modules = [
        Inception { hw: 28, in_c: 192, c1: 64, c3r: 96, c3: 128, c5r: 16, c5: 32, pp: 32 },
        Inception { hw: 28, in_c: 256, c1: 128, c3r: 128, c3: 192, c5r: 32, c5: 96, pp: 64 },
    ];
    for m in &modules {
        inception(&mut g, m);
    }
    g.push(KernelBuilder::pool2d(OpKind::MaxPool2d, 1, 480, 28, 28, 3, 3, 2));
    let modules4 = [
        Inception { hw: 14, in_c: 480, c1: 192, c3r: 96, c3: 208, c5r: 16, c5: 48, pp: 64 },
        Inception { hw: 14, in_c: 512, c1: 160, c3r: 112, c3: 224, c5r: 24, c5: 64, pp: 64 },
        Inception { hw: 14, in_c: 512, c1: 128, c3r: 128, c3: 256, c5r: 24, c5: 64, pp: 64 },
        Inception { hw: 14, in_c: 512, c1: 112, c3r: 144, c3: 288, c5r: 32, c5: 64, pp: 64 },
        Inception { hw: 14, in_c: 528, c1: 256, c3r: 160, c3: 320, c5r: 32, c5: 128, pp: 128 },
    ];
    for m in &modules4 {
        inception(&mut g, m);
    }
    g.push(KernelBuilder::pool2d(OpKind::MaxPool2d, 1, 832, 14, 14, 3, 3, 2));
    let modules5 = [
        Inception { hw: 7, in_c: 832, c1: 256, c3r: 160, c3: 320, c5r: 32, c5: 128, pp: 128 },
        Inception { hw: 7, in_c: 832, c1: 384, c3r: 192, c3: 384, c5r: 48, c5: 128, pp: 128 },
    ];
    for m in &modules5 {
        inception(&mut g, m);
    }

    g.push(KernelBuilder::global_avg_pool(1, 1024, 7, 7));
    g.push(KernelBuilder::dense(1, 1024, 1000, &[OpKind::Add]));
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn matches_table2_row_m7() {
        let g = googlenet();
        let mut c: BTreeMap<String, usize> = BTreeMap::new();
        for k in &g.kernels {
            *c.entry(k.class_signature()).or_insert(0) += 1;
        }
        // Paper: B(10), C(1), D(1), E(49).
        assert_eq!(c["global_avg_pool2d"], 1);
        assert_eq!(c["dense_add"], 1);
        let pools = c["max_pool2d"];
        assert!((8..=12).contains(&pools), "max pools {pools}");
        let convs = c["conv2d_bias_relu"];
        assert!((45..=56).contains(&convs), "conv kernels {convs} (paper: 49)");
    }

    #[test]
    fn conv_time_dominates() {
        // Class E is 95% of untuned time in the paper; structurally the
        // conv kernels must carry nearly all FLOPs.
        let g = googlenet();
        let conv_flops: f64 = g
            .instances
            .iter()
            .map(|i| &g.kernels[i.kernel])
            .filter(|k| k.class_signature() == "conv2d_bias_relu")
            .map(|k| k.flops())
            .sum();
        assert!(conv_flops / g.total_flops() > 0.9);
    }
}
