//! MnasNet-1.0 (Tan et al., CVPR 2019) — NAS-designed mobile model.
//!
//! Table 2 row M8 classes: A projection convs with residual, D
//! classifier, E `conv2d_bias_relu` (expansion + stem convs; MnasNet
//! uses plain ReLU), K `dwconv2d_bias_relu6` (the NAS picks some
//! relu6-capped depthwise stages), P `dwconv2d_bias_relu`.
//! Crucially, MnasNet *shares class E with the ResNet/VGG/GoogLeNet
//! family*, which is why the paper's heuristic sends GoogLeNet's 49
//! class-E schedules its way (Table 3: M7 gives the best speedup).

use crate::ir::{KernelBuilder, ModelGraph, OpKind};

const BIAS_RELU: &[OpKind] = &[OpKind::BiasAdd, OpKind::Relu];
const BIAS_RELU6: &[OpKind] = &[OpKind::BiasAdd, OpKind::Relu6];

/// (expansion, out_c, repeats, stride, kernel, use_relu6_depthwise)
const BLOCKS: &[(u64, u64, u64, u64, u64, bool)] = &[
    (1, 16, 1, 1, 3, false),
    (6, 24, 3, 2, 3, false),
    (3, 40, 3, 2, 5, true),
    (6, 80, 3, 2, 5, false),
    (6, 96, 2, 1, 3, true),
    (6, 192, 4, 2, 5, false),
    (6, 320, 1, 1, 3, true),
];

pub fn mnasnet_1_0() -> ModelGraph {
    let mut g = ModelGraph::new("MnasNet1.0");
    g.push(KernelBuilder::conv2d(1, 3, 224, 224, 32, 3, 3, 2, 1, BIAS_RELU));

    let mut in_c = 32u64;
    let mut hw = 112u64;
    for &(t, c, n, s, k, relu6_dw) in BLOCKS {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let exp_c = in_c * t;
            if t != 1 {
                // Expansion conv (class E — plain ReLU in MnasNet).
                g.push(KernelBuilder::conv2d(1, in_c, hw, hw, exp_c, 1, 1, 1, 0, BIAS_RELU));
            }
            let pad = k / 2;
            // Depthwise: class P (relu) or K (relu6) depending on stage.
            let fused: &[OpKind] = if relu6_dw { BIAS_RELU6 } else { BIAS_RELU };
            g.push(KernelBuilder::depthwise_conv2d(1, exp_c, hw, hw, k, k, stride, pad, fused));
            let out_hw = hw / stride;
            // Projection: class A with residual, plain conv2d without.
            if stride == 1 && in_c == c {
                g.push(KernelBuilder::conv2d(1, exp_c, out_hw, out_hw, c, 1, 1, 1, 0, &[OpKind::Add]));
            } else {
                g.push(KernelBuilder::conv2d(1, exp_c, out_hw, out_hw, c, 1, 1, 1, 0, &[]));
            }
            in_c = c;
            hw = out_hw;
        }
    }
    g.push(KernelBuilder::conv2d(1, 320, 7, 7, 1280, 1, 1, 1, 0, BIAS_RELU));
    g.push(KernelBuilder::global_avg_pool(1, 1280, 7, 7));
    g.push(KernelBuilder::dense(1, 1280, 1000, &[OpKind::Add]));
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn class_structure_matches_m8() {
        let g = mnasnet_1_0();
        let mut c: BTreeMap<String, usize> = BTreeMap::new();
        for k in &g.kernels {
            *c.entry(k.class_signature()).or_insert(0) += 1;
        }
        // Paper M8: A(7) D(1) E(9) K(5) P(12).
        assert_eq!(c["dense_add"], 1);
        assert!((5..=9).contains(&c["conv2d_add"]), "A = {}", c["conv2d_add"]);
        assert!((7..=12).contains(&c["conv2d_bias_relu"]), "E = {}", c["conv2d_bias_relu"]);
        assert!(c.contains_key("dwconv2d_bias_relu6"), "K missing");
        assert!(c.contains_key("dwconv2d_bias_relu"), "P missing");
    }

    #[test]
    fn shares_class_e_with_googlenet() {
        let g = mnasnet_1_0();
        assert!(!g.kernels_of_class("conv2d_bias_relu").is_empty());
    }
}
