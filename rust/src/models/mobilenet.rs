//! MobileNetV2 (Sandler et al., CVPR 2018).
//!
//! Table 2 row M4 classes: A `conv2d_add` (linear-bottleneck projections
//! fused with the residual add), C global pool, D classifier,
//! J `conv2d_bias_relu6` (expansion 1x1 convs + stem), K
//! `dwconv2d_bias_relu6` (depthwise), L plain `conv2d` (projections
//! without residual). Roughly half of the untuned time sits in classes
//! J/L that EfficientNet lacks, which the paper calls out in §5.2.

use crate::ir::{KernelBuilder, ModelGraph, OpKind};

const BIAS_RELU6: &[OpKind] = &[OpKind::BiasAdd, OpKind::Relu6];

/// Inverted-residual block config: (expansion t, out channels c,
/// repeats n, stride s) — Table 2 of the MobileNetV2 paper.
const BLOCKS: &[(u64, u64, u64, u64)] = &[
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

pub fn mobilenet_v2() -> ModelGraph {
    let mut g = ModelGraph::new("MobileNetV2");
    // Stem: 32 filters 3x3/2 + relu6.
    g.push(KernelBuilder::conv2d(1, 3, 224, 224, 32, 3, 3, 2, 1, BIAS_RELU6));

    let mut in_c = 32u64;
    let mut hw = 112u64;
    for &(t, c, n, s) in BLOCKS {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            let exp_c = in_c * t;
            if t != 1 {
                // Expansion 1x1 (class J).
                g.push(KernelBuilder::conv2d(1, in_c, hw, hw, exp_c, 1, 1, 1, 0, BIAS_RELU6));
            }
            // Depthwise 3x3 (class K).
            g.push(KernelBuilder::depthwise_conv2d(1, exp_c, hw, hw, 3, 3, stride, 1, BIAS_RELU6));
            let out_hw = hw / stride;
            // Linear projection 1x1: residual add fuses in when the block
            // has a shortcut (stride 1, same channels) -> class A; else a
            // plain conv2d -> class L.
            if stride == 1 && in_c == c {
                g.push(KernelBuilder::conv2d(1, exp_c, out_hw, out_hw, c, 1, 1, 1, 0, &[OpKind::Add]));
            } else {
                g.push(KernelBuilder::conv2d(1, exp_c, out_hw, out_hw, c, 1, 1, 1, 0, &[]));
            }
            in_c = c;
            hw = out_hw;
        }
    }
    // Head: 1x1 to 1280 (class J), pool, classifier.
    g.push(KernelBuilder::conv2d(1, 320, 7, 7, 1280, 1, 1, 1, 0, BIAS_RELU6));
    g.push(KernelBuilder::global_avg_pool(1, 1280, 7, 7));
    g.push(KernelBuilder::dense(1, 1280, 1000, &[OpKind::Add]));
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn class_structure_matches_m4() {
        let g = mobilenet_v2();
        let mut c: BTreeMap<String, usize> = BTreeMap::new();
        for k in &g.kernels {
            *c.entry(k.class_signature()).or_insert(0) += 1;
        }
        // Paper M4: A(7) C(1) D(1) J(8) K(5) L(10) — we accept small
        // deviations from TVM's exact partitioning.
        assert_eq!(c["global_avg_pool2d"], 1);
        assert_eq!(c["dense_add"], 1);
        assert!((5..=9).contains(&c["conv2d_add"]), "A = {}", c["conv2d_add"]);
        assert!((6..=10).contains(&c["conv2d_bias_relu6"]), "J = {}", c["conv2d_bias_relu6"]);
        assert!((4..=12).contains(&c["dwconv2d_bias_relu6"]), "K = {}", c["dwconv2d_bias_relu6"]);
        assert!((6..=12).contains(&c["conv2d"]), "L = {}", c["conv2d"]);
    }

    #[test]
    fn lightweight_model() {
        // ~0.3 GMACs -> well under 1.5 GFLOPs.
        let f = mobilenet_v2().total_flops();
        assert!(f > 3e8 && f < 1.5e9, "flops {f:.3e}");
    }

    #[test]
    fn no_class_e_or_h() {
        // MobileNetV2 shares no conv2d_bias_relu class with ResNet —
        // the paper's heuristic must look at J/K/L availability instead.
        let g = mobilenet_v2();
        assert!(g.kernels_of_class("conv2d_bias_relu").is_empty());
        assert!(g.kernels_of_class("dense_bias_relu").is_empty());
    }
}
