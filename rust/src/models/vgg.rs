//! VGG-16 (Simonyan & Zisserman, ICLR 2015).
//!
//! Table 2 row M3: B(5) max-pools, D(1) classifier, E(9) unique conv
//! kernels (13 conv layers dedupe to 9: repeated same-shape 3x3 convs
//! within a stage share a workload id), H(2) FC+ReLU, I(1) flatten.

use crate::ir::{KernelBuilder, ModelGraph, OpKind};

const BIAS_RELU: &[OpKind] = &[OpKind::BiasAdd, OpKind::Relu];

pub fn vgg16() -> ModelGraph {
    let mut g = ModelGraph::new("VGG-16");
    // (in_c, out_c, hw, convs in stage)
    let stages: &[(u64, u64, u64, usize)] = &[
        (3, 64, 224, 2),
        (64, 128, 112, 2),
        (128, 256, 56, 3),
        (256, 512, 28, 3),
        (512, 512, 14, 3),
    ];
    for &(in_c, out_c, hw, convs) in stages {
        g.push(KernelBuilder::conv2d(1, in_c, hw, hw, out_c, 3, 3, 1, 1, BIAS_RELU));
        for _ in 1..convs {
            g.push(KernelBuilder::conv2d(1, out_c, hw, hw, out_c, 3, 3, 1, 1, BIAS_RELU));
        }
        g.push(KernelBuilder::pool2d(OpKind::MaxPool2d, 1, out_c, hw, hw, 2, 2, 2));
    }
    g.push(KernelBuilder::eltwise(&[OpKind::Flatten], 512 * 7 * 7));
    g.push(KernelBuilder::dense(1, 25088, 4096, BIAS_RELU));
    g.push(KernelBuilder::dense(1, 4096, 4096, BIAS_RELU));
    g.push(KernelBuilder::dense(1, 4096, 1000, &[OpKind::Add]));
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn matches_table2_row_m3() {
        let g = vgg16();
        let mut c: BTreeMap<String, usize> = BTreeMap::new();
        for k in &g.kernels {
            *c.entry(k.class_signature()).or_insert(0) += 1;
        }
        assert_eq!(c["max_pool2d"], 5); // B
        assert_eq!(c["dense_add"], 1); // D
        assert_eq!(c["conv2d_bias_relu"], 9); // E: 13 convs, 9 unique
        assert_eq!(c["dense_bias_relu"], 2); // H
        assert_eq!(c["flatten"], 1); // I
    }

    #[test]
    fn thirteen_conv_instances() {
        let g = vgg16();
        let conv_instances = g
            .instances
            .iter()
            .filter(|i| g.kernels[i.kernel].class_signature() == "conv2d_bias_relu")
            .count();
        assert_eq!(conv_instances, 13);
    }

    #[test]
    fn vgg_is_heavy() {
        // ~15.5 GMACs -> ~31 GFLOPs.
        let f = vgg16().total_flops();
        assert!(f > 25e9 && f < 40e9, "flops {f:.3e}");
    }
}
