//! BERT-base and MobileBERT for sequence classification (paper §5.1),
//! parameterized by sequence length (§5.4 varies it: 128 vs 256).
//!
//! Table 2 rows M9/M10: the models are utterly dominated by class Q
//! (`dense`, 98%/97% of untuned time). BERT-base deduplicates to exactly
//! 3 unique dense kernels — QKV/attention-output projections share the
//! (S,768)x(768,768) shape; the FFN contributes (S,768)x(768,3072) and
//! (S,3072)x(3072,768). Class R is the pair of attention batch-matmuls,
//! S softmax, T layer-norm, U GELU, V the embedding add; D is the final
//! classifier head. This is why the paper's BERT numbers are extreme:
//! transfer a good dense schedule and you have transferred 98% of the
//! model.

use crate::ir::{KernelBuilder, ModelGraph, OpKind};

/// BERT-base: 12 layers, hidden 768, 12 heads, FFN 3072.
pub fn bert(seq: u64) -> ModelGraph {
    let name = if seq == super::DEFAULT_SEQ_LEN {
        "BERT".to_string()
    } else {
        format!("BERT-{seq}")
    };
    let mut g = ModelGraph::new(&name);
    let hidden = 768u64;
    let heads = 12u64;
    let head_dim = hidden / heads;
    let ffn = 3072u64;

    // Embedding lookup + position/segment adds (class V).
    g.push(KernelBuilder::eltwise(&[OpKind::Embedding, OpKind::Add], seq * hidden));

    for _ in 0..12 {
        // Q, K, V projections — identical shapes, dedupe to one workload.
        for _ in 0..3 {
            g.push(KernelBuilder::dense(seq, hidden, hidden, &[]));
        }
        // Attention scores QK^T (class R) + softmax (class S).
        g.push(KernelBuilder::batch_matmul(heads, seq, head_dim, seq, &[]));
        g.push(KernelBuilder::row_reduce(OpKind::Softmax, heads * seq, seq, &[]));
        // Attention-weighted values (class R, second unique shape).
        g.push(KernelBuilder::batch_matmul(heads, seq, seq, head_dim, &[]));
        // Output projection (dedupes with QKV).
        g.push(KernelBuilder::dense(seq, hidden, hidden, &[]));
        // LayerNorm (class T).
        g.push(KernelBuilder::row_reduce(OpKind::LayerNorm, seq, hidden, &[]));
        // FFN: up (with GELU as separate class-U kernel) and down.
        g.push(KernelBuilder::dense(seq, hidden, ffn, &[]));
        g.push(KernelBuilder::eltwise(&[OpKind::Gelu], seq * ffn));
        g.push(KernelBuilder::dense(seq, ffn, hidden, &[]));
        g.push(KernelBuilder::row_reduce(OpKind::LayerNorm, seq, hidden, &[]));
    }

    // Pooler/classifier head (class D).
    g.push(KernelBuilder::dense(1, hidden, 2, &[OpKind::Add]));
    g
}

/// MobileBERT: 24 thin layers (hidden 512, intra-block bottleneck 128,
/// 4 heads); uses NoNorm (folded into adjacent dense kernels), so —
/// matching Table 2 row M10 — the class set is only D, Q, R, S.
pub fn mobilebert(seq: u64) -> ModelGraph {
    let name = if seq == super::DEFAULT_SEQ_LEN {
        "MobileBERT".to_string()
    } else {
        format!("MobileBERT-{seq}")
    };
    let mut g = ModelGraph::new(&name);
    let hidden = 512u64;
    let intra = 128u64;
    let heads = 4u64;
    let head_dim = intra / heads;

    for _ in 0..24 {
        // Bottleneck input projection: hidden -> intra.
        g.push(KernelBuilder::dense(seq, hidden, intra, &[]));
        // QKV + output projections in the intra space (dedupe to 1).
        for _ in 0..4 {
            g.push(KernelBuilder::dense(seq, intra, intra, &[]));
        }
        g.push(KernelBuilder::batch_matmul(heads, seq, head_dim, seq, &[]));
        g.push(KernelBuilder::row_reduce(OpKind::Softmax, heads * seq, seq, &[]));
        g.push(KernelBuilder::batch_matmul(heads, seq, seq, head_dim, &[]));
        // Stacked FFNs intra->hidden (the MobileBERT "stacked FFN" block)
        // and output projection back up.
        g.push(KernelBuilder::dense(seq, intra, hidden, &[]));
        g.push(KernelBuilder::dense(seq, hidden, hidden, &[]));
    }

    g.push(KernelBuilder::dense(1, hidden, 2, &[OpKind::Add]));
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn counts(g: &ModelGraph) -> BTreeMap<String, usize> {
        let mut c = BTreeMap::new();
        for k in &g.kernels {
            *c.entry(k.class_signature()).or_insert(0) += 1;
        }
        c
    }

    #[test]
    fn bert_matches_table2_row_m9() {
        let g = bert(256);
        let c = counts(&g);
        // Paper M9: D(1) Q(3) R(2) S(1) T(1) U(1) V(1).
        assert_eq!(c["dense_add"], 1);
        assert_eq!(c["dense"], 3);
        assert_eq!(c["batch_matmul"], 2);
        assert_eq!(c["softmax"], 1);
        assert_eq!(c["layer_norm"], 1);
        assert_eq!(c["gelu"], 1);
        assert_eq!(c["embedding_add"], 1);
        assert_eq!(g.kernels.len(), 10);
    }

    #[test]
    fn mobilebert_matches_table2_row_m10() {
        let g = mobilebert(256);
        let c = counts(&g);
        // Paper M10: D(1) Q(4) R(2) S(1).
        assert_eq!(c["dense_add"], 1);
        assert_eq!(c["dense"], 4);
        assert_eq!(c["batch_matmul"], 2);
        assert_eq!(c["softmax"], 1);
        assert_eq!(c.len(), 4, "{c:?}");
    }

    #[test]
    fn dense_dominates_flops() {
        // Paper: class Q is 98% of BERT's untuned inference time.
        let g = bert(256);
        let dense_flops: f64 = g
            .instances
            .iter()
            .map(|i| &g.kernels[i.kernel])
            .filter(|k| k.class_signature() == "dense")
            .map(|k| k.flops())
            .sum();
        assert!(dense_flops / g.total_flops() > 0.75, "{}", dense_flops / g.total_flops());
    }

    #[test]
    fn seq_len_changes_every_dense_workload() {
        // §5.4: "varying the input size means the whole model is
        // different, since every single kernel has different data sizes".
        let g256 = bert(256);
        let g128 = bert(128);
        for k256 in g256.kernels_of_class("dense") {
            let id = g256.kernels[k256].workload_id;
            assert!(g128.kernels.iter().all(|k| k.workload_id != id));
        }
        // But the class signatures are unchanged -> transfer-tuning works.
        assert_eq!(g256.class_signatures(), g128.class_signatures());
    }

    #[test]
    fn named_with_seq_suffix() {
        assert_eq!(bert(128).name, "BERT-128");
        assert_eq!(bert(256).name, "BERT");
    }
}
